"""Full-PCILT Mamba decode: calibrate -> convert_mamba_decode -> generate.

The end-to-end deployment story for the ``mamba2_130m`` config family: one
offline conversion (``core.serving.convert_mamba_decode`` — a calibration
prefill, the per-layer conv ``[L, C, V]`` and layer-stacked projection
``[L, G, V, O]`` table builds, and the hoisted jitted step executor), then a
greedy generation loop where *every* matmul of the decode hot loop — the
conv frontend and all six projections per layer — executes as a PCILT table
fetch via the scalar-prefetch stacked kernel.  Finishes by checking the
fetch path against the fake-quant dense oracle (the paper's exactness-on-
the-grid claim, composed through the whole step) and printing the table
memory the conversion deploys.

Runs the reduced smoke dims of the ``mamba2_130m`` config so it completes
in seconds on CPU (interpret-mode kernels); the full 24-layer d768 config
converts identically but wants bf16 tables / ext.-3 sharing for the
projection table memory (see ``benchmarks/run.py`` ``lm.*``).

    PYTHONPATH=src python examples/decode_pcilt.py

Doubles as the manual repro for the ``decode_e2e.*`` benchmark section
(``BENCH_pr5.json``).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PCILTConfig
from repro.core.serving import convert_mamba_decode
from repro.models import build_model
from repro.nn import materialize
from repro.nn.layers import Ctx


def main(steps: int = 8):
    cfg = get_smoke_config("mamba2-130m")
    cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                              dtype=jnp.float32)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = materialize(model.param_specs(), key)
    ctx = Ctx()

    # --- offline: calibrate + build every table + hoist the executor ------
    calib = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    eng = convert_mamba_decode(model, params, calib)
    eng.tune(batch=1)  # record fused_gemv_stacked tilings for this shape
    n_proj = len(eng.pcilt["proj"]["tables"])
    print(f"converted {cfg.n_layers} layers: conv tables "
          f"{tuple(eng.pcilt['tables'].shape)} + {n_proj} stacked projection "
          f"tables; {eng.table_bytes() / 2**20:.2f} MiB total")

    # --- generate: prefill a prompt, then greedy full-PCILT decode --------
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (1, 16), 0,
                                cfg.vocab)
    logits, cache = model.prefill(params, {"tokens": prompt}, ctx)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [int(tok[0, 0])]
    for _ in range(steps - 1):
        logits, cache = eng.step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(int(tok[0, 0]))
    print(f"greedy full-PCILT decode, {steps} steps: {out_tokens}")

    # --- exactness on the quantized grid ----------------------------------
    oracle_pc = dict(eng.pcilt, proj=dict(eng.pcilt["proj"],
                                          path="dense_fq"))
    l_fetch, _ = eng.step(params, cache, tok)
    l_oracle, _ = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx, pcilt=oracle_pc)
    )(params, cache, tok)
    np.testing.assert_allclose(np.asarray(l_fetch), np.asarray(l_oracle),
                               rtol=2e-4, atol=2e-4)
    print("stacked table fetch == fake-quant dense oracle ✓ "
          f"(max |Δ| = {float(jnp.max(jnp.abs(l_fetch - l_oracle))):.2e})")


if __name__ == "__main__":
    main()
