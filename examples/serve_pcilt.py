"""PCILT quantized serving: the paper's technique on an LM decode path.

Converts a decoder's MLP projections into grouped PCILTs offline (the
once-per-lifetime build), then decodes with table *fetches* instead of
multiplies and verifies the fetch path equals the dense matmul on the
quantized activation grid — the paper's exactness claim, composed through a
whole transformer block.  Also prints the table-memory arithmetic, which is
why the serving integration targets the memory-bound decode GEMV regime and
small models / shared tables (DESIGN.md §6).

    PYTHONPATH=src python examples/serve_pcilt.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import QuantSpec, calibrate, quantize, dequantize
from repro.core.serving import convert_kernel, mlp_table_bytes
from repro.models import build_model
from repro.nn.module import materialize
from repro.nn.layers import Ctx


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    ctx = Ctx()
    spec = QuantSpec(bits=4)
    group = 2

    # --- offline: convert layer-0 MLP kernels to PCILTs -------------------
    blk = jax.tree.map(lambda a: a[0], params["blocks"])["sub0"]["mlp"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model)) * 0.5
    x = jnp.abs(x)  # post-norm activations are roughly symmetric; use |x|
    s_in = calibrate(x, spec)
    lut_g = convert_kernel(blk["wg"]["kernel"], spec, s_in, group)
    lut_u = convert_kernel(blk["wu"]["kernel"], spec, s_in, group)

    # --- decode-time: fetch instead of multiply ---------------------------
    xq = dequantize(quantize(x, spec, s_in), spec, s_in)
    for path in ("gather", "onehot", "kernel"):
        g_lut = lut_g(x, path=path)
        np.testing.assert_allclose(
            np.asarray(g_lut), np.asarray(xq @ blk["wg"]["kernel"]),
            rtol=1e-4, atol=1e-4)
    print("MLP gate projection: PCILT(gather|onehot|kernel) == dense ✓")

    h = jax.nn.silu(lut_g(x)) * lut_u(x)
    s_h = calibrate(h, spec)
    lut_d = convert_kernel(blk["wd"]["kernel"], spec, s_h, group)
    y_lut = lut_d(h)
    hq = dequantize(quantize(h, spec, s_h), spec, s_h)
    np.testing.assert_allclose(np.asarray(y_lut),
                               np.asarray(hq @ blk["wd"]["kernel"]),
                               rtol=1e-4, atol=1e-4)
    print("full MLP through PCILTs: exact on the quantized grid ✓")

    # --- the memory story --------------------------------------------------
    for d, f, label in ((cfg.d_model, cfg.d_ff, "smoke"),
                        (1024, 3072, "qwen3-0.6b"),
                        (7168, 19200, "deepseek-33b")):
        mb = mlp_table_bytes(d, f, act_bits=4, group=group) / 2**20
        print(f"table memory, {label:12s} MLP layer: {mb:10.1f} MiB "
              f"(INT4, g={group})")
    print("→ big GEMMs need ext.3 shared tables or stay on the MXU; the "
          "fetch path earns its keep on conv frontends and narrow "
          "projections (DESIGN.md §6).")


if __name__ == "__main__":
    main()
