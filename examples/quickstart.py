"""Quickstart: the paper's algorithm end to end on its own example CNN.

Builds the PCILTs once ("done only once in the lifetime of a CNN"), runs
inference through the fetch paths, and verifies the paper's exactness claim
against direct multiplication.  Prints the op-count and table-memory
arithmetic for the configuration.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import smoke_config
from repro.core import calibrate, table_bytes, build_cost_multiplies
from repro.nn.module import materialize


def main():
    model = smoke_config()
    print(f"paper CNN (reduced): channels={model.channels}, "
          f"{model.k}x{model.k} filters, INT{model.act_spec.bits} activations")

    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 16, 16, 1)) * 2

    # calibration pass (per-layer activation scales)
    scales, h = {}, x
    for i in range(len(model.channels)):
        scales[f"conv{i}"] = calibrate(h, model.act_spec)
        h = jax.nn.relu(jax.lax.conv_general_dilated(
            h, params[f"conv{i}"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))

    # offline table build — the once-per-lifetime step
    t0 = time.time()
    tables = model.build_tables(params, scales)
    print(f"table build: {time.time()-t0:.3f}s")

    dm = model.forward(params, x, mode="dm", scales=scales)
    for path in ("gather", "onehot"):
        t0 = time.time()
        out = model.forward(params, x, mode=path, scales=scales, tables=tables)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dm),
                                   rtol=1e-3, atol=1e-3)
        print(f"PCILT[{path:7s}] == DM  ✓   ({time.time()-t0:.3f}s)")

    # the paper's arithmetic, for this network
    n_w = sum(int(np.prod(params[f"conv{i}"].shape))
              for i in range(len(model.channels)))
    print(f"\nweights: {n_w}; PCILT memory "
          f"{table_bytes(n_w, model.act_spec.bits, 2)/1e6:.2f} MB; "
          f"build multiplies {build_cost_multiplies(n_w, model.act_spec.bits):,}")
    print("exactness: 'The PCILT values are an exact product of the "
          "convolutional function — there is no result precision loss.'")


if __name__ == "__main__":
    main()
