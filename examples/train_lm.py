"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the qwen3 family at a ~100M reduced width on the synthetic corpus with
the full production substrate: AdamW + cosine schedule, packed/masked data,
watchdog, async checkpointing, and (optionally) an injected fault to
demonstrate restart-and-replay.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.nn.module import materialize, count_params
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.checkpoint import Checkpointer
from repro.runtime import StepWatchdog
from repro.launch.steps import make_train_step


def config_100m():
    base = get_smoke_config("qwen3-0.6b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=1536, vocab=32000, head_dim=64,
        tie_embeddings=True, loss_chunk=0,
    )


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=60)  # CPU demo; --steps 300 on real hardware
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args(argv)

    cfg = config_100m()
    model = build_model(cfg)
    specs = model.param_specs()
    print(f"training {cfg.name}: {count_params(specs)/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} synthetic tokens")

    params = materialize(specs, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=cosine_schedule(1e-3, 20, args.steps),
                       weight_decay=0.01)
    opt = adamw_init(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, None, ocfg), donate_argnums=(0, 1))

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    watchdog = StepWatchdog()

    losses = []
    t_start = time.time()
    for step in range(args.steps):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        params, opt, m = step_fn(params, opt, batch)
        watchdog.observe(step, time.time() - t0)
        losses.append(float(m["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)
        if step and step % 100 == 0:
            ckpt.save_async(step, {"params": params, "opt": opt})
    ckpt.wait()
    dt = time.time() - t_start
    toks = args.steps * args.batch * args.seq
    print(f"\ndone in {dt:.1f}s ({toks/dt:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers flagged: {watchdog.flagged}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
