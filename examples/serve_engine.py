"""Continuous-batching serving demo: batched requests through the slot
engine (prefill + decode with KV cache recycling).

    PYTHONPATH=src python examples/serve_engine.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3-0.6b", "--requests", "6", "--max-new", "12",
          "--slots", "3"])
