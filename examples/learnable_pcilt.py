"""Extension 4 demo — "Using PCILTs as Weights".

Trains table entries directly (no filter weights) on a small regression
task at each of the paper's four adjustment granularities, then reconstructs
classic filters from the trained tables ("analyze the final PCILT values and
build back from them weight-adjusted input filters").

    PYTHONPATH=src python examples/learnable_pcilt.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    QuantSpec, calibrate, init_learnable_pcilt, apply_learnable_pcilt,
    effective_tables, extract_filters,
)


def main():
    spec = QuantSpec(bits=2)
    key = jax.random.PRNGKey(0)
    n_in, n_out, batch = 16, 4, 64
    x = jnp.abs(jax.random.normal(key, (batch, n_in)))
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (n_in, n_out))
    y = x @ w_true
    scale = float(calibrate(x, spec))

    for gran in ("filter", "table", "offset", "entry"):
        params = init_learnable_pcilt(
            jax.random.fold_in(key, 2), n_in, n_out, spec, scale, group=2,
            granularity=gran)

        def loss(p):
            return jnp.mean((apply_learnable_pcilt(p, x, spec, scale, 2) - y) ** 2)

        l0 = float(loss(params))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params = jax.tree.map(lambda a, b: a - 0.03 * b, params, g)
        print(f"granularity={gran:7s}  loss {l0:8.4f} -> {float(loss(params)):8.4f}"
              f"   (params adjusted: "
              f"{[k for k in params if k != 'base']})")

    # reconstruct classic filters from the entry-trained tables
    w_rec = extract_filters(effective_tables(params), spec, scale, 2)
    err = float(jnp.mean((x @ w_rec - apply_learnable_pcilt(
        params, x, spec, scale, 2)) ** 2))
    print(f"\nfilters rebuilt from tables: surrogate-DM vs LUT mse={err:.5f} "
          "(exact when tables stay in the product manifold; the residual is "
          "the extra expressivity per-entry training bought)")


if __name__ == "__main__":
    main()
