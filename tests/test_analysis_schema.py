"""Artifact schemas (repro.analysis.schema): the checked-in BENCH payloads
and any committed autotune cache validate clean, seeded violations fail with
named findings, and a real TileCache round-trips through the validator."""

import copy
import glob
import json
import os

import pytest

from repro.analysis import repo_root
from repro.analysis import schema
from repro.kernels import autotune as atn


def _rules(findings):
    return sorted({f.rule for f in findings})


# ----------------------------------------------------------------------------
# Checked-in artifacts are clean (the acceptance gate)
# ----------------------------------------------------------------------------


def test_repo_artifacts_validate_clean():
    fs = schema.validate_repo_artifacts(repo_root())
    assert fs == [], "\n".join(f.render() for f in fs)


def test_checked_in_bench_files_exist_and_validate():
    paths = sorted(glob.glob(os.path.join(repo_root(), "BENCH_*.json")))
    assert paths, "the repo ships BENCH_pr*.json artifacts"
    for p in paths:
        with open(p) as f:
            payload = json.load(f)
        assert schema.validate_bench(payload, p) == []


# ----------------------------------------------------------------------------
# Shape-key grammar
# ----------------------------------------------------------------------------


def test_parse_shape_key_roundtrips_real_keys():
    key = atn.shape_key("fused_gemv", dtype="float32", backend="cpu",
                        B=8, G=512, V=16, O=1024, g=2, bits=2)
    kernel, dims, dtype, backend = schema.parse_shape_key(key)
    assert kernel == "fused_gemv" and dtype == "float32" and backend == "cpu"
    assert dims == {"B": 8, "G": 512, "V": 16, "O": 1024, "g": 2, "bits": 2}


@pytest.mark.parametrize("bad", [
    "no_pipes_at_all",
    "fused_gemv|B=8,G=2|backend=cpu",            # missing dtype
    "fused_gemv|B=eight,dtype=float32|backend=cpu",  # non-int dim
    "fused_gemv|B=8,dtype=float32",              # missing backend
])
def test_parse_shape_key_rejects_malformed(bad):
    with pytest.raises(ValueError):
        schema.parse_shape_key(bad)


def test_known_kernels_match_family_names():
    from repro.analysis import vmem
    assert {f.name for f in vmem.FAMILIES()} == set(schema.KNOWN_KERNELS)


# ----------------------------------------------------------------------------
# Autotune cache validation
# ----------------------------------------------------------------------------


def _good_cache():
    key = atn.shape_key("fused_gemv", dtype="float32", backend="cpu",
                        B=8, G=512, V=16, O=1024, g=2, bits=2)
    return {key: {"tiles": {"Bb": 8, "Gb": 512, "Ob": 128, "row_tile": 8},
                  "us": 812.4, "candidates": 4}}


def test_good_cache_validates_clean():
    assert schema.validate_tune_cache(_good_cache()) == []


def test_null_us_untimed_fallback_is_legal():
    c = _good_cache()
    entry = next(iter(c.values()))
    entry["us"] = None
    entry["candidates"] = 0
    assert schema.validate_tune_cache(c) == []


@pytest.mark.parametrize("mutate, needle", [
    (lambda c, e: c.update({"gibberish key": e}), "bad shape key"),
    (lambda c, e: c.update({"mystery_kernel|B=8,dtype=f32|backend=cpu": e}),
     "unknown kernel family"),
    (lambda c, e: c.update(
        {"fused_gemv|B=8,dtype=float32|backend=cpu": e}), "missing required"),
    (lambda c, e: e["tiles"].update({"Gb": 0}), "positive int"),
    (lambda c, e: e["tiles"].update({"Qb": 3}), "unknown fields"),
    (lambda c, e: e.update({"us": float("nan")}), "finite"),
    (lambda c, e: e.update({"candidates": -1}), "non-negative"),
    (lambda c, e: e.pop("us"), "missing 'us'"),
    (lambda c, e: e.update({"extra": 1}), "unknown fields"),
    (lambda c, e: e.update({"us": 10.0, "candidates": 0}), "contradictory"),
])
def test_seeded_cache_violations_fire_schema002(mutate, needle):
    c = _good_cache()
    mutate(c, next(iter(c.values())))
    fs = schema.validate_tune_cache(c)
    assert fs and _rules(fs) == ["SCHEMA002"]
    assert any(needle in f.message for f in fs), \
        f"{needle!r} not in: " + "\n".join(f.message for f in fs)


def test_real_tilecache_roundtrip_validates(tmp_path):
    path = str(tmp_path / "tiles.json")
    cache = atn.TileCache(path)
    key = atn.shape_key("shared_gemv", dtype="bfloat16", backend="cpu",
                        B=8, G=64, V=16, O=256, X=4, g=2, bits=2)
    cache.record(key, atn.TileConfig(Bb=8, Gb=64, Ob=128), 55.5, 3)
    cache.record(  # a failed tune records us=null — also schema-legal
        atn.shape_key("fused_dwconv1d", dtype="float32", backend="cpu",
                      B=2, T=16, C=128, V=256, k=4, bits=2),
        atn.TileConfig(Bb=16, Gb=1, Ob=128), None, 0)
    with open(path) as f:
        payload = json.load(f)
    assert schema.validate_tune_cache(payload, path) == []


# ----------------------------------------------------------------------------
# BENCH payload validation
# ----------------------------------------------------------------------------


def _good_bench():
    return {
        "pr": 7, "backend": "cpu", "timing": "perf_counter min-of-5",
        "skipped": {"decode.e2e": "needs 8 devices"},
        "rows": [
            {"name": "gemv.fused_f32", "us_per_call": 812.4,
             "derived": 1.31},
            {"name": "decode.e2e", "us_per_call": 0.0,
             "derived": "skipped: needs 8 devices",
             "skipped": "needs 8 devices"},
        ],
        "speedup": {"gemv": 1.31},
        "target_min_speedup": {"gemv": 1.3},
    }


def test_good_bench_validates_clean():
    assert schema.validate_bench(_good_bench()) == []


@pytest.mark.parametrize("mutate, needle", [
    (lambda b: b.update({"pr": "seven"}), "'pr' must be an int"),
    (lambda b: b.update({"backend": ""}), "non-empty string"),
    (lambda b: b.update({"rows": []}), "non-empty list"),
    (lambda b: b["rows"][0].update({"name": "NoSection"}),
     "'<section>.<case>'"),
    (lambda b: b["rows"][0].pop("us_per_call"), "missing required"),
    (lambda b: b["rows"][0].update({"us_per_call": float("inf")}), "finite"),
    (lambda b: b["rows"][0].update({"mystery": 1}), "unknown fields"),
    (lambda b: b["rows"][1].pop("skipped"), "no row carries the skip"),
    (lambda b: b.update({"skipped": {}}), "no entry in the top-level"),
    (lambda b: b["rows"][1].update({"derived": 0.0}), None),
    (lambda b: b.update({"target_min_speedup": 1.3}),
     "map metric names to finite numbers"),
    (lambda b: b.update({"speedup": {"gemv": float("nan")}}),
     "map metric names to finite numbers"),
])
def test_seeded_bench_violations_fire_schema001(mutate, needle):
    b = _good_bench()
    mutate(b)
    fs = schema.validate_bench(b)
    if needle is None:  # derived losing its skip marker: any finding is fine
        assert fs and _rules(fs) == ["SCHEMA001"]
        return
    assert fs and _rules(fs) == ["SCHEMA001"]
    assert any(needle in f.message for f in fs), \
        f"{needle!r} not in: " + "\n".join(f.message for f in fs)


def test_unreadable_artifacts_become_findings_not_crashes(tmp_path):
    (tmp_path / "BENCH_pr9.json").write_text("{not json")
    (tmp_path / "tiles.json").write_text("[1, 2")
    fs = schema.validate_repo_artifacts(str(tmp_path))
    assert _rules(fs) == ["SCHEMA001", "SCHEMA002"]
    assert all("unreadable" in f.message for f in fs)


def test_legacy_scalar_target_min_speedup_rejected():
    # the drift this pass caught in the real BENCH_pr1/pr2 artifacts: the
    # PR-4 writer moved to per-metric maps, stale scalars must keep failing
    b = _good_bench()
    b["target_min_speedup"] = 1.3
    fs = schema.validate_bench(b)
    assert any("target_min_speedup" in f.message for f in fs)


def test_mutating_a_copy_of_checked_in_bench_fails(tmp_path):
    src = sorted(glob.glob(os.path.join(repo_root(), "BENCH_*.json")))[0]
    with open(src) as f:
        payload = json.load(f)
    bad = copy.deepcopy(payload)
    bad["rows"][0]["us_per_call"] = float("nan")
    assert schema.validate_bench(bad) != []
