"""Fault tolerance: watchdog, straggler detection, supervised restart, and
the seeded data pipeline's determinism guarantees."""

import numpy as np
import pytest

from repro.runtime import StepWatchdog, detect_stragglers, Supervisor, FaultInjector
from repro.data import SyntheticLM


def test_watchdog_flags_slow_step():
    w = StepWatchdog(deadline_factor=2.0, min_samples=3)
    for s in range(6):
        assert not w.observe(s, 0.1)
    assert w.observe(6, 1.0)          # 10x the EMA
    assert w.flagged == [6]


def test_detect_stragglers():
    times = [0.1, 0.11, 0.09, 0.5, 0.1, 0.1, 0.1, 0.1]
    assert detect_stragglers(times, threshold=2.0) == [3]
    assert detect_stragglers([0.1] * 8) == []


def test_detect_stragglers_degenerate_inputs():
    # all hosts equal: nobody exceeds threshold x median
    assert detect_stragglers([0.25, 0.25, 0.25, 0.25]) == []
    # a single host is its own median — it can never be its own straggler
    assert detect_stragglers([0.25]) == []
    assert detect_stragglers([1e9]) == []


def test_supervisor_restarts_and_replays():
    """Injected fault at step 25 -> restore at 20 -> final state identical to
    an uninterrupted run (determinism through restart)."""
    saved = {}

    def make_run(fail_at):
        inj = FaultInjector(fail_at)
        log = []

        def step_fn(state, step):
            inj.maybe_fail(step)
            log.append(step)
            return state + step

        def save_fn(state, step):
            saved[step] = state

        def restore_fn():
            if not saved:
                return None
            s = max(saved)
            return s, saved[s]

        sup = Supervisor(step_fn, save_fn, restore_fn, ckpt_every=10,
                         max_restarts=3)
        return sup.run(0, 40)

    saved.clear()
    step, state, stats = make_run([25])
    assert step == 40 and stats["restarts"] == 1
    saved.clear()
    _, state_clean, _ = make_run([])
    assert state == state_clean  # replayed steps reproduce the same state


def test_supervisor_gives_up_after_max_restarts():
    calls = []

    def step_fn(state, step):
        calls.append(step)
        raise RuntimeError("always fails")

    sup = Supervisor(step_fn, lambda *a: None, lambda: (0, 0),
                     ckpt_every=10, max_restarts=2)
    with pytest.raises(RuntimeError, match="always fails"):
        sup.run(0, 10)
    # the budget bounds the attempts: initial try + max_restarts replays
    assert len(calls) == 3


def test_supervisor_no_checkpoint_to_restore():
    """A fault before the first checkpoint exists must surface as a restore
    failure, not an infinite replay of nothing."""
    def step_fn(state, step):
        if step == 3:
            raise ValueError("fault before any checkpoint")
        return state + step

    sup = Supervisor(step_fn, lambda *a: None, lambda: None,
                     ckpt_every=10, max_restarts=3)
    with pytest.raises(RuntimeError, match="no checkpoint to restore"):
        sup.run(0, 10)


# ---- data pipeline ----------------------------------------------------------


def test_data_deterministic_per_step_and_shard():
    d1 = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=1,
                     n_shards=2, shard=0)
    d2 = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=1,
                     n_shards=2, shard=0)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    other_shard = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=1,
                              n_shards=2, shard=1).batch(5)
    assert not np.array_equal(b1["tokens"], other_shard["tokens"])
    assert not np.array_equal(b1["tokens"], d1.batch(6)["tokens"])


def test_data_labels_shifted_and_masked():
    d = SyntheticLM(vocab=100, seq_len=64, global_batch=4, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert b["loss_mask"].shape == (4, 64)
    assert set(np.unique(b["loss_mask"])) <= {0.0, 1.0}
    assert b["loss_mask"].sum() > 0
    assert b["tokens"].max() < 100 and b["tokens"].min() >= 0


def test_data_modality_stubs():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=2, memory_len=10,
                    img_tokens=4, d_model=8)
    b = d.batch(0)
    assert b["memory"].shape == (2, 10, 8)
    assert b["img_embeds"].shape == (2, 4, 8)
