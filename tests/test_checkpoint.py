"""Checkpointing: roundtrip, integrity, retention, async fence, latest."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save, restore, latest_step, Checkpointer


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t, extra={"note": "hi"})
    got, extra = restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["note"] == "hi"


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20, 30):
        ck.save_async(s, t)
        ck.wait()
    assert latest_step(str(tmp_path)) == 30
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [20, 30]  # keep=2 garbage-collected step 10


def test_corruption_detected(tmp_path):
    t = _tree()
    d = save(str(tmp_path), 1, t)
    npz = os.path.join(d, "shard_p0.npz")
    raw = bytearray(open(npz, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="corrupt"):
        restore(str(tmp_path), 1, t)


def test_tree_mismatch_detected(tmp_path):
    t = _tree()
    save(str(tmp_path), 2, t)
    other = {"x": jnp.zeros(3)}
    with pytest.raises(ValueError, match="mismatch"):
        restore(str(tmp_path), 2, other)


def test_async_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    t = _tree()
    ck.save_async(5, t, extra={"arch": "x"})
    step, got, extra = ck.restore_latest(t)  # restore_latest waits implicitly?
    # restore may race the writer thread: wait explicitly then retry
    ck.wait()
    step, got, extra = ck.restore_latest(t)
    assert step == 5 and extra["arch"] == "x"


def test_elastic_restore_with_shardings(tmp_path):
    """Restore under (trivial single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save(str(tmp_path), 3, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    got, _ = restore(str(tmp_path), 3, t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
