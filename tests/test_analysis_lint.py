"""AST lint (repro.analysis.lint): every rule fires on a seeded violation,
the repo itself is clean, and the baseline workflow accepts exceptions
without masking new findings."""

import os
import textwrap

import pytest

from repro.analysis import Baseline, Finding, repo_root, run_all
from repro.analysis import __main__ as cli
from repro.analysis import lint


def _lint_snippet(tmp_path, src, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return lint.lint_files([str(p)], root=str(tmp_path))


def _rules(findings):
    return sorted({f.rule for f in findings})


# ----------------------------------------------------------------------------
# Seeded violations: each rule fires
# ----------------------------------------------------------------------------


def test_lint001_bare_assert_fires(tmp_path):
    fs = _lint_snippet(tmp_path, """
        def f(x, y):
            assert x == y, (x, y)
            return x
    """)
    assert _rules(fs) == ["LINT001"]
    assert fs[0].symbol == "f" and "x == y" in fs[0].message


KERNEL_PREAMBLE = """
    import functools
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
"""


def test_lint002_missing_preferred_element_type_fires(tmp_path):
    fs = _lint_snippet(tmp_path, KERNEL_PREAMBLE + """
    def _kern(x_ref, t_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], t_ref[...])

    def run(x, t):
        spec = pl.BlockSpec((8, 8), lambda i: (i, 0))
        return pl.pallas_call(_kern, out_shape=x,
                              in_specs=[spec, spec], out_specs=spec)(x, t)
    """)
    assert _rules(fs) == ["LINT002"]
    assert "preferred_element_type" in fs[0].message


def test_lint002_wrong_accum_dtype_and_matmul_op_fire(tmp_path):
    fs = _lint_snippet(tmp_path, KERNEL_PREAMBLE + """
    def _kern(x_ref, t_ref, o_ref):
        a = jnp.dot(x_ref[...], t_ref[...],
                    preferred_element_type=jnp.bfloat16)
        o_ref[...] = a + x_ref[...] @ t_ref[...]

    def run(x, t):
        spec = pl.BlockSpec((8, 8), lambda i: (i, 0))
        return pl.pallas_call(_kern, out_shape=x,
                              in_specs=[spec, spec], out_specs=spec)(x, t)
    """)
    assert _rules(fs) == ["LINT002"] and len(fs) == 2
    assert any("jnp.bfloat16" in f.message for f in fs)
    assert any("'@'" in f.message for f in fs)


def test_lint002_reaches_helpers_via_partial_and_imports(tmp_path):
    # kernel root passed via functools.partial; the violating dot lives in a
    # helper imported from a sibling module — both hops must be followed.
    (tmp_path / "helpers.py").write_text(textwrap.dedent("""
        import jax.numpy as jnp

        def fetch(a, b):
            return jnp.dot(a, b)
    """))
    (tmp_path / "kern.py").write_text(textwrap.dedent(KERNEL_PREAMBLE + """
    from .helpers import fetch

    def _kern(x_ref, t_ref, o_ref, *, g):
        o_ref[...] = fetch(x_ref[...], t_ref[...])

    def run(x, t):
        spec = pl.BlockSpec((8, 8), lambda i: (i, 0))
        return pl.pallas_call(functools.partial(_kern, g=2), out_shape=x,
                              in_specs=[spec, spec], out_specs=spec)(x, t)
    """))
    fs = lint.lint_files([str(tmp_path / "helpers.py"),
                          str(tmp_path / "kern.py")], root=str(tmp_path))
    assert _rules(fs) == ["LINT002"]
    assert fs[0].path.endswith("helpers.py") and fs[0].symbol == "fetch"


def test_lint002_ignores_host_side_dots(tmp_path):
    # a dot *outside* any kernel body is not the kernel's problem
    fs = _lint_snippet(tmp_path, """
        import jax.numpy as jnp

        def host(a, b):
            return jnp.dot(a, b)
    """)
    assert fs == []


def test_lint003_host_calls_in_kernel_and_index_map_fire(tmp_path):
    fs = _lint_snippet(tmp_path, KERNEL_PREAMBLE + """
    import numpy as np

    def _kern(x_ref, o_ref):
        print("tracing")
        o_ref[...] = x_ref[...] + np.random.rand()

    def run(x):
        spec = pl.BlockSpec((8, 8), lambda i: (i, print(i)))
        return pl.pallas_call(_kern, out_shape=x,
                              in_specs=[spec], out_specs=spec)(x)
    """)
    assert _rules(fs) == ["LINT003"] and len(fs) == 3
    wheres = {f.message for f in fs}
    assert any("index_map" in m for m in wheres)
    assert any("kernel body" in m for m in wheres)


def test_lint004_unkeyed_generator_param_fires(tmp_path):
    fs = _lint_snippet(tmp_path, """
        def dispatch(x, tables, atn):
            B, n = x.shape
            G, V, O = tables.shape
            key = atn.shape_key("fused_gemv", dtype=str(tables.dtype),
                                backend="cpu", B=B, V=V, O=O)
            cands = atn.gemv_candidates(B, G, V, O)
            return key, cands
    """)
    assert _rules(fs) == ["LINT004"]
    assert "'G'" in fs[0].message and fs[0].symbol == "dispatch"


def test_lint004_complete_key_is_clean(tmp_path):
    fs = _lint_snippet(tmp_path, """
        def dispatch(x, tables, atn):
            B, n = x.shape
            G, V, O = tables.shape
            key = atn.shape_key("fused_gemv", dtype=str(tables.dtype),
                                backend="cpu", B=B, G=G, V=V, O=O)
            cands = atn.gemv_candidates(B, G, V, O, tables.dtype.itemsize)
            return key, cands
    """)
    assert fs == []


def test_lint004_derived_dims_cover_roots(tmp_path):
    # the key pins W/k/s; the generator consumes the *derived* Ho — the
    # root-expansion must accept that as covered
    fs = _lint_snippet(tmp_path, """
        def dispatch(x, tables, atn, kh, kw, stride):
            B, Hp, Wp, C = x.shape
            G, V, O = tables.shape
            Ho = (Hp - kh) // stride + 1
            key = atn.shape_key("fused_conv2d", dtype=str(tables.dtype),
                                backend="cpu", B=B, Ho=Ho, W=Wp, C=C,
                                k=kh * kw, s=stride, G=G, V=V, O=O)
            cands = atn.conv2d_candidates(Ho, G, V, O)
            return key, cands
    """)
    assert fs == []


def test_lint004_signature_introspection_rejects_unknown_kwarg(tmp_path):
    fs = _lint_snippet(tmp_path, """
        def dispatch(x, tables, atn):
            B, n = x.shape
            G, V, O = tables.shape
            key = atn.shape_key("fused_gemv", dtype=str(tables.dtype),
                                backend="cpu", B=B, G=G, V=V, O=O)
            cands = atn.gemv_candidates(B, G, V, O, made_up_axis=3)
            return key, cands
    """)
    assert _rules(fs) == ["LINT004"]
    assert "made_up_axis" in fs[0].message


# ----------------------------------------------------------------------------
# The repo itself is clean; rule metadata is consistent
# ----------------------------------------------------------------------------


def test_repo_lint_is_clean():
    root = repo_root()
    fs = lint.lint_tree(os.path.join(root, "src", "repro"), root=root)
    assert fs == [], "\n".join(f.render() for f in fs)


def test_every_lint_rule_has_catalogue_entry():
    assert set(lint.RULES) == {"LINT001", "LINT002", "LINT003", "LINT004"}


# ----------------------------------------------------------------------------
# Baseline workflow
# ----------------------------------------------------------------------------


def _seed_repo(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(textwrap.dedent("""
        def f(x, y):
            assert x == y
            return x
    """))
    return tmp_path


def test_cli_gates_then_baseline_accepts(tmp_path, capsys):
    root = str(_seed_repo(tmp_path))
    assert cli.main(["--passes", "lint", "--root", root]) == 1
    assert cli.main(["--passes", "lint", "--root", root,
                     "--write-baseline"]) == 0
    assert os.path.exists(os.path.join(root, cli.DEFAULT_BASELINE))
    assert cli.main(["--passes", "lint", "--root", root]) == 0
    out = capsys.readouterr().out
    assert "(baselined)" in out


def test_baseline_does_not_mask_new_findings(tmp_path):
    root = _seed_repo(tmp_path)
    assert cli.main(["--passes", "lint", "--root", str(root),
                     "--write-baseline"]) == 0
    (root / "src" / "repro" / "worse.py").write_text(
        "def g(a):\n    assert a\n    return a\n")
    assert cli.main(["--passes", "lint", "--root", str(root)]) == 1


def test_fingerprint_survives_line_drift():
    a = Finding("LINT001", "error", "src/x.py", 10, "bare assert ('a == b') "
                "in library code; raise a typed ValueError", symbol="f")
    b = Finding("LINT001", "error", "src/x.py", 99, "bare assert ('a == b') "
                "in library code; different tail after semicolon",
                symbol="f")
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != Finding(
        "LINT001", "error", "src/x.py", 10,
        "bare assert ('other') in library code", symbol="f").fingerprint()


def test_stale_baseline_version_is_loud(tmp_path):
    p = tmp_path / "base.json"
    p.write_text('{"version": 0, "accepted": []}')
    with pytest.raises(ValueError, match="version 0"):
        Baseline.load(str(p))


def test_run_all_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown analysis passes"):
        run_all(passes=("lint", "typo"))
