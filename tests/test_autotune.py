"""Persistent tile-autotune lookup table: miss -> tune-once-and-record,
hit -> zero-cost dispatch (zero timing runs), across simulated processes."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, calibrate, build_grouped_tables
from repro.kernels import autotune as atn
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.fixture
def tune_cache(tmp_path):
    """Point the autotuner at a private cache file; restore afterwards."""
    path = str(tmp_path / "tiles.json")
    atn.reset_cache(path)
    atn.TIMING_RUNS = 0
    yield path
    atn.TIMING_RUNS = 0
    atn.reset_cache()


def _problem(B=8, n=64, O=256, bits=2, group=2):
    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(n, O)), jnp.float32)
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group)
    return x, T, spec, s, group


def test_miss_tunes_then_hit_is_free(tune_cache):
    x, T, spec, s, group = _problem()
    out1 = ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    assert atn.TIMING_RUNS > 0, "cache miss must time candidates"
    assert os.path.exists(tune_cache)
    entry = next(iter(json.load(open(tune_cache)).values()))
    assert entry["candidates"] >= 1 and entry["tiles"]["Gb"] >= 1

    # "Second process": fresh in-memory cache loaded from the same file.
    atn.reset_cache(tune_cache)
    atn.TIMING_RUNS = 0
    out2 = ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    assert atn.TIMING_RUNS == 0, "warm cache must perform zero timing runs"
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_round_trip_returns_same_tiles(tune_cache):
    x, T, spec, s, group = _problem()
    B, O = x.shape[0], T.shape[-1]
    G, V = T.shape[0], T.shape[1]
    key = atn.shape_key("fused_gemv", dtype=T.dtype, backend="cpu",
                        B=B, G=G, V=V, O=O, g=group, bits=spec.bits)
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    first = atn.lookup(key)
    assert first is not None
    atn.reset_cache(tune_cache)
    assert atn.lookup(key) == first


def test_lookup_only_dispatch_never_times(tune_cache):
    """Without autotune=True a miss falls back to the heuristic silently."""
    x, T, spec, s, group = _problem()
    ops.pcilt_fused_gemv(x, T, spec, s, group)  # autotune defaults off
    assert atn.TIMING_RUNS == 0
    assert not os.path.exists(tune_cache)


def test_host_kernels_route_through_cache(tune_cache):
    """Host-packed gemv/conv2d dispatch also tunes and stays correct."""
    off = jnp.asarray(RNG.integers(0, 16, (8, 12)), jnp.int32)
    tab = jnp.asarray(RNG.normal(size=(12, 16, 40)), jnp.float32)
    got = ops.pcilt_gemv(off, tab, autotune=True)
    assert atn.TIMING_RUNS > 0
    np.testing.assert_allclose(got, ref.pcilt_gemv_ref(off, tab),
                               rtol=1e-5, atol=1e-5)
    runs_after_gemv = atn.TIMING_RUNS
    offc = jnp.asarray(RNG.integers(0, 8, (1, 6, 6, 3)), jnp.int32)
    tabc = jnp.asarray(RNG.normal(size=(3, 8, 20)), jnp.float32)
    gotc = ops.pcilt_conv2d(offc, tabc, autotune=True)
    assert atn.TIMING_RUNS > runs_after_gemv
    np.testing.assert_allclose(gotc, ref.pcilt_conv2d_ref(offc, tabc),
                               rtol=1e-5, atol=1e-5)
    # both hits on re-dispatch
    atn.TIMING_RUNS = 0
    ops.pcilt_gemv(off, tab, autotune=True)
    ops.pcilt_conv2d(offc, tabc, autotune=True)
    assert atn.TIMING_RUNS == 0


def test_serving_tune_populates_cache(tune_cache):
    from repro.core.serving import convert_kernel

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 1, (4, 24)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(24, 32)), jnp.float32)
    lin = convert_kernel(k, spec, calibrate(x, spec), group=2)
    want = lin(x, path="gather")
    got = lin.tune(x)
    assert atn.TIMING_RUNS > 0
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    atn.TIMING_RUNS = 0
    np.testing.assert_allclose(lin(x, path="fused"), want,
                               rtol=1e-4, atol=1e-4)
    assert atn.TIMING_RUNS == 0


def test_concurrent_saves_keep_newest_per_key(tune_cache):
    """Regression: a process must merge back only keys it recorded itself.

    Two interleaved caches share one file.  Cache B re-records key "a" after
    cache A loaded the stale copy; when A later records its own key "b", A's
    save must not clobber B's newer "a" with A's stale startup copy ("last
    writer wins per key only")."""
    t = atn.TileConfig(Bb=8, Gb=1, Ob=128)
    seed = atn.TileCache(tune_cache)
    seed.record("a", atn.TileConfig(Bb=8, Gb=1, Ob=1), 5.0, 1)

    cache_a = atn.TileCache(tune_cache)  # loads a@v1
    cache_b = atn.TileCache(tune_cache)  # loads a@v1
    newer = atn.TileConfig(Bb=16, Gb=2, Ob=256)
    cache_b.record("a", newer, 3.0, 2)   # concurrent tuner updates "a"
    cache_a.record("b", t, 7.0, 1)       # we only recorded "b"

    final = atn.TileCache(tune_cache)
    assert final.lookup("a") == newer, "stale startup copy clobbered newer entry"
    assert final.lookup("b") == t


def test_failed_tune_records_null_not_nan(tune_cache):
    """Regression: an all-candidates-failed tune must write valid JSON
    (us: null), never a bare NaN token that breaks strict parsers/jq."""
    cands = [atn.TileConfig(Bb=8, Gb=1, Ob=128)]

    def bench(cfg):
        raise RuntimeError("no candidate can run")

    got = atn.tune("k|dtype=float32|backend=cpu", cands, bench)
    assert got == cands[0]  # heuristic fallback still dispatches
    raw = open(tune_cache).read()
    assert "NaN" not in raw
    entry = json.loads(raw)["k|dtype=float32|backend=cpu"]  # strict parse ok
    assert entry["us"] is None and entry["candidates"] == 0
    # lookup tolerates the null timing and returns the recorded tiles
    atn.reset_cache(tune_cache)
    assert atn.lookup("k|dtype=float32|backend=cpu") == cands[0]


def test_record_sanitizes_nonfinite_us(tune_cache):
    atn.get_cache().record("k2", atn.TileConfig(Bb=8, Gb=1, Ob=128),
                           float("nan"), 1)
    assert json.load(open(tune_cache))["k2"]["us"] is None


def test_legacy_nan_cache_file_does_not_break_record(tune_cache):
    """A tiles.json written by older code with a bare `us: NaN` entry
    (json.load accepts it) must not crash later record()s under
    allow_nan=False — the legacy timing is rewritten as null."""
    with open(tune_cache, "w") as f:
        json.dump({"legacy": {"tiles": {"Bb": 8, "Gb": 1, "Ob": 128},
                              "us": float("nan"), "candidates": 1}}, f)
    cache = atn.TileCache(tune_cache)
    cache.record("fresh", atn.TileConfig(Bb=8, Gb=2, Ob=128), 4.2, 1)
    raw = open(tune_cache).read()
    assert "NaN" not in raw
    entries = json.loads(raw)
    assert entries["legacy"]["us"] is None and entries["fresh"]["us"] == 4.2
    assert atn.TileCache(tune_cache).lookup("legacy") is not None


def test_candidate_generators_valid():
    for B, G, V, O in [(1, 7, 4, 3), (8, 512, 16, 1024), (128, 24, 256, 384)]:
        cands = atn.gemv_candidates(B, G, V, O)
        assert cands and all(G % c.Gb == 0 for c in cands)
    for Ho, G, V, O in [(5, 9, 16, 12), (28, 100, 16, 350)]:
        cands = atn.conv2d_candidates(Ho, G, V, O)
        assert cands and all(G % c.Gb == 0 and Ho % c.row_tile == 0
                             for c in cands)
    for T, C, V, k in [(16, 6, 256, 4), (1, 192, 256, 4), (130, 129, 16, 2)]:
        cands = atn.dwconv1d_candidates(T, C, V, k)
        assert cands and all(T % c.Bb == 0 and C % c.Ob == 0 for c in cands)


# ----------------------------------------------------------------------------
# Analytic VMEM scratch bound (_fit_scratch_gb): replaces try-compile pruning.
# ----------------------------------------------------------------------------


def _shared_onehot_bytes(cfg, B, V, X, itemsize):
    """Per-grid-step scratch of the shared GEMV at tiling ``cfg``: f32
    one-hot [Bb, Gb, V] + f32 counts [Bb, V, X] + staged [V, X, Ob] pool."""
    return (cfg.Bb * cfg.Gb * V * 4 + cfg.Bb * V * X * 4
            + V * X * cfg.Ob * itemsize)


def test_fit_scratch_gb_basic_properties():
    # divides G, respects the budget, never below 1
    for G, R, V in [(512, 128, 16), (100, 800, 16), (7, 8, 256)]:
        gb = atn._fit_scratch_gb(G, R, V)
        assert G % gb == 0 and gb >= 1
        assert R * gb * V * 4 <= atn.SCRATCH_BUDGET or gb == 1
    # a degenerate budget still yields a dispatchable tile
    assert atn._fit_scratch_gb(64, 10**6, 10**6, budget=1) == 1
    # fixed bytes eat into the budget monotonically
    a = atn._fit_scratch_gb(1 << 16, 128, 16, fixed_bytes=0)
    b = atn._fit_scratch_gb(1 << 16, 128, 16, fixed_bytes=atn.SCRATCH_BUDGET // 2)
    assert b <= a


def test_shared_candidates_all_fit_budget():
    """Every candidate the analytic bound admits must fit the configured
    scratch budget — the acceptance contract that makes try-compile pruning
    unnecessary."""
    B, G, V, O, X = 8, 1 << 14, 256, 1024, 16  # one-hot at Gb=G would be ~16 GB
    itemsize = 4
    cands = atn.shared_gemv_candidates(B, G, V, O, X, itemsize)
    assert cands
    for c in cands:
        assert _shared_onehot_bytes(c, B, V, X, itemsize) <= atn.SCRATCH_BUDGET, c
        assert G % c.Gb == 0


def test_bounded_sweep_strictly_smaller_when_bound_bites():
    """On an oversized problem the bounded generator emits strictly fewer
    candidates than the unbounded (old try-compile) sweep; an infinite
    budget reproduces the old sweep exactly."""
    B, G, V, O, X = 8, 1 << 14, 256, 1024, 16
    old = atn.shared_gemv_candidates(B, G, V, O, X, 4,
                                     scratch_budget=float("inf"))
    new = atn.shared_gemv_candidates(B, G, V, O, X, 4)
    assert len(new) < len(old), (len(new), len(old))
    # same on the conv flavor
    old_c = atn.shared_conv2d_candidates(28, 1 << 14, 256, 1024, X, 4,
                                         scratch_budget=float("inf"))
    new_c = atn.shared_conv2d_candidates(28, 1 << 14, 256, 1024, X, 4)
    assert len(new_c) < len(old_c)


def test_bound_never_prunes_recorded_case_winners():
    """On the recorded CPU-interpret problems (the BENCH shapes — small
    enough that everything fits) the bounded candidate list must contain
    every candidate of the unbounded sweep, so the tile the exhaustive
    sweep would have picked is never pruned."""
    recorded = [
        # (B, G, V, O, X): BENCH_pr2 decode-GEMV and conv5x5 shared shapes
        (8, 512, 16, 1024, 16),
        (8, 100, 16, 1024, 8),
        (1, 8, 16, 48, 5),
    ]
    for B, G, V, O, X in recorded:
        unbounded = atn.shared_gemv_candidates(B, G, V, O, X, 4,
                                               scratch_budget=float("inf"))
        bounded = atn.shared_gemv_candidates(B, G, V, O, X, 4)
        assert bounded == unbounded, (B, G, V, O, X)
    for Ho, G, V, O, X in [(14, 100, 16, 64, 8), (6, 18, 16, 16, 4)]:
        unbounded = atn.shared_conv2d_candidates(Ho, G, V, O, X, 4, Wo=16,
                                                 scratch_budget=float("inf"))
        bounded = atn.shared_conv2d_candidates(Ho, G, V, O, X, 4, Wo=16)
        assert bounded == unbounded, (Ho, G, V, O, X)
    # dense fused generators: same retention contract on recorded shapes
    for B, G, V, O in [(8, 512, 16, 1024), (16, 16, 16, 24)]:
        assert atn.gemv_candidates(B, G, V, O) == atn.gemv_candidates(
            B, G, V, O, scratch_budget=float("inf"))


def test_bounded_tunes_select_no_slower_tiles_on_recorded_cases(tune_cache):
    """End-to-end: tuning with the bounded sweep on a recorded-size problem
    picks a tile that times no slower than the unbounded sweep's winner
    (identical candidate lists => identical winner modulo timing noise; we
    assert the recorded tile is a member of the unbounded sweep)."""
    x, T, spec, s, group = _problem(B=8, n=64, O=256)
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    key = atn.shape_key("fused_gemv", dtype=T.dtype, backend="cpu",
                        B=8, G=T.shape[0], V=T.shape[1], O=256, g=group,
                        bits=spec.bits)
    winner = atn.lookup(key)
    assert winner is not None
    unbounded = atn.gemv_candidates(8, T.shape[0], T.shape[1], 256, 4,
                                    scratch_budget=float("inf"))
    assert winner in unbounded


def _quarantined(path):
    """Timestamp-sorted (oldest first) quarantine files for ``path``."""
    base = os.path.basename(path) + ".corrupt-"
    d = os.path.dirname(path) or "."
    names = [n for n in os.listdir(d) if n.startswith(base)
             and n[len(base):].isdigit()]
    return [os.path.join(d, n)
            for n in sorted(names, key=lambda n: int(n[len(base):]))]


def test_corrupt_cache_warns_quarantines_and_recovers(tune_cache, caplog):
    """A truncated/garbled cache file must never crash or silently reset:
    the load warns (naming the path and the parse error), preserves the
    original bytes at a timestamped ``<path>.corrupt-<ns>``, and the cache
    keeps working."""
    import logging

    from repro.runtime.faults import FaultInjector

    x, T, spec, s, group = _problem()
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    with open(tune_cache, "rb") as f:
        garbled = f.read()[: 10]  # truncated mid-JSON

    FaultInjector().garble_file(tune_cache, "truncate")
    with open(tune_cache, "rb") as f:
        garbled = f.read()
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        cache = atn.reset_cache(tune_cache)
    msgs = [r.getMessage() for r in caplog.records
            if r.name == "repro.autotune"]
    assert any(tune_cache in m and "corrupt" in m for m in msgs), msgs
    # original bytes preserved for post-mortem, live path starts empty
    qfiles = _quarantined(tune_cache)
    assert len(qfiles) == 1, qfiles
    with open(qfiles[0], "rb") as f:
        assert f.read() == garbled
    assert not os.path.exists(tune_cache)

    # the cache still records and persists after recovery
    atn.TIMING_RUNS = 0
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    assert atn.TIMING_RUNS > 0  # entry was lost with the corrupt file
    assert cache.lookup(next(iter(json.load(open(tune_cache))))) is not None


def test_quarantine_distinct_files_and_keeps_newest_three(tune_cache):
    """Repeated corruption must (a) never overwrite an earlier incident's
    post-mortem bytes — every quarantine gets a distinct timestamped name —
    and (b) never grow unbounded: only the newest
    ``QUARANTINE_KEEP`` (3) quarantined copies survive."""
    incidents = []
    for i in range(5):
        payload = b"not json at all #%d" % i
        with open(tune_cache, "wb") as f:
            f.write(payload)
        atn.reset_cache(tune_cache)
        qfiles = _quarantined(tune_cache)
        assert qfiles, f"incident {i} was not quarantined"
        with open(qfiles[-1], "rb") as f:
            assert f.read() == payload  # newest file = this incident's bytes
        incidents.append(qfiles[-1])
        assert not os.path.exists(tune_cache)
    assert len(set(incidents)) == 5  # distinct name per incident
    survivors = _quarantined(tune_cache)
    assert len(survivors) == atn.QUARANTINE_KEEP == 3
    # the survivors are exactly the three newest incidents, oldest pruned
    assert survivors == incidents[-3:]
