"""End-to-end PCILT decode: layer-scanned fused GEMV for the projections.

Covers the PR 5 tentpole:

* full-PCILT ``MambaLM.decode_step`` (conv + every projection a table
  fetch) against the fake-quant dense oracle — the fetch is exact on the
  quantized grid, so a decode step whose projections fake-quantize their
  inputs before the dense matmul must match the stacked-table fetch to
  float tolerance — at batch ∈ {1, 4};
* the ``fused_gemv_stacked`` autotune-key contract: keys carry ``L`` and
  the *local* ``G`` (``G/D`` under a mesh), and a failed tune records
  strict-JSON ``us: null``;
* the typed ``ValueError`` at the ``build_pcilt`` / ``convert_mamba_decode``
  boundary when ``cfg.pcilt`` is unset;
* dispatch-boundary rejections of the ``stacked=`` operand
  (``SegmentPlan``, shared pools, wrong rank).

The multi-shard parity tests (model ∈ {2, 4}) are marked ``slow`` — plain
tier-1 deselects them via the ``-m "not slow"`` default (pytest.ini) so the
suite's wall time stays flat; the CI multi-device job (and a slow-marked
subprocess wrapper for local runs) executes them on 8 forced host devices.
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORCE_FLAG = "--xla_force_host_platform_device_count=8"


def _device_count() -> int:
    import jax

    return jax.device_count()


MULTI = _device_count() >= 8
multi_device = pytest.mark.skipif(
    not MULTI,
    reason="needs 8 forced host devices (re-run via the subprocess wrapper)",
)

RNG = np.random.default_rng(7)
BITS, GROUP = 2, 2


@pytest.fixture
def tune_cache(tmp_path):
    from repro.kernels import autotune as atn

    path = str(tmp_path / "tiles.json")
    atn.reset_cache(path)
    atn.TIMING_RUNS = 0
    yield path
    atn.TIMING_RUNS = 0
    atn.reset_cache()


def _pcilt_cfg():
    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig
    import jax.numpy as jnp

    cfg = get_smoke_config("mamba2-130m")
    # f32 compute: the oracle compares a dense matmul against the table
    # fetch, so the only wanted difference is the quantization grid itself.
    return dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=BITS,
                                                      group=GROUP),
                               dtype=jnp.float32)


@pytest.fixture(scope="module")
def decode_problem(tmp_path_factory):
    """One converted smoke MambaLM shared by the parity tests (the table
    build and calibration prefill run once per module)."""
    import jax
    import jax.numpy as jnp
    from repro.core.serving import convert_mamba_decode
    from repro.kernels import autotune as atn
    from repro.models import build_model
    from repro.nn import materialize
    from repro.nn.layers import Ctx

    atn.reset_cache(str(tmp_path_factory.mktemp("tune") / "tiles.json"))
    cfg = _pcilt_cfg()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = materialize(model.param_specs(), key)
    ctx = Ctx()
    calib = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    eng = convert_mamba_decode(model, params, calib)
    yield {"cfg": cfg, "model": model, "params": params, "ctx": ctx,
           "calib": calib, "eng": eng, "key": key}
    atn.reset_cache()


def _prefill(pb, B):
    import jax

    model, params, ctx = pb["model"], pb["params"], pb["ctx"]
    toks = jax.random.randint(pb["key"], (B, 16), 0, pb["cfg"].vocab)
    _, cache = model.prefill(params, {"tokens": toks}, ctx)
    tok = jax.random.randint(jax.random.fold_in(pb["key"], 1), (B, 1), 0,
                             pb["cfg"].vocab)
    return cache, tok


# ----------------------------------------------------------------------------
# Full-PCILT decode vs the fake-quant dense oracle (model=1)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [1, 4])
def test_full_pcilt_decode_matches_fakequant_oracle(decode_problem, batch):
    """Every projection a stacked-table fetch == every projection a dense
    matmul on fake-quantized inputs (exactness on the quantized grid,
    composed through the whole decode step), plus identical cache motion."""
    import jax
    import jax.numpy as jnp

    pb = decode_problem
    model, params, ctx, eng = pb["model"], pb["params"], pb["ctx"], pb["eng"]
    cache, tok = _prefill(pb, batch)
    logits, nc = eng.step(params, cache, tok)
    oracle_pc = dict(eng.pcilt, proj=dict(eng.pcilt["proj"],
                                          path="dense_fq"))
    l_oracle, nc_o = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx, pcilt=oracle_pc)
    )(params, cache, tok)
    assert logits.shape == (batch, pb["cfg"].padded_vocab)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(l_oracle),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(nc["layers"]["ssd"]),
                               np.asarray(nc_o["layers"]["ssd"]),
                               rtol=2e-4, atol=2e-4)
    assert int(nc["pos"]) == int(nc_o["pos"])


def test_hostpacked_proj_path_matches_fused(decode_problem):
    """The host-packed projection baseline (per-layer table-slice copy +
    offset packing in HBM) computes the same decode step as the stacked
    fused kernel — it is the *same arithmetic*, only slower."""
    import jax

    pb = decode_problem
    model, params, ctx, eng = pb["model"], pb["params"], pb["ctx"], pb["eng"]
    cache, tok = _prefill(pb, 2)
    logits, _ = eng.step(params, cache, tok)
    host_pc = dict(eng.pcilt, proj=dict(eng.pcilt["proj"], path="kernel"))
    l_host, _ = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx, pcilt=host_pc)
    )(params, cache, tok)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(l_host),
                               rtol=1e-4, atol=1e-4)


def test_convert_covers_all_projections(decode_problem):
    from repro.nn.ssm import PROJ_NAMES

    proj = decode_problem["eng"].pcilt["proj"]
    assert set(proj["tables"]) == set(PROJ_NAMES)
    L = decode_problem["cfg"].n_layers
    for name in PROJ_NAMES:
        t = proj["tables"][name]
        assert t.ndim == 4 and t.shape[0] == L
        assert t.shape[2] == (1 << (BITS * GROUP))
        assert proj["scales"][name].shape == (L,)
    assert decode_problem["eng"].table_bytes() > 0


# ----------------------------------------------------------------------------
# fused_gemv_stacked autotune-key contract
# ----------------------------------------------------------------------------


def _stacked_problem(L=3, n=32, O=24, B=4):
    import jax.numpy as jnp
    from repro.core import QuantSpec, build_grouped_tables

    spec = QuantSpec(BITS, symmetric=True)
    x = jnp.asarray(RNG.normal(size=(B, n)), jnp.float32)
    scales = jnp.asarray(0.1 + 0.05 * np.arange(L), jnp.float32)
    tabs = jnp.stack([
        build_grouped_tables(
            jnp.asarray(RNG.normal(size=(n, O)), jnp.float32),
            spec, scales[l], GROUP)
        for l in range(L)])
    return x, tabs, scales, spec


def test_stacked_matches_unstacked_per_layer(tune_cache):
    from repro.kernels import ops

    x, tabs, scales, spec = _stacked_problem()
    for l in range(tabs.shape[0]):
        want = ops.pcilt_fused_gemv(x, tabs[l], spec, scales[l], GROUP)
        got = ops.pcilt_fused_gemv_stacked(x, tabs, l, spec, scales[l],
                                           GROUP)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_stacked_keys_carry_L_and_local_G(tune_cache):
    """Keys carry the stack depth L and the *local* segment count — tuning
    one device's ``[L, G/D, V, O]`` shard records under G/D, so caches
    tuned at different device counts never collide."""
    from repro.kernels import autotune as atn
    from repro.kernels import ops

    x, tabs, scales, spec = _stacked_problem(L=3, n=32)
    G = tabs.shape[1]
    ops.pcilt_fused_gemv_stacked(x, tabs, 0, spec, scales[0], GROUP,
                                 autotune=True)
    # the local-shard tune a 2-way mesh would dispatch (G/D segments)
    ops.pcilt_fused_gemv_stacked(x[:, : G // 2 * GROUP], tabs[:, : G // 2],
                                 0, spec, scales[0], GROUP, autotune=True)
    entries = json.load(open(tune_cache))
    keys = sorted(k for k in entries if k.startswith("fused_gemv_stacked|"))
    assert len(keys) == 2
    assert any(f"G={G}," in k and "L=3," in k for k in keys)
    assert any(f"G={G // 2}," in k and "L=3," in k for k in keys)
    # warm-cache contract: the recorded tiles dispatch with zero timing runs
    atn.reset_cache(tune_cache)
    atn.TIMING_RUNS = 0
    ops.pcilt_fused_gemv_stacked(x, tabs, 1, spec, scales[1], GROUP,
                                 autotune=True)
    assert atn.TIMING_RUNS == 0


def test_stacked_failed_tune_records_null(tune_cache, monkeypatch):
    """All candidates failing must still record strict JSON (``us: null``)
    under the stacked key and dispatch via the heuristic fallback."""
    from repro.kernels import autotune as atn
    from repro.kernels import ops

    def boom(fn, reps, warmup):
        raise RuntimeError("no candidate can run")

    monkeypatch.setattr(atn, "_time_one", boom)
    x, tabs, scales, spec = _stacked_problem()
    out = ops.pcilt_fused_gemv_stacked(x, tabs, 2, spec, scales[2], GROUP,
                                       autotune=True)
    assert out.shape == (x.shape[0], tabs.shape[-1])
    raw = open(tune_cache).read()
    assert "NaN" not in raw
    entries = json.loads(raw)
    key = next(k for k in entries if k.startswith("fused_gemv_stacked|"))
    assert entries[key]["us"] is None and entries[key]["candidates"] == 0


def test_stacked_candidates_mirror_dense_sweep():
    """The staged per-layer slice is byte-identical to the unstacked tile,
    so the stacked sweep starts with the dense sweep as a prefix (L never
    enters) — candidate 0 is still the heuristic no-tune fallback.  The
    batch-R extension may append row-split variants after the prefix, and
    those differ from the dense candidates only in (Bb, Gb) — R is a tuned
    axis, not a new staging strategy."""
    from repro.kernels import autotune as atn

    for B, G, V, O in [(1, 32, 16, 128), (8, 512, 16, 1024), (64, 32, 16, 256)]:
        for L in (2, 24):
            dense = atn.gemv_candidates(B, G, V, O)
            stacked = atn.stacked_gemv_candidates(B, L, G, V, O)
            assert stacked[:len(dense)] == dense
            assert stacked[0] == dense[0]  # heuristic fallback unchanged
            extra = stacked[len(dense):]
            dense_obs = {c.Ob for c in dense}
            for c in extra:
                assert c.Bb < dense[0].Bb and c.Bb % 8 == 0
                assert c.Ob in dense_obs


def test_stacked_candidates_sweep_row_tiles_at_large_B():
    """At serving batch sizes the R-aware sweep must offer genuine Bb
    sub-tiles (splitting the batch across grid rows), deduplicated and
    capped."""
    from repro.kernels import autotune as atn

    cands = atn.stacked_gemv_candidates(64, 3, 32, 16, 256)
    bbs = {c.Bb for c in cands}
    assert 64 in bbs  # full-batch tiles still present
    assert any(b < 64 for b in bbs), f"no row sub-tiles in {sorted(bbs)}"
    assert len(cands) == len(set(cands)) <= 8
    # B=1 stays minimal: the padded row tile is already the floor (8), so
    # the R sweep adds nothing
    small = atn.stacked_gemv_candidates(1, 3, 32, 16, 256)
    assert small == atn.gemv_candidates(1, 32, 16, 256)

    paired = atn.paired_stacked_gemv_candidates(64, 2, 8, 256, 128)
    pbbs = {c.Bb for c in paired}
    assert any(b < max(pbbs) for b in pbbs)
    assert len(paired) == len(set(paired)) <= 8


# ----------------------------------------------------------------------------
# Typed boundary errors
# ----------------------------------------------------------------------------


def test_build_pcilt_without_config_raises_actionable_error():
    import jax
    from repro.models import build_model
    from repro.nn import materialize

    cfg = dataclasses.replace(_pcilt_cfg(), pcilt=None)
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match=r"cfg\.pcilt.*PCILTConfig"):
        model.build_pcilt(params, 0.1)


def test_convert_mamba_decode_without_config_raises():
    import jax
    from repro.core.serving import convert_mamba_decode
    from repro.models import build_model
    from repro.nn import materialize

    cfg = dataclasses.replace(_pcilt_cfg(), pcilt=None)
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    with pytest.raises(ValueError, match=r"cfg\.pcilt.*PCILTConfig"):
        convert_mamba_decode(model, params, calib)


def test_build_pcilt_conv_without_config_raises():
    from repro.nn.ssm import build_pcilt_conv

    cfg = dataclasses.replace(_pcilt_cfg(), pcilt=None)
    with pytest.raises(ValueError, match=r"cfg\.pcilt.*PCILTConfig"):
        build_pcilt_conv({}, cfg, 0.1)


def test_stacked_rejects_plan_shared_and_wrong_rank(tune_cache):
    import jax.numpy as jnp
    from repro.core import (QuantSpec, SegmentPlan,
                            build_shared_grouped_tables, pcilt_linear)

    x, tabs, scales, spec = _stacked_problem()
    n = x.shape[-1]
    with pytest.raises(ValueError, match="SegmentPlan"):
        pcilt_linear(x, tabs, spec, scales[0], GROUP,
                     plan=SegmentPlan.contiguous(n, GROUP), stacked=0)
    with pytest.raises(ValueError, match=r"\[L, G, V, O\]"):
        pcilt_linear(x, tabs[0], spec, scales[0], GROUP, stacked=0)
    st = build_shared_grouped_tables(
        jnp.asarray(RNG.normal(size=(n, 8)), jnp.float32), spec, scales[0],
        GROUP)
    with pytest.raises(ValueError, match="shared"):
        pcilt_linear(x, st, spec, scales[0], GROUP, stacked=0, path="shared")


# ----------------------------------------------------------------------------
# Multi-shard parity (slow tier: 8 forced host devices)
# ----------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running with forced devices")
def test_decode_parity_reruns_with_forced_devices(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["REPRO_PCILT_TUNE_CACHE"] = str(tmp_path / "tiles.json")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x",
         os.path.abspath(__file__), "-m", "slow or not slow"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, (
        f"decode parity suite failed under {FORCE_FLAG}:\n"
        f"{r.stdout}\n{r.stderr}")


@pytest.mark.slow
@multi_device
@pytest.mark.parametrize("model_shards", [2, 4])
def test_full_pcilt_decode_sharded_matches_single_device(
        decode_problem, tune_cache, model_shards):
    """Stacked proj tables sharded over the model axis (one psum per step)
    produce the same decode step as the single-device stack — and the
    shard-local tunes record under the local ``G/D`` key."""
    import jax
    import jax.numpy as jnp
    from repro.core.serving import convert_mamba_decode
    from repro.launch.mesh import make_decode_mesh

    pb = decode_problem
    model, params = pb["model"], pb["params"]
    cache, tok = _prefill(pb, 1)
    l_ref, nc_ref = pb["eng"].step(params, cache, tok)

    mesh = make_decode_mesh(model_shards)
    eng_m = convert_mamba_decode(model, params, pb["calib"], mesh=mesh)
    eng_m.tune(batch=1)
    proj = eng_m.pcilt["proj"]
    G = proj["tables"]["wz"].shape[1]
    entries = json.load(open(tune_cache))
    assert any(k.startswith("fused_gemv_stacked|")
               and f"G={G // model_shards}," in k for k in entries), \
        "tune must record the local shard's G"
    l_m, nc_m = eng_m.step(params, cache, tok)
    np.testing.assert_allclose(np.asarray(l_m), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(nc_m["layers"]["ssd"]),
                               np.asarray(nc_ref["layers"]["ssd"]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@multi_device
def test_sharded_stack_falls_back_when_axis_does_not_divide(decode_problem):
    """A mesh axis that does not divide G replicates (divisibility
    fallback) instead of failing — same contract as every other PCILT
    mesh path."""
    import jax
    from repro.core.serving import convert_mamba_decode
    from repro.launch.mesh import make_decode_mesh

    pb = decode_problem
    mesh = make_decode_mesh(3)  # 3 ∤ G for the smoke dims
    eng = convert_mamba_decode(pb["model"], pb["params"], pb["calib"],
                               mesh=mesh)
    cache, tok = _prefill(pb, 1)
    l_ref, _ = pb["eng"].step(pb["params"], cache, tok)
    l_m, _ = eng.step(pb["params"], cache, tok)
    np.testing.assert_allclose(np.asarray(l_m), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
