"""End-to-end system behaviour: the paper CNN with PCILT vs DM, and the
framework's public API surface."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import smoke_config
from repro.nn.module import materialize


def test_paper_cnn_pcilt_equals_dm():
    """The reproduction target: PCILT inference == DM inference on the
    quantized grid, across all fetch paths."""
    model = smoke_config()
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 12, 12, 1)) * 2
    from repro.core import calibrate
    scales = {}
    h = x
    for i in range(len(model.channels)):
        scales[f"conv{i}"] = calibrate(h, model.act_spec)
        h = jax.nn.relu(jax.lax.conv_general_dilated(
            h, params[f"conv{i}"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
    dm = model.forward(params, x, mode="dm", scales=scales)
    tables = model.build_tables(params, scales)
    for path in ("gather", "onehot"):
        got = model.forward(params, x, mode=path, scales=scales, tables=tables)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dm),
                                   rtol=1e-3, atol=1e-3)


def test_public_api_imports():
    import repro.core as core
    import repro.kernels.ops as ops
    from repro.configs import ARCHS, get_config
    from repro.models import build_model
    from repro.launch.steps import make_train_step, make_decode_step
    assert len(ARCHS) == 10
    for name in ("QuantSpec", "build_grouped_tables", "pcilt_linear",
                 "pcilt_conv2d", "SegmentPlan", "build_shared_tables"):
        assert hasattr(core, name)
