"""Distributed semantics on 8 fake CPU devices (subprocess per test, since
device count locks at first jax init).

Covers: shard_map MoE distributed == single-device routing, compressed int8
gradient pmean accuracy + HLO byte reduction, elastic checkpoint re-mesh
(save on (4,2), restore on (2,4) and (8,1)), and the sharded train step
agreeing with the unsharded one.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_distributed_matches_local():
    run8("""
    import dataclasses
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.nn.moe import moe_spec, moe_apply
    from repro.nn.module import materialize, shardings
    from repro.nn.layers import Ctx
    from repro.nn.module import ShardingRules

    cfg = get_smoke_config("granite-moe-3b-a800m")
    # drop-free capacity: local (32 tokens) vs distributed (4 tokens/shard)
    # otherwise disagree on which over-capacity tokens drop
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    spec = moe_spec(cfg)
    params = materialize(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)

    y_local, aux_local = moe_apply(params, cfg, Ctx(), x)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = Ctx(mesh=mesh, rules=ShardingRules.for_mesh(mesh))
    sh = shardings(spec, mesh)
    params_d = jax.tree.map(jax.device_put, params, sh)
    y_dist, aux_dist = jax.jit(lambda p, x: moe_apply(p, cfg, ctx, x))(params_d, x)
    np.testing.assert_allclose(np.asarray(y_local, np.float32),
                               np.asarray(y_dist, np.float32),
                               rtol=3e-2, atol=3e-2)
    assert np.isfinite(float(aux_dist["load_balance"]))
    assert np.isfinite(float(aux_dist["router_z"]))
    print("moe distributed ok")
    """)


def test_compressed_pmean_int8_and_bf16():
    run8("""
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.optim import compressed_pmean
    from repro.compat import shard_map

    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

    for scheme, tol in (("int8", 3e-2), ("bf16", 1e-2), ("none", 1e-6)):
        def body(xl):
            r, resid = compressed_pmean(xl[0], "data", scheme)
            return r
        got = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                                    out_specs=P(), check_vma=False))(x)
        want = x.mean(0)
        err = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
        assert err < tol, (scheme, err)

    # HLO wire bytes: int8 scheme moves ~4x fewer bytes than fp32 pmean
    from repro.launch.hlo_analysis import analyze_hlo
    def red8(xl):
        return compressed_pmean(xl[0], "data", "int8")[0]
    def red32(xl):
        return compressed_pmean(xl[0], "data", "none")[0]
    c8 = jax.jit(shard_map(red8, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)).lower(x).compile()
    c32 = jax.jit(shard_map(red32, mesh=mesh, in_specs=P("data"), out_specs=P(), check_vma=False)).lower(x).compile()
    b8 = analyze_hlo(c8.as_text())["collective_bytes"]
    b32 = analyze_hlo(c32.as_text())["collective_bytes"]
    assert b8 < 0.75 * b32, (b8, b32)
    print("compressed pmean ok", b8, b32)
    """)


def test_elastic_checkpoint_remesh():
    run8("""
    import os, tempfile
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save, restore

    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.arange(8.0)}
    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    sh1 = {"w": NamedSharding(mesh1, P("data", "model")),
           "b": NamedSharding(mesh1, P("model"))}
    t1 = jax.tree.map(jax.device_put, tree, sh1)

    d = tempfile.mkdtemp()
    save(d, 1, t1)

    # restore onto a different mesh topology
    for shape, axes in (((2, 4), ("data", "model")), ((8, 1), ("data", "model"))):
        mesh2 = jax.make_mesh(shape, axes)
        sh2 = {"w": NamedSharding(mesh2, P("data", "model")),
               "b": NamedSharding(mesh2, P("model") if shape[1] > 1 else P())}
        got, _ = restore(d, 1, tree, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        np.testing.assert_array_equal(np.asarray(got["b"]), np.asarray(tree["b"]))
    print("elastic remesh ok")
    """)


def test_sharded_train_step_matches_single_device():
    run8("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.nn.module import materialize, shardings, ShardingRules
    from repro.nn.layers import Ctx
    from repro.optim import AdamWConfig, adamw_init
    from repro.launch.steps import make_train_step

    cfg = get_smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    specs = model.param_specs()
    params = materialize(specs, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab),
    }

    p1, o1, m1 = jax.jit(make_train_step(cfg, None, ocfg))(params, opt, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sh = shardings(specs, mesh)
    params_d = jax.tree.map(jax.device_put, params, sh)
    opt_d = adamw_init(params_d, ocfg)
    p2, o2, m2 = jax.jit(make_train_step(cfg, mesh, ocfg))(params_d, opt_d, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, (m1["loss"], m2["loss"])
    # spot-check a parameter leaf trains to the same place
    a = np.asarray(jax.tree.leaves(p1)[0], np.float32)
    b = np.asarray(jax.tree.leaves(p2)[0], np.float32)
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-3)
    print("sharded train step ok", float(m1["loss"]), float(m2["loss"]))
    """)


def test_production_mesh_shapes():
    run8("""
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.shape == (2, 16, 16)
    assert m2.axis_names == ("pod", "data", "model")
    print("mesh ok")
    """, devices=512)


def test_rowrs_explicit_reduce_scatter_matches_base():
    run8("""
    import dataclasses
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.nn.module import materialize, shardings
    from repro.optim import AdamWConfig, adamw_init
    from repro.launch.steps import make_train_step

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), n_layers=2)
    model = build_model(cfg)
    specs = model.param_specs()
    params = materialize(specs, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab)}
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params_d = jax.tree.map(jax.device_put, params, shardings(specs, mesh))
    p1, o1, m1 = jax.jit(make_train_step(cfg, mesh, ocfg))(
        params_d, adamw_init(params_d, ocfg), batch)
    p2, o2, m2 = jax.jit(make_train_step(cfg, mesh, ocfg, explicit_rs=True))(
        params_d, adamw_init(params_d, ocfg), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
    a = np.asarray(jax.tree.leaves(p1)[0], np.float32)
    b = np.asarray(jax.tree.leaves(p2)[0], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)
    print("rowrs == base ok")
    """)


def test_kvshard_decode_matches_base():
    run8("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.nn.module import materialize, shardings, shape_structs
    from repro.launch.steps import make_decode_step
    from repro.launch.specs import data_spec

    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    specs = model.param_specs()
    params = materialize(specs, jax.random.PRNGKey(0))
    B, T = 4, 32
    cache = materialize(model.cache_specs(B, T), jax.random.PRNGKey(1))
    cache = dict(cache, pos=jnp.asarray(T - 1, jnp.int32))
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params_d = jax.tree.map(jax.device_put, params, shardings(specs, mesh))
    l1, _ = jax.jit(make_decode_step(cfg, mesh))(params_d, cache, tok)
    l2, _ = jax.jit(make_decode_step(
        cfg, mesh, rule_overrides={"cache_seq": "model"}))(params_d, cache, tok)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=3e-2, atol=3e-2)
    print("kvshard decode == base ok")
    """)


def test_pipeline_parallel_matches_sequential():
    run8("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.runtime.pipeline import pipeline_apply

    S, M, B, D = 4, 6, 2, 8
    mesh = jax.make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, B, D))

    def stage(w, a):
        return jnp.tanh(a @ w)

    got = pipeline_apply(stage, ws, x, mesh)
    want = x
    for s in range(S):
        want = jnp.tanh(want @ ws[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    g = jax.grad(lambda ws: jnp.sum(pipeline_apply(stage, ws, x, mesh) ** 2))(ws)
    def loss_seq(ws):
        h = x
        for s in range(S):
            h = jnp.tanh(h @ ws[s])
        return jnp.sum(h ** 2)
    g2 = jax.grad(loss_seq)(ws)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g2),
                               rtol=5e-4, atol=5e-5)
    print("pipeline fwd+grad ok")
    """)
