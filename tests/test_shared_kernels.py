"""Shared-pool (extension 3) fused path: the segment-dedup builder, the
pointer-resolving Pallas kernels, and their dispatch through the autotune
lookup table.  ``path="shared"`` must be bit-consistent with the
``SharedGroupedTables`` pointer-gather reference (f32 accumulation
tolerance) across symmetric/asymmetric specs at 2–4 bits."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec, calibrate, build_grouped_tables, build_shared_grouped_tables,
    pcilt_linear, shared_pool_bytes,
)
from repro.core.lut_layers import pcilt_conv2d
from repro.kernels import autotune as atn
from repro.kernels import ops

RNG = np.random.default_rng(3)


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path):
    atn.reset_cache(str(tmp_path / "tiles.json"))
    atn.TIMING_RUNS = 0
    yield
    atn.TIMING_RUNS = 0
    atn.reset_cache()


def _codebook_weights(n, O, group, X):
    """[n, O] weights whose [group, O] segments are drawn from an X-entry
    codebook — the weight-clustered / low-cardinality regime ext. 3 targets."""
    G = -(-n // group)
    cb = RNG.normal(size=(X, group, O))
    w = cb[RNG.integers(0, X, G)].reshape(G * group, O)[:n]
    return jnp.asarray(w, jnp.float32)


# ----------------------------------------------------------------------------
# Builder
# ----------------------------------------------------------------------------


def test_builder_dedups_and_materializes_exactly():
    spec = QuantSpec(2)
    w = _codebook_weights(24, 10, group=2, X=4)
    st = build_shared_grouped_tables(w, spec, 0.5, group=2)
    assert st.pool_cardinality <= 4 and st.n_segments == 12
    T = build_grouped_tables(w, spec, 0.5, group=2)
    np.testing.assert_array_equal(np.asarray(st.materialize()), np.asarray(T))


def test_builder_generic_fn_matches_grouped():
    from repro.core import log_mul_fn

    spec = QuantSpec(2)
    w = _codebook_weights(8, 5, group=2, X=2)
    st = build_shared_grouped_tables(w, spec, 0.7, group=2, fn=log_mul_fn)
    T = build_grouped_tables(w, spec, 0.7, group=2, fn=log_mul_fn)
    np.testing.assert_allclose(np.asarray(st.materialize()), np.asarray(T),
                               rtol=1e-6, atol=1e-6)


def test_pool_memory_accounting():
    spec = QuantSpec(2)
    group, O = 2, 16
    w = _codebook_weights(64, O, group=group, X=3)
    st = build_shared_grouped_tables(w, spec, 0.5, group=group)
    X, G = st.pool_cardinality, st.n_segments
    want = shared_pool_bytes(X, spec.bits, group, O, 4, n_segments=G)
    assert st.pool_bytes() == want
    assert st.dense_bytes() == G * (1 << (spec.bits * group)) * O * 4
    assert st.dedup_ratio > 5  # G=32 vs X<=3: order-of-magnitude shrink


# ----------------------------------------------------------------------------
# GEMV parity: path="shared" vs the pointer-gather reference
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("bits,symmetric", [
    (2, False), (2, True), (3, False), (3, True), (4, False), (4, True),
])
def test_shared_gemv_parity_specs(bits, symmetric):
    spec = QuantSpec(bits, symmetric=symmetric)
    B, n, O, group = 8, 24, 40, 2
    lo = -2.0 if symmetric else 0.0
    x = jnp.asarray(RNG.uniform(lo, 3, (B, n)), jnp.float32)
    w = _codebook_weights(n, O, group, X=5)
    s = calibrate(x, spec)
    st = build_shared_grouped_tables(w, spec, s, group)
    want = pcilt_linear(x, st, spec, s, group, path="gather")
    got = pcilt_linear(x, st, spec, s, group, path="shared")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,n,O,group,X", [
    (7, 30, 130, 2, 3),    # odd B, non-128-multiple O
    (3, 36, 257, 3, 4),    # G=12 with non-trivial splits
    (1, 16, 5, 1, 2),      # decode-style B=1, group=1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shared_gemv_parity_shapes(B, n, O, group, X, dtype):
    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = _codebook_weights(n, O, group, X)
    s = calibrate(x, spec)
    st = build_shared_grouped_tables(w, spec, s, group)
    want = pcilt_linear(x, st, spec, s, group, path="gather")
    st.pool = st.pool.astype(dtype)
    got = pcilt_linear(x, st, spec, s, group, path="shared")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_shared_matches_dense_fused():
    """The two fused pipelines agree: the pool resolves to the same tables."""
    spec = QuantSpec(2)
    B, n, O, group = 8, 32, 48, 2
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = _codebook_weights(n, O, group, X=4)
    s = calibrate(x, spec)
    st = build_shared_grouped_tables(w, spec, s, group)
    dense = pcilt_linear(x, st.materialize(), spec, s, group, path="fused")
    got = pcilt_linear(x, st, spec, s, group, path="shared")
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_shared_path_requires_pool_and_rejects_plans():
    from repro.core import SegmentPlan

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (4, 8)), jnp.float32)
    w = _codebook_weights(8, 6, 2, X=2)
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, 2)
    with pytest.raises(ValueError, match="shared"):
        pcilt_linear(x, T, spec, s, 2, path="shared")
    st = build_shared_grouped_tables(w, spec, s, 2)
    with pytest.raises(ValueError, match="fused"):
        pcilt_linear(x, st, spec, s, 2, path="fused")
    with pytest.raises(ValueError, match="contiguous"):
        pcilt_linear(x, st, spec, s, 2, plan=SegmentPlan.contiguous(8, 2),
                     path="shared")


# ----------------------------------------------------------------------------
# Conv parity
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,W,C,kh,kw,stride,O,bits,group,padding", [
    (2, 8, 8, 3, 3, 3, 1, 5, 2, 2, "SAME"),     # ragged n=27 -> pad_n
    (1, 9, 7, 4, 3, 3, 2, 12, 2, 2, "SAME"),    # strided, odd spatial
    (1, 8, 8, 2, 3, 3, 2, 6, 2, 2, "SAME"),     # strided, even spatial
    (2, 8, 8, 2, 5, 5, 1, 6, 4, 2, "VALID"),    # 5x5 paper filter, 4-bit
    (1, 6, 6, 4, 3, 3, 1, 130, 3, 3, "SAME"),   # non-128-multiple O
])
def test_shared_conv2d_parity(B, H, W, C, kh, kw, stride, O, bits, group,
                              padding):
    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 2, (B, H, W, C)), jnp.float32)
    n = kh * kw * C
    w = _codebook_weights(n + (-n) % group, O, group, X=4)
    f = jnp.asarray(np.asarray(w)[:n].reshape(kh, kw, C, O), jnp.float32)
    s = calibrate(x, spec)
    want = pcilt_conv2d(x, f, spec, s, group, stride=stride, padding=padding,
                        path="gather")
    got = pcilt_conv2d(x, f, spec, s, group, stride=stride, padding=padding,
                       path="shared")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_shared_conv2d_prebuilt_pool_bf16():
    from repro.core.pcilt import build_shared_grouped_tables as build

    spec = QuantSpec(2)
    B, H, W, C, kh, kw, O, group = 2, 8, 8, 2, 3, 3, 6, 2
    x = jnp.asarray(RNG.uniform(0, 2, (B, H, W, C)), jnp.float32)
    n = kh * kw * C
    w = _codebook_weights(n, O, group, X=3)
    f = jnp.asarray(np.asarray(w).reshape(kh, kw, C, O), jnp.float32)
    s = calibrate(x, spec)
    st = build(jnp.asarray(w), spec, s, group)
    want = pcilt_conv2d(x, f, spec, s, group, path="gather")
    st.pool = st.pool.astype(jnp.bfloat16)
    got = pcilt_conv2d(x, f, spec, s, group, tables=st, path="shared")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)


# ----------------------------------------------------------------------------
# Dispatch: autotune lookup table with the X-carrying shape keys
# ----------------------------------------------------------------------------


def test_shared_dispatch_tunes_once_with_x_key(tmp_path):
    path = str(tmp_path / "tiles.json")
    atn.reset_cache(path)
    spec = QuantSpec(2)
    B, n, O, group = 8, 24, 32, 2
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = _codebook_weights(n, O, group, X=3)
    s = calibrate(x, spec)
    st = build_shared_grouped_tables(w, spec, s, group)
    out1 = ops.pcilt_shared_gemv(x, st.pool, st.seg_idx, spec, s, group,
                                 autotune=True)
    assert atn.TIMING_RUNS > 0
    entries = json.load(open(path))
    key = next(iter(entries))
    assert key.startswith("shared_gemv") and f"X={st.pool_cardinality}" in key

    # "Second process": warm cache, zero timing runs, same result.
    atn.reset_cache(path)
    atn.TIMING_RUNS = 0
    out2 = ops.pcilt_shared_gemv(x, st.pool, st.seg_idx, spec, s, group,
                                 autotune=True)
    assert atn.TIMING_RUNS == 0
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_shared_candidate_generators_valid():
    for B, G, V, O, X in [(1, 7, 4, 3, 2), (8, 512, 16, 1024, 16),
                          (128, 24, 256, 384, 5)]:
        cands = atn.shared_gemv_candidates(B, G, V, O, X)
        assert cands and all(G % c.Gb == 0 for c in cands)
        assert any(c.Gb == G for c in cands)  # stage-everything always present
    for Ho, G, V, O, X in [(5, 9, 16, 12, 3), (28, 100, 16, 350, 7)]:
        cands = atn.shared_conv2d_candidates(Ho, G, V, O, X)
        assert cands and all(G % c.Gb == 0 and Ho % c.row_tile == 0
                             for c in cands)
        assert any(c.Gb == G for c in cands)


# ----------------------------------------------------------------------------
# Serving conversion
# ----------------------------------------------------------------------------


def test_convert_kernel_shared_roundtrip():
    from repro.core.serving import convert_kernel

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 1, (4, 24)), jnp.float32)
    k = jnp.asarray(np.asarray(_codebook_weights(24, 32, 2, X=4)), jnp.float32)
    s = calibrate(x, spec)
    lin = convert_kernel(k, spec, s, group=2, shared=True)
    assert lin.tables is None and lin.shared is not None
    want = lin(x, path="gather")
    got = lin(x, path="shared")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the deployed representation is the pool, not the dense tables
    dense = lin.shared.dense_bytes()
    assert lin.table_bytes() < dense
    with pytest.raises(ValueError, match="shared"):
        lin(x, path="fused")


def test_convert_kernel_weight_bits_enables_dedup():
    """Low-bit weight quantization lowers segment cardinality — the ext.-3
    precondition — and the shared layer still matches the dense reference."""
    from repro.core.serving import convert_kernel

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 1, (4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(32, 1)), jnp.float32)
    s = calibrate(x, spec)
    lin = convert_kernel(k, spec, s, group=2, weight_bits=2, shared=True)
    # group*out = 2 values from a 4-level grid -> <= 16 distinct segments
    # against G = 16; random draws collide, so the pool strictly shrinks.
    assert lin.shared.pool_cardinality < lin.shared.n_segments
    ref = convert_kernel(k, spec, s, group=2, weight_bits=2)
    np.testing.assert_allclose(
        np.asarray(lin(x, path="shared")),
        np.asarray(ref(x, path="gather")), rtol=1e-4, atol=1e-4)


def test_serving_tune_shared_populates_cache(tmp_path):
    from repro.core.serving import convert_kernel

    atn.reset_cache(str(tmp_path / "tiles.json"))
    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 1, (4, 24)), jnp.float32)
    k = jnp.asarray(np.asarray(_codebook_weights(24, 32, 2, X=4)), jnp.float32)
    s = calibrate(x, spec)
    lin = convert_kernel(k, spec, s, group=2, shared=True)
    want = lin(x, path="gather")
    got = lin.tune(x)
    assert atn.TIMING_RUNS > 0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    atn.TIMING_RUNS = 0
    np.testing.assert_allclose(np.asarray(lin(x, path="shared")),
                               np.asarray(want), rtol=1e-4, atol=1e-4)
    assert atn.TIMING_RUNS == 0
