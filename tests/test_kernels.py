"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the exact kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import autotune as atn

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path):
    """Kernel dispatch consults the persistent autotune cache; isolate it so
    results don't depend on whatever this machine tuned before."""
    atn.reset_cache(str(tmp_path / "tiles.json"))
    yield
    atn.reset_cache()


def _mk(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("B,G,V,O", [
    (4, 8, 16, 32), (128, 16, 256, 128), (3, 5, 4, 7),
    (256, 32, 16, 384), (1, 1, 2, 1), (17, 3, 64, 130),
])
def test_pcilt_gemv_shapes(B, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcilt_gemv_dtypes(dtype):
    off = jnp.asarray(RNG.integers(0, 16, (32, 8)), jnp.int32)
    tab = _mk((8, 16, 64), dtype)
    got = ops.pcilt_gemv(off, tab)
    want = ref.pcilt_gemv_ref(off, tab)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,W,G,V,O", [
    (2, 8, 8, 9, 16, 8), (1, 16, 12, 4, 64, 32), (3, 5, 7, 2, 8, 3),
    # non-128-multiple O exercises the lane padding; odd W the sublane padding
    (1, 4, 4, 3, 8, 130), (2, 6, 9, 2, 16, 5),
])
def test_pcilt_conv2d_shapes(B, H, W, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, H, W, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_conv2d(off, tab), ref.pcilt_conv2d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcilt_conv2d_dtypes(dtype):
    off = jnp.asarray(RNG.integers(0, 16, (2, 6, 6, 4)), jnp.int32)
    tab = _mk((4, 16, 24), dtype)
    got = ops.pcilt_conv2d(off, tab)
    want = ref.pcilt_conv2d_ref(off, tab)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,C,V", [
    (2, 16, 6, 16), (1, 64, 192, 256), (3, 7, 5, 4), (2, 130, 129, 16),
])
def test_pcilt_dwconv1d_shapes(B, T, C, V):
    off = jnp.asarray(RNG.integers(0, V, (B, T, C)), jnp.int32)
    tab = _mk((C, V))
    np.testing.assert_allclose(
        ops.pcilt_dwconv1d(off, tab), ref.pcilt_dwconv1d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_gemv_vmem_tiling_path():
    """Big-enough O/G to exercise multi-tile grids and accumulation."""
    B, G, V, O = 64, 24, 32, 512
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_end_to_end_linear_kernel_path():
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (16, 32)), jnp.float32)
    w = _mk((32, 24))
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group=4)
    a = pcilt_linear(x, T, spec, s, group=4, path="kernel")
    b = pcilt_linear(x, T, spec, s, group=4, path="gather")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# Fused-pipeline parity: path="fused" (and the host-packed path="kernel") must
# agree with the literal path="gather" semantics across ragged shapes — odd B,
# non-multiple O, G not divisible by the staged Gb — and both table dtypes.
# (f32 agrees to reassociation-of-summation tolerance; bf16 to bf16 precision.)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("B,n,O,bits,group", [
    (16, 32, 24, 2, 4),     # baseline
    (7, 30, 130, 2, 2),     # odd B, non-128-multiple O
    (3, 36, 257, 2, 3),     # G=12 not divisible by typical Gb splits
    (1, 16, 5, 4, 1),       # decode-style B=1, tiny O, 4-bit codes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_gemv_parity(B, n, O, bits, group, dtype):
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear

    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = _mk((n, O))
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group=group).astype(dtype)
    want = pcilt_linear(x, T, spec, s, group=group, path="gather")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    for path in ("fused", "kernel"):
        got = pcilt_linear(x, T, spec, s, group=group, path=path)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("B,H,W,C,kh,kw,stride,O,bits,group,padding", [
    (2, 8, 8, 3, 3, 3, 1, 5, 2, 2, "SAME"),     # ragged n=27 -> pad_n
    (1, 9, 7, 4, 3, 3, 2, 12, 2, 2, "SAME"),    # strided, odd spatial
    (2, 8, 8, 2, 5, 5, 1, 6, 2, 4, "VALID"),    # 5x5 paper filter
    (1, 6, 6, 4, 3, 3, 1, 130, 2, 3, "SAME"),   # non-128-multiple O
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_conv2d_parity(B, H, W, C, kh, kw, stride, O, bits, group,
                             padding, dtype):
    from repro.core import QuantSpec, calibrate, build_grouped_tables
    from repro.core.lut_layers import pcilt_conv2d

    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 2, (B, H, W, C)), jnp.float32)
    f = _mk((kh, kw, C, O))
    s = calibrate(x, spec)
    n = kh * kw * C
    wflat = f.reshape(n, O)
    pad_n = (-n) % group
    if pad_n:
        wflat = jnp.concatenate([wflat, jnp.zeros((pad_n, O))], 0)
    T = build_grouped_tables(wflat, spec, s, group).astype(dtype)
    want = pcilt_conv2d(x, f, spec, s, group, stride=stride, padding=padding,
                        path="gather")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    for path in ("fused", "kernel"):
        got = pcilt_conv2d(x, f, spec, s, group, stride=stride,
                           padding=padding, tables=T, path=path)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol)


# ----------------------------------------------------------------------------
# Fused depthwise-conv1d parity: quantize + tap-stack + pack + factored
# two-level one-hot fetch in VMEM must match the host-packed reference on
# every padding mode, ragged lengths, and both table dtypes.
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,C,k,bits", [
    (2, 16, 6, 4, 2),      # the Mamba frontend shape class (k=4)
    (1, 33, 129, 3, 2),    # ragged T, non-128-multiple C (lane padding)
    (2, 7, 5, 2, 4),       # tiny ragged T, 4-bit codes
    (3, 130, 64, 4, 1),    # BoolHash bits=1, T not a tile multiple
])
@pytest.mark.parametrize("padding", ["CAUSAL", "SAME", "VALID"])
def test_fused_dwconv1d_parity(B, T, C, k, bits, padding):
    from repro.core import QuantSpec, calibrate
    from repro.core.lut_layers import pcilt_depthwise_conv1d

    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 3, (B, T, C)), jnp.float32)
    f = _mk((k, C))
    s = calibrate(x, spec)
    want = pcilt_depthwise_conv1d(x, f, spec, s, path="gather",
                                  padding=padding)
    for path in ("fused", "kernel", "onehot"):
        got = pcilt_depthwise_conv1d(x, f, spec, s, path=path,
                                     padding=padding)
        assert got.shape == want.shape
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
            err_msg=f"path={path} padding={padding}")


@pytest.mark.parametrize("padding", ["CAUSAL", "VALID"])
def test_fused_dwconv1d_bf16_tables_exact(padding):
    """One fetch per output: the factored one-hot chain has exactly one
    nonzero term, so f32 accumulation must return the bf16 table cell
    bit-exactly (the host-packed kernel's contract)."""
    from repro.core import QuantSpec, calibrate
    from repro.core.lut_layers import (build_dwconv_tables,
                                       pcilt_depthwise_conv1d)

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (2, 32, 6)), jnp.float32)
    f = _mk((4, 6))
    s = calibrate(x, spec)
    tab = build_dwconv_tables(f, spec, s).astype(jnp.bfloat16)
    want = pcilt_depthwise_conv1d(x, f, spec, s, tables=tab, path="gather",
                                  padding=padding)
    got = pcilt_depthwise_conv1d(x, f, spec, s, tables=tab, path="fused",
                                 padding=padding)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_fused_dwconv1d_decode_window():
    """The Mamba decode regime: a pre-assembled [B, k, C] window through
    padding='VALID' yields exactly one output per channel — the fetch the
    serving decode step dispatches."""
    from repro.core import QuantSpec, calibrate
    from repro.core.lut_layers import pcilt_depthwise_conv1d

    spec = QuantSpec(2)
    k, C = 4, 160
    x = jnp.asarray(RNG.uniform(0, 2, (3, k, C)), jnp.float32)
    f = _mk((k, C))
    s = calibrate(x, spec)
    want = pcilt_depthwise_conv1d(x, f, spec, s, path="gather",
                                  padding="VALID")
    got = pcilt_depthwise_conv1d(x, f, spec, s, path="fused",
                                 padding="VALID")
    assert got.shape == (3, 1, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_dwconv1d_rejects_unknown_padding():
    from repro.core import QuantSpec, calibrate
    from repro.core.lut_layers import pcilt_depthwise_conv1d

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 2, (1, 8, 4)), jnp.float32)
    with pytest.raises(ValueError, match="CAUSAL"):
        pcilt_depthwise_conv1d(x, _mk((3, 4)), spec, calibrate(x, spec),
                               path="fused", padding="FULL")


def test_fused_conv2d_seg_offset_shard_slice():
    """The seg_offset kernel contract, without a mesh: fetching each table
    shard at its global segment offset and summing the partials must equal
    the full fused conv — the property the sharded in-VMEM-im2col route is
    built on."""
    from repro.core import QuantSpec, calibrate, build_grouped_tables
    from repro.core.lut_layers import pcilt_conv2d

    spec = QuantSpec(2)
    B, H, W, C, kh, kw, O, group = 1, 6, 6, 4, 3, 3, 8, 2
    x = jnp.asarray(RNG.uniform(0, 2, (B, H, W, C)), jnp.float32)
    f = _mk((kh, kw, C, O))
    s = calibrate(x, spec)
    n = kh * kw * C  # 36 -> G = 18
    T = build_grouped_tables(f.reshape(n, O), spec, s, group)
    G = T.shape[0]
    want = pcilt_conv2d(x, f, spec, s, group, path="fused", tables=T)
    D = 2
    Gl = G // D
    parts = [
        ops.pcilt_fused_conv2d(x, T[d * Gl:(d + 1) * Gl], spec, s, group,
                               kh, kw, seg_offset=d * Gl, n_total=G * group)
        for d in range(D)
    ]
    np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pcilt_dwconv1d_bf16_tables_f32_accumulation():
    """bf16 tables must not round through bf16 on every fori_loop step: the
    kernel accumulates f32 and casts once, so each output equals its bf16
    table cell exactly (one fetch per output element)."""
    off = jnp.asarray(RNG.integers(0, 16, (2, 32, 6)), jnp.int32)
    tab = _mk((6, 16), jnp.bfloat16)
    got = ops.pcilt_dwconv1d(off, tab)
    want = ref.pcilt_dwconv1d_ref(off, tab)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("H,W", [(8, 8), (9, 7), (10, 8)])
def test_strided_same_matches_lax_conv(H, W):
    """Stride-2 "SAME" must sample the exact windows XLA samples (pad_total
    split low-first), on every path — even sizes used to shift by one."""
    from repro.core import QuantSpec, calibrate, quantize, dequantize
    from repro.core.lut_layers import pcilt_conv2d

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 2, (1, H, W, 2)), jnp.float32)
    f = _mk((3, 3, 2, 4))
    s = calibrate(x, spec)
    xq = dequantize(quantize(x, spec, s), spec, s)
    want = jax.lax.conv_general_dilated(
        xq, f, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    for path in ("gather", "kernel", "fused"):
        got = pcilt_conv2d(x, f, spec, s, group=2, stride=2, padding="SAME",
                           path=path)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"path={path} H={H} W={W}")


def test_host_conv2d_clamps_malformed_cache_tiles(tmp_path):
    """A hand-edited / cross-version cache entry with Gb ∤ G (and oversized
    Hb/Ob) must be clamped before reaching the kernel, like the fused path."""
    import json

    off = jnp.asarray(RNG.integers(0, 8, (1, 6, 6, 9)), jnp.int32)  # G=9
    tab = _mk((9, 8, 20))
    key = atn.shape_key("conv2d_host", dtype=tab.dtype,
                        backend=jax.default_backend(),
                        B=1, Ho=6, Wo=6, G=9, V=8, O=20)
    path = str(tmp_path / "tiles.json")
    with open(path, "w") as f:
        json.dump({key: {"tiles": {"Bb": 8, "Gb": 7, "Ob": 999,
                                   "row_tile": 5}, "us": 1.0,
                         "candidates": 1}}, f)
    atn.reset_cache(path)
    got = ops.pcilt_conv2d(off, tab)  # 7 ∤ 9, 5 ∤ 6, Ob > O: must not crash
    np.testing.assert_allclose(got, ref.pcilt_conv2d_ref(off, tab),
                               rtol=1e-5, atol=1e-5)


def test_is_concrete_uses_compat_tracer_probe():
    from repro import compat

    seen = []
    jax.jit(lambda t: seen.append(compat.is_tracer(t)) or t)(jnp.zeros(1))
    assert seen == [True]
    assert not compat.is_tracer(jnp.zeros(1))
    assert not compat.is_tracer(np.zeros(1))


def test_fused_executes_segment_plans():
    """Formerly a hard raise: path='fused' now runs generalized plans via
    the in-VMEM plan gather.  A contiguous plan is the identity mapping, so
    it must match the planless fused dispatch exactly."""
    from repro.core import QuantSpec, SegmentPlan, calibrate, build_grouped_tables
    from repro.core import pcilt_linear

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (4, 8)), jnp.float32)
    w = _mk((8, 16))
    s = calibrate(x, spec)
    plan = SegmentPlan.contiguous(8, 2)
    T = build_grouped_tables(w, spec, s, group=2, plan=plan)
    got = pcilt_linear(x, T, spec, s, group=2, plan=plan, path="fused")
    want = pcilt_linear(x, T, spec, s, group=2, path="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
