"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the exact kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _mk(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("B,G,V,O", [
    (4, 8, 16, 32), (128, 16, 256, 128), (3, 5, 4, 7),
    (256, 32, 16, 384), (1, 1, 2, 1), (17, 3, 64, 130),
])
def test_pcilt_gemv_shapes(B, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcilt_gemv_dtypes(dtype):
    off = jnp.asarray(RNG.integers(0, 16, (32, 8)), jnp.int32)
    tab = _mk((8, 16, 64), dtype)
    got = ops.pcilt_gemv(off, tab)
    want = ref.pcilt_gemv_ref(off, tab)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,W,G,V,O", [
    (2, 8, 8, 9, 16, 8), (1, 16, 12, 4, 64, 32), (3, 5, 7, 2, 8, 3),
])
def test_pcilt_conv2d_shapes(B, H, W, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, H, W, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_conv2d(off, tab), ref.pcilt_conv2d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,T,C,V", [
    (2, 16, 6, 16), (1, 64, 192, 256), (3, 7, 5, 4), (2, 130, 129, 16),
])
def test_pcilt_dwconv1d_shapes(B, T, C, V):
    off = jnp.asarray(RNG.integers(0, V, (B, T, C)), jnp.int32)
    tab = _mk((C, V))
    np.testing.assert_allclose(
        ops.pcilt_dwconv1d(off, tab), ref.pcilt_dwconv1d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_gemv_vmem_tiling_path():
    """Big-enough O/G to exercise multi-tile grids and accumulation."""
    B, G, V, O = 64, 24, 32, 512
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_end_to_end_linear_kernel_path():
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (16, 32)), jnp.float32)
    w = _mk((32, 24))
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group=4)
    a = pcilt_linear(x, T, spec, s, group=4, path="kernel")
    b = pcilt_linear(x, T, spec, s, group=4, path="gather")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
