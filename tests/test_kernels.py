"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the exact kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import autotune as atn

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path):
    """Kernel dispatch consults the persistent autotune cache; isolate it so
    results don't depend on whatever this machine tuned before."""
    atn.reset_cache(str(tmp_path / "tiles.json"))
    yield
    atn.reset_cache()


def _mk(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("B,G,V,O", [
    (4, 8, 16, 32), (128, 16, 256, 128), (3, 5, 4, 7),
    (256, 32, 16, 384), (1, 1, 2, 1), (17, 3, 64, 130),
])
def test_pcilt_gemv_shapes(B, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcilt_gemv_dtypes(dtype):
    off = jnp.asarray(RNG.integers(0, 16, (32, 8)), jnp.int32)
    tab = _mk((8, 16, 64), dtype)
    got = ops.pcilt_gemv(off, tab)
    want = ref.pcilt_gemv_ref(off, tab)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,W,G,V,O", [
    (2, 8, 8, 9, 16, 8), (1, 16, 12, 4, 64, 32), (3, 5, 7, 2, 8, 3),
    # non-128-multiple O exercises the lane padding; odd W the sublane padding
    (1, 4, 4, 3, 8, 130), (2, 6, 9, 2, 16, 5),
])
def test_pcilt_conv2d_shapes(B, H, W, G, V, O):
    off = jnp.asarray(RNG.integers(0, V, (B, H, W, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_conv2d(off, tab), ref.pcilt_conv2d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcilt_conv2d_dtypes(dtype):
    off = jnp.asarray(RNG.integers(0, 16, (2, 6, 6, 4)), jnp.int32)
    tab = _mk((4, 16, 24), dtype)
    got = ops.pcilt_conv2d(off, tab)
    want = ref.pcilt_conv2d_ref(off, tab)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,C,V", [
    (2, 16, 6, 16), (1, 64, 192, 256), (3, 7, 5, 4), (2, 130, 129, 16),
])
def test_pcilt_dwconv1d_shapes(B, T, C, V):
    off = jnp.asarray(RNG.integers(0, V, (B, T, C)), jnp.int32)
    tab = _mk((C, V))
    np.testing.assert_allclose(
        ops.pcilt_dwconv1d(off, tab), ref.pcilt_dwconv1d_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_gemv_vmem_tiling_path():
    """Big-enough O/G to exercise multi-tile grids and accumulation."""
    B, G, V, O = 64, 24, 32, 512
    off = jnp.asarray(RNG.integers(0, V, (B, G)), jnp.int32)
    tab = _mk((G, V, O))
    np.testing.assert_allclose(
        ops.pcilt_gemv(off, tab), ref.pcilt_gemv_ref(off, tab),
        rtol=1e-5, atol=1e-5)


def test_end_to_end_linear_kernel_path():
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (16, 32)), jnp.float32)
    w = _mk((32, 24))
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group=4)
    a = pcilt_linear(x, T, spec, s, group=4, path="kernel")
    b = pcilt_linear(x, T, spec, s, group=4, path="gather")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# Fused-pipeline parity: path="fused" (and the host-packed path="kernel") must
# agree with the literal path="gather" semantics across ragged shapes — odd B,
# non-multiple O, G not divisible by the staged Gb — and both table dtypes.
# (f32 agrees to reassociation-of-summation tolerance; bf16 to bf16 precision.)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("B,n,O,bits,group", [
    (16, 32, 24, 2, 4),     # baseline
    (7, 30, 130, 2, 2),     # odd B, non-128-multiple O
    (3, 36, 257, 2, 3),     # G=12 not divisible by typical Gb splits
    (1, 16, 5, 4, 1),       # decode-style B=1, tiny O, 4-bit codes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_gemv_parity(B, n, O, bits, group, dtype):
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear

    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 3, (B, n)), jnp.float32)
    w = _mk((n, O))
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group=group).astype(dtype)
    want = pcilt_linear(x, T, spec, s, group=group, path="gather")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    for path in ("fused", "kernel"):
        got = pcilt_linear(x, T, spec, s, group=group, path=path)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("B,H,W,C,kh,kw,stride,O,bits,group,padding", [
    (2, 8, 8, 3, 3, 3, 1, 5, 2, 2, "SAME"),     # ragged n=27 -> pad_n
    (1, 9, 7, 4, 3, 3, 2, 12, 2, 2, "SAME"),    # strided, odd spatial
    (2, 8, 8, 2, 5, 5, 1, 6, 2, 4, "VALID"),    # 5x5 paper filter
    (1, 6, 6, 4, 3, 3, 1, 130, 2, 3, "SAME"),   # non-128-multiple O
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_conv2d_parity(B, H, W, C, kh, kw, stride, O, bits, group,
                             padding, dtype):
    from repro.core import QuantSpec, calibrate, build_grouped_tables
    from repro.core.lut_layers import pcilt_conv2d

    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.uniform(0, 2, (B, H, W, C)), jnp.float32)
    f = _mk((kh, kw, C, O))
    s = calibrate(x, spec)
    n = kh * kw * C
    wflat = f.reshape(n, O)
    pad_n = (-n) % group
    if pad_n:
        wflat = jnp.concatenate([wflat, jnp.zeros((pad_n, O))], 0)
    T = build_grouped_tables(wflat, spec, s, group).astype(dtype)
    want = pcilt_conv2d(x, f, spec, s, group, stride=stride, padding=padding,
                        path="gather")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    for path in ("fused", "kernel"):
        got = pcilt_conv2d(x, f, spec, s, group, stride=stride,
                           padding=padding, tables=T, path=path)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol)


def test_fused_rejects_segment_plans():
    from repro.core import QuantSpec, SegmentPlan, calibrate, build_grouped_tables
    from repro.core import pcilt_linear

    spec = QuantSpec(2)
    x = jnp.asarray(RNG.uniform(0, 3, (4, 8)), jnp.float32)
    w = _mk((8, 16))
    s = calibrate(x, spec)
    plan = SegmentPlan.contiguous(8, 2)
    T = build_grouped_tables(w, spec, s, group=2, plan=plan)
    with pytest.raises(ValueError, match="fused"):
        pcilt_linear(x, T, spec, s, group=2, plan=plan, path="fused")
