"""Multi-device tier: mesh-sharded PCILT tables for tensor-parallel decode.

Asserts parity of the sharded gather / fused / shared execution paths
against the single-device reference for GEMV and conv2d — including G not
divisible by the mesh axis (replication fallback) and the batch=1 decode
regime — plus the sharded autotune-key contract (local-shard shapes, no
collision across device counts, ``us: null`` on failed tunes under a mesh).

This file wants ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI multi-device job exports it).  When collected in a single-device process
— e.g. the plain tier-1 run — the device-hungry tests skip and one wrapper
test re-executes this very file under pytest in a subprocess with the flag
set, so the tier is exercised either way.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORCE_FLAG = "--xla_force_host_platform_device_count=8"


def _device_count() -> int:
    import jax

    return jax.device_count()


MULTI = _device_count() >= 8
multi_device = pytest.mark.skipif(
    not MULTI,
    reason="needs 8 forced host devices (re-run via the subprocess wrapper)",
)


# ----------------------------------------------------------------------------
# Subprocess wrapper: single-device collection re-executes this file forced.
# ----------------------------------------------------------------------------


@pytest.mark.skipif(MULTI, reason="already running with forced devices")
def test_suite_reruns_with_forced_devices(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["REPRO_PCILT_TUNE_CACHE"] = str(tmp_path / "tiles.json")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x", os.path.abspath(__file__)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, (
        f"sharded suite failed under {FORCE_FLAG}:\n{r.stdout}\n{r.stderr}")


# ----------------------------------------------------------------------------
# Shared fixtures / helpers (all imports of jax stay inside so the outer
# single-device collection never pays for them).
# ----------------------------------------------------------------------------

RNG = np.random.default_rng(11)
BITS, GROUP = 2, 2


@pytest.fixture
def tune_cache(tmp_path):
    from repro.kernels import autotune as atn

    path = str(tmp_path / "tiles.json")
    atn.reset_cache(path)
    atn.TIMING_RUNS = 0
    yield path
    atn.TIMING_RUNS = 0
    atn.reset_cache()


def _mesh(model):
    from repro.launch.mesh import make_decode_mesh

    return make_decode_mesh(model)


def _spec_scale(x):
    from repro.core import QuantSpec, calibrate

    spec = QuantSpec(BITS)
    return spec, calibrate(x, spec)


def _int_weights(n, O):
    """Integer weights (paired with ``scale=1.0``): every table entry,
    partial product and partial sum is then a small exact integer in f32, so
    *any* summation order — single adder tree or per-shard partials + psum —
    produces bit-identical results.  This is what lets the parity asserts
    below be bitwise."""
    return np.asarray(RNG.integers(-4, 5, size=(n, O)), np.float32)


def _codebook_weights(n, O, X, integers=True):
    G = n // GROUP
    if integers:
        cb = RNG.integers(-4, 5, size=(X, GROUP, O)).astype(np.float32)
    else:
        cb = RNG.normal(size=(X, GROUP, O)).astype(np.float32)
    return cb[RNG.integers(0, X, G)].reshape(n, O)


def _gemv_problem(B=4, n=64, O=48, shared=False, integers=True):
    import jax.numpy as jnp
    from repro.core import build_grouped_tables, build_shared_grouped_tables

    x = jnp.asarray(np.abs(RNG.normal(size=(B, n))), jnp.float32)
    w = _codebook_weights(n, O, X=5, integers=integers) if shared else (
        _int_weights(n, O) if integers
        else np.asarray(RNG.normal(size=(n, O)), np.float32))
    w = jnp.asarray(w)
    spec, s = _spec_scale(x)
    if integers:
        s = jnp.float32(1.0)  # integer grid: exact arithmetic, see _int_weights
    if shared:
        T = build_shared_grouped_tables(w, spec, s, GROUP)
    else:
        T = build_grouped_tables(w, spec, s, GROUP)
    return x, T, spec, s


# ----------------------------------------------------------------------------
# Parity: sharded gather / fused / shared vs the single-device reference.
# ----------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("model", [1, 2, 4, 8])
@pytest.mark.parametrize("path", ["gather", "fused", "shared"])
def test_gemv_parity_bitwise(model, path):
    """Exact-arithmetic GEMV: the sharded result is bit-identical to the
    single-device gather reference at every device count."""
    from repro.core import pcilt_linear

    x, T, spec, s = _gemv_problem(shared=(path == "shared"), integers=True)
    ref = pcilt_linear(x, T, spec, s, GROUP, path="gather")
    got = pcilt_linear(x, T, spec, s, GROUP, path=path, mesh=_mesh(model))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multi_device
@pytest.mark.parametrize("model", [2, 8])
@pytest.mark.parametrize("path", ["gather", "onehot", "kernel", "fused", "shared"])
def test_gemv_parity_gaussian(model, path):
    """Gaussian weights: allclose parity for every execution path."""
    from repro.core import pcilt_linear

    x, T, spec, s = _gemv_problem(shared=(path == "shared"), integers=False)
    ref = pcilt_linear(x, T, spec, s, GROUP, path="gather")
    got = pcilt_linear(x, T, spec, s, GROUP, path=path, mesh=_mesh(model))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@multi_device
@pytest.mark.parametrize("model", [1, 2, 4, 8])
@pytest.mark.parametrize("path", ["gather", "fused", "shared"])
def test_conv2d_parity(model, path):
    """Strided-SAME conv2d (non-congruent extent — the PR 2 stride fix
    regime) stays allclose to the single-device gather reference."""
    import jax.numpy as jnp
    from repro.core import build_shared_grouped_tables, pcilt_conv2d

    B, H, W, C, kh, kw, Co = 2, 9, 9, 4, 3, 3, 16
    x = jnp.asarray(np.abs(RNG.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(RNG.normal(size=(kh, kw, C, Co)), jnp.float32)
    spec, s = _spec_scale(x)
    tables = None
    if path == "shared":
        tables = build_shared_grouped_tables(
            jnp.asarray(_codebook_weights(kh * kw * C, Co, X=4,
                                          integers=False)),
            spec, s, GROUP)
    ref = pcilt_conv2d(x, f, spec, s, GROUP, stride=2, tables=tables,
                       path="gather")
    got = pcilt_conv2d(x, f, spec, s, GROUP, stride=2, tables=tables,
                       path=path, mesh=_mesh(model))
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@multi_device
@pytest.mark.parametrize("path", ["gather", "fused", "shared"])
def test_decode_batch1(path):
    """The decode regime proper: batch=1 GEMV, 4-way tensor parallel."""
    from repro.core import pcilt_linear

    x, T, spec, s = _gemv_problem(B=1, shared=(path == "shared"))
    ref = pcilt_linear(x, T, spec, s, GROUP, path="gather")
    got = pcilt_linear(x, T, spec, s, GROUP, path=path, mesh=_mesh(4))
    assert got.shape == (1, ref.shape[-1])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multi_device
@pytest.mark.parametrize("path", ["gather", "fused", "shared"])
def test_divisibility_fallback(path):
    """G=12 over an 8-way model axis: falls back to replication — the exact
    single-device code path, so the result is bitwise identical."""
    import jax.numpy as jnp
    from repro.core import (build_grouped_tables, build_shared_grouped_tables,
                            mesh_shard_count, pcilt_linear)

    n, O = 24, 32  # G = 12, not divisible by 8
    x = jnp.asarray(np.abs(RNG.normal(size=(3, n))), jnp.float32)
    spec, s = _spec_scale(x)
    if path == "shared":
        T = build_shared_grouped_tables(
            jnp.asarray(_codebook_weights(n, O, X=3)), spec, s, GROUP)
    else:
        T = build_grouped_tables(jnp.asarray(_int_weights(n, O)), spec, s,
                                 GROUP)
    mesh = _mesh(8)
    assert mesh_shard_count(mesh, "model", 12) == 1
    ref = pcilt_linear(x, T, spec, s, GROUP, path=path)
    got = pcilt_linear(x, T, spec, s, GROUP, path=path, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multi_device
def test_table_pspec_divisibility_fallback():
    """The nn.module rule table applies the same fallback: a G the model
    axis does not divide replicates instead of sharding."""
    from jax.sharding import PartitionSpec as P
    from repro.nn.module import ShardingRules, pcilt_table_pspec

    rules = ShardingRules.for_mesh(_mesh(8))
    assert pcilt_table_pspec(64, rules=rules) == P("model", None, None)
    assert pcilt_table_pspec(12, rules=rules) == P(None, None, None)


# ----------------------------------------------------------------------------
# Sharded conv with in-VMEM im2col (PR 4): the fused/shared conv kernels run
# under shard_map with a seg_offset per shard — no host im2col detour.
# ----------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("model", [1, 2, 4, 8])
@pytest.mark.parametrize("path", ["fused", "shared"])
def test_conv2d_in_vmem_im2col_bitwise(model, path):
    """Integer weights + scale=1: the sharded conv route (in-VMEM im2col per
    shard, one psum) is *bitwise* identical to the single-device gather
    reference at every device count — each shard's partial sum is exact, so
    summation order cannot matter."""
    import jax.numpy as jnp
    from repro.core import build_shared_grouped_tables, pcilt_conv2d

    B, H, W, C, kh, kw, Co = 2, 8, 8, 4, 3, 3, 16
    x = jnp.asarray(np.abs(RNG.normal(size=(B, H, W, C))), jnp.float32)
    n = kh * kw * C  # G = 18: shards at 1/2, falls back at 4/8 (18 % 4 != 0)
    spec, _ = _spec_scale(x)
    s = jnp.float32(1.0)  # integer grid: exact arithmetic (see _int_weights)
    tables = None
    if path == "shared":
        w = _codebook_weights(n, Co, X=4)
        tables = build_shared_grouped_tables(jnp.asarray(w), spec, s, GROUP)
        f = jnp.asarray(np.asarray(w).reshape(kh, kw, C, Co))
    else:
        f = jnp.asarray(_int_weights(n, Co).reshape(kh, kw, C, Co))
    ref = pcilt_conv2d(x, f, spec, s, GROUP, tables=tables, path="gather")
    got = pcilt_conv2d(x, f, spec, s, GROUP, tables=tables, path=path,
                       mesh=_mesh(model))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multi_device
@pytest.mark.parametrize("model", [2, 4])
def test_conv2d_in_vmem_im2col_strided_allclose(model):
    """Gaussian weights, stride-2 SAME (non-congruent extent): the in-VMEM
    sharded route stays allclose to the reference — G = 100 divides both
    tested model-axis sizes, so this genuinely shards."""
    import jax.numpy as jnp
    from repro.core import mesh_shard_count, pcilt_conv2d

    B, H, W, C, kh, kw, Co = 2, 9, 9, 8, 5, 5, 24
    x = jnp.asarray(np.abs(RNG.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(RNG.normal(size=(kh, kw, C, Co)), jnp.float32)
    spec, s = _spec_scale(x)
    mesh = _mesh(model)
    assert mesh_shard_count(mesh, "model", kh * kw * C // GROUP) == model
    ref = pcilt_conv2d(x, f, spec, s, GROUP, stride=2, path="gather")
    got = pcilt_conv2d(x, f, spec, s, GROUP, stride=2, path="fused",
                       mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@multi_device
def test_sharded_conv_keys_local_shard_shape(tune_cache):
    """The conv kernels dispatched under shard_map consult the autotune
    cache with the *local* G — pre-tuning on the local shard shape with a
    concrete seg_offset populates exactly the key the sharded trace hits."""
    import jax.numpy as jnp
    from repro.core import build_grouped_tables, pcilt_conv2d
    from repro.kernels import ops
    from repro.kernels import autotune as atn

    B, H, W, C, kh, kw, Co, model = 1, 6, 6, 4, 3, 3, 16, 2
    x = jnp.asarray(np.abs(RNG.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(_int_weights(kh * kw * C, Co).reshape(kh, kw, C, Co))
    spec, _ = _spec_scale(x)
    s = jnp.float32(1.0)
    T = build_grouped_tables(f.reshape(-1, Co), spec, s, GROUP)
    G = T.shape[0]  # 18
    Gl = G // model
    ops.pcilt_fused_conv2d(x, T[:Gl], spec, s, GROUP, kh, kw,
                           seg_offset=0, n_total=G * GROUP, autotune=True)
    entries = json.load(open(tune_cache))
    keys = [k for k in entries if k.startswith("fused_conv2d|")]
    assert len(keys) == 1 and f"G={Gl}," in keys[0], keys
    # the sharded execution is a pure cache hit on that local key
    atn.TIMING_RUNS = 0
    got = pcilt_conv2d(x, f, spec, s, GROUP, path="fused", mesh=_mesh(model))
    ref = pcilt_conv2d(x, f, spec, s, GROUP, path="gather")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert atn.TIMING_RUNS == 0


@multi_device
def test_conv_layer_tune_keys_local_shard_shape(tune_cache):
    """Regression: PCILTConv2d.tune under a mesh must record the *local*
    shard's shape key (like PCILTLinear.tune) — the key the sharded
    shard_map dispatch actually looks up — and the later sharded call must
    be a pure cache hit."""
    import jax.numpy as jnp
    from repro.core import pcilt_conv2d
    from repro.core.serving import convert_conv_kernel
    from repro.kernels import autotune as atn

    model = 2
    x = jnp.asarray(np.abs(RNG.normal(size=(2, 8, 8, 4))), jnp.float32)
    f = jnp.asarray(_int_weights(3 * 3 * 4, 8).reshape(3, 3, 4, 8))
    spec, _ = _spec_scale(x)
    s = jnp.float32(1.0)  # exact arithmetic -> bitwise parity
    conv = convert_conv_kernel(f, spec, s, group=GROUP, mesh=_mesh(model))
    conv.tune(x)  # G = 18 -> local G 9
    entries = json.load(open(tune_cache))
    keys = [k for k in entries if k.startswith("fused_conv2d|")]
    assert len(keys) == 1 and "G=9," in keys[0], keys
    atn.reset_cache(tune_cache)
    atn.TIMING_RUNS = 0
    ref = pcilt_conv2d(x, f, spec, s, GROUP, path="gather")
    np.testing.assert_array_equal(np.asarray(conv(x, path="fused")),
                                  np.asarray(ref))
    assert atn.TIMING_RUNS == 0, "sharded dispatch missed the tuned entry"


@multi_device
def test_conv_layer_shared_mesh_preshards_pool(tune_cache):
    """A shared PCILTConv2d converted with mesh= shards and places the pool
    at conversion (offline), keeps per-device memory at local-pool scale,
    and tunes the local-shard shared_conv2d key."""
    import jax.numpy as jnp
    from repro.core import pcilt_conv2d
    from repro.core.serving import convert_conv_kernel

    model, n, Co = 2, 36, 8
    w = _codebook_weights(n, Co, X=4)
    f = jnp.asarray(np.asarray(w).reshape(3, 3, 4, Co))
    x = jnp.asarray(np.abs(RNG.normal(size=(2, 8, 8, 4))), jnp.float32)
    spec, _ = _spec_scale(x)
    s = jnp.float32(1.0)
    conv = convert_conv_kernel(f, spec, s, group=GROUP, shared=True,
                               mesh=_mesh(model))
    assert conv.shard_pools is not None
    assert conv.shard_pools.n_shards == model
    assert conv.per_device_table_bytes() <= conv.table_bytes()
    conv.tune(x)
    keys = [k for k in json.load(open(tune_cache))
            if k.startswith("shared_conv2d|")]
    assert len(keys) == 1 and "G=9," in keys[0], keys
    ref = pcilt_conv2d(x, f, spec, s, GROUP, path="gather")
    np.testing.assert_array_equal(np.asarray(conv(x, path="shared")),
                                  np.asarray(ref))


# ----------------------------------------------------------------------------
# Fused dwconv1d under the multi-device tier: plain parity (the kernel is
# unsharded — depthwise has no segment axis — but must coexist with forced
# multi-device platforms).
# ----------------------------------------------------------------------------


@multi_device
def test_fused_dwconv1d_parity_under_forced_devices():
    import jax.numpy as jnp
    from repro.core import pcilt_depthwise_conv1d

    x = jnp.asarray(np.abs(RNG.normal(size=(2, 24, 8))), jnp.float32)
    f = jnp.asarray(RNG.normal(size=(4, 8)), jnp.float32)
    spec, s = _spec_scale(x)
    ref = pcilt_depthwise_conv1d(x, f, spec, s, path="gather")
    got = pcilt_depthwise_conv1d(x, f, spec, s, path="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# Sharded shared pools: local-X memory scaling and structure.
# ----------------------------------------------------------------------------


@multi_device
def test_shard_pool_memory_scales_with_local_cardinality():
    """Segments arranged so each half of the layer references only half the
    codebook: per-shard pools keep local X = X/2 rows and per-device memory
    drops accordingly, while the materialized tables stay identical."""
    import jax.numpy as jnp
    from repro.core import (build_shared_grouped_tables,
                            shard_shared_grouped_tables)

    n, O, X = 64, 32, 4
    G = n // GROUP
    cb = RNG.integers(-4, 5, size=(X, GROUP, O)).astype(np.float32)
    picks = np.concatenate([RNG.integers(0, 2, G // 2),
                            RNG.integers(2, 4, G // 2)])
    w = jnp.asarray(cb[picks].reshape(n, O))
    x = jnp.asarray(np.abs(RNG.normal(size=(2, n))), jnp.float32)
    spec, s = _spec_scale(x)
    st = build_shared_grouped_tables(w, spec, s, GROUP)
    assert st.pool_cardinality == X
    sp = shard_shared_grouped_tables(st, 2)
    assert sp.shard_cards == (2, 2) and sp.max_cardinality == 2
    assert sp.local_pool_bytes() < st.pool_bytes()
    np.testing.assert_array_equal(np.asarray(sp.materialize()),
                                  np.asarray(st.materialize()))


@multi_device
def test_shard_pool_mesh_mismatch_raises():
    from repro.core import pcilt_linear, shard_shared_grouped_tables

    x, st, spec, s = _gemv_problem(shared=True)
    sp = shard_shared_grouped_tables(st, 4)
    with pytest.raises(ValueError, match="4 shards"):
        pcilt_linear(x, sp, spec, s, GROUP, path="shared", mesh=_mesh(2))
    with pytest.raises(ValueError, match="mesh"):
        pcilt_linear(x, sp, spec, s, GROUP, path="shared")
    with pytest.raises(ValueError, match="shared"):
        pcilt_linear(x, sp, spec, s, GROUP, path="fused", mesh=_mesh(4))


@multi_device
def test_generalized_plan_refuses_to_shard():
    """A generalized SegmentPlan cannot shard along contiguous G-blocks:
    combining plan= with a sharding mesh raises instead of silently keeping
    full per-device table residency."""
    import jax.numpy as jnp
    from repro.core import SegmentPlan, build_grouped_tables, pcilt_linear

    x, T, spec, s = _gemv_problem()
    plan = SegmentPlan(
        np.array([[1, 0], [3, 2], [5, 4], [7, 6]], np.int32))
    Tp = build_grouped_tables(jnp.asarray(_int_weights(8, 16)), spec, s,
                              GROUP, plan=plan)
    with pytest.raises(ValueError, match="cannot be sharded"):
        pcilt_linear(x[:, :8], Tp, spec, s, GROUP, plan=plan, path="gather",
                     mesh=_mesh(4))
    # mesh=None executes the plan replicated, as the error message says
    out = pcilt_linear(x[:, :8], Tp, spec, s, GROUP, plan=plan, path="gather")
    assert out.shape == (x.shape[0], 16)


# ----------------------------------------------------------------------------
# Serving conversion: placement, per-device memory, local-shard autotune.
# ----------------------------------------------------------------------------


@multi_device
def test_convert_kernel_mesh_places_table_shards():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import pcilt_linear
    from repro.core.serving import convert_kernel

    n, O, D = 64, 48, 4
    x = jnp.asarray(np.abs(RNG.normal(size=(2, n))), jnp.float32)
    w = jnp.asarray(_int_weights(n, O))
    spec, s = _spec_scale(x)
    s = jnp.float32(1.0)  # exact arithmetic -> bitwise parity
    lin = convert_kernel(w, spec, s, GROUP, mesh=_mesh(D))
    assert lin.shard_count == D
    assert lin.tables.sharding.spec == P("model", None, None)
    assert lin.tables.addressable_shards[0].data.shape[0] == lin.n_segments // D
    assert lin.per_device_table_bytes() * D == lin.table_bytes()
    ref = pcilt_linear(x, jnp.asarray(np.asarray(lin.tables)), spec, s, GROUP)
    for path in ("gather", "fused"):
        np.testing.assert_array_equal(np.asarray(lin(x, path=path)),
                                      np.asarray(ref))


@multi_device
def test_convert_kernel_mesh_shared_pool():
    import jax.numpy as jnp
    from repro.core.serving import convert_kernel

    n, O, D = 64, 32, 4
    x = jnp.asarray(np.abs(RNG.normal(size=(2, n))), jnp.float32)
    w = jnp.asarray(_codebook_weights(n, O, X=5))
    spec, s = _spec_scale(x)
    s = jnp.float32(1.0)  # exact arithmetic -> bitwise parity
    ref_lin = convert_kernel(w, spec, s, GROUP, shared=True)
    lin = convert_kernel(w, spec, s, GROUP, shared=True, mesh=_mesh(D))
    assert lin.shard_pools is not None and lin.shard_pools.n_shards == D
    # shared-path memory follows the padded *local* pool, never G
    assert lin.per_device_table_bytes() <= lin.table_bytes()
    for path in ("gather", "shared"):
        np.testing.assert_array_equal(
            np.asarray(lin(x, path=path)),
            np.asarray(ref_lin(x, path="gather")))


@multi_device
def test_tune_keys_local_shard_shape_no_collision(tune_cache):
    """Caches tuned at different device counts key on the local shard shape
    and must not collide: both entries coexist and both later dispatches are
    pure hits."""
    import jax.numpy as jnp
    from repro.core.serving import convert_kernel
    from repro.kernels import autotune as atn

    n, O = 64, 48  # G = 32 -> local G 8 at model=4, 16 at model=2
    x = jnp.asarray(np.abs(RNG.normal(size=(4, n))), jnp.float32)
    w = jnp.asarray(_int_weights(n, O))
    spec, s = _spec_scale(x)
    s = jnp.float32(1.0)  # exact arithmetic -> bitwise parity
    outs = {}
    for model in (4, 2):
        lin = convert_kernel(w, spec, s, GROUP, mesh=_mesh(model))
        outs[model] = np.asarray(lin.tune(x))
    np.testing.assert_array_equal(outs[4], outs[2])
    entries = json.load(open(tune_cache))
    keys = sorted(k for k in entries if k.startswith("fused_gemv|"))
    assert len(keys) == 2, f"expected one key per device count, got {keys}"
    assert any("G=8," in k for k in keys) and any("G=16," in k for k in keys)
    assert not any("G=32," in k for k in keys), "global-shape key leaked"
    # warm cache: re-tuning both device counts performs zero timing runs
    atn.reset_cache(tune_cache)
    atn.TIMING_RUNS = 0
    for model in (4, 2):
        convert_kernel(w, spec, s, GROUP, mesh=_mesh(model)).tune(x)
    assert atn.TIMING_RUNS == 0


@multi_device
def test_tune_under_mesh_records_null_on_failure(tune_cache, monkeypatch):
    """Regression: a sharded tune whose candidates all fail must still write
    strict JSON (``us: null``) under the local-shard key."""
    import jax.numpy as jnp
    from repro.core.serving import convert_kernel
    from repro.kernels import autotune as atn

    def boom(fn, reps, warmup):
        raise RuntimeError("no candidate can run")

    monkeypatch.setattr(atn, "_time_one", boom)
    x = jnp.asarray(np.abs(RNG.normal(size=(4, 64))), jnp.float32)
    w = jnp.asarray(_int_weights(64, 48))
    spec, s = _spec_scale(x)
    lin = convert_kernel(w, spec, s, GROUP, mesh=_mesh(4))
    out = lin.tune(x)  # must still execute via the heuristic fallback
    assert out.shape == (4, 48)
    raw = open(tune_cache).read()
    assert "NaN" not in raw
    entries = json.loads(raw)
    key = next(k for k in entries if k.startswith("fused_gemv|"))
    assert "G=8," in key
    assert entries[key]["us"] is None and entries[key]["candidates"] == 0
