"""The trip-count-aware HLO analyzer against analytically-known costs."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo
from repro.compat import shard_map


def _scan_matmul(L=8, d=128, b=64):
    def model(ws, x):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    return model, jnp.zeros((L, d, d)), jnp.zeros((b, d)), 2 * b * d * d * L


def test_forward_flops_exact():
    model, ws, x, expect = _scan_matmul()
    c = jax.jit(model).lower(ws, x).compile()
    got = analyze_hlo(c.as_text())["flops"]
    assert abs(got - expect) / expect < 1e-6


def test_grad_flops_3x():
    model, ws, x, expect = _scan_matmul()
    c = jax.jit(jax.grad(model)).lower(ws, x).compile()
    got = analyze_hlo(c.as_text())["flops"]
    assert abs(got - 3 * expect) / (3 * expect) < 1e-6


def test_trip_count_scales_with_layers():
    m8, ws8, x, e8 = _scan_matmul(L=8)
    m16, ws16, _, e16 = _scan_matmul(L=16)
    f8 = analyze_hlo(jax.jit(m8).lower(ws8, x).compile().as_text())["flops"]
    f16 = analyze_hlo(jax.jit(m16).lower(ws16, x).compile().as_text())["flops"]
    assert abs(f16 / f8 - 2.0) < 1e-6


def test_collectives_weighted_by_trips():
    import numpy as np
    from jax.sharding import PartitionSpec as P

    if len(jax.devices()) < 1:
        return
    mesh = jax.make_mesh((1,), ("data",))

    def body(xl):
        def step(c, _):
            return jax.lax.psum(c, "data"), ()
        y, _ = jax.lax.scan(step, xl, None, length=5)
        return y

    f = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P())
    c = jax.jit(f).lower(jnp.zeros((4, 4))).compile()
    a = analyze_hlo(c.as_text())
    # psum of 64B fp32 × 5 trips (single-device AR may be optimized away;
    # accept either exact 5× weighting or a fully-elided collective)
    total = a["coll"]["all-reduce"]["count"]
    assert total in (0, 5), a["coll"]


def test_top_diagnostics_present():
    model, ws, x, _ = _scan_matmul()
    c = jax.jit(model).lower(ws, x).compile()
    a = analyze_hlo(c.as_text())
    assert "top_collectives" in a and "top_buffers" in a
    assert a["bytes_traffic_est"] > 0
