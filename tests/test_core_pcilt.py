"""Core PCILT semantics: every fetch path reproduces direct multiplication
exactly (the paper's central claim: "The PCILT values are an exact product of
the convolutional function — there is no result precision loss")."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec, calibrate, quantize, dequantize, code_values,
    build_scalar_tables, build_grouped_tables, build_shared_tables,
    pcilt_linear, pcilt_conv2d, pcilt_depthwise_conv1d, lut_lookup,
    SegmentPlan, pack_offsets, unpack_offsets, offset_grid,
    mul_fn, log_mul_fn, init_learnable_pcilt, apply_learnable_pcilt,
    effective_tables, extract_filters,
)

KEY = jax.random.PRNGKey(0)


def _data(bits, n=8, b=4, out=5, lo=0.0, hi=3.0):
    spec = QuantSpec(bits=bits)
    x = jax.random.uniform(KEY, (b, n), minval=lo, maxval=hi)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (n, out))
    scale = calibrate(x, spec)
    xq = dequantize(quantize(x, spec, scale), spec, scale)
    return spec, x, w, scale, xq


@pytest.mark.parametrize("bits,group", [(1, 8), (2, 4), (2, 2), (4, 2), (8, 1)])
def test_grouped_paths_equal_dm(bits, group):
    spec, x, w, scale, xq = _data(bits)
    T = build_grouped_tables(w, spec, scale, group)
    want = xq @ w
    for path in ("gather", "onehot"):
        got = pcilt_linear(x, T, spec, scale, group, path=path)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_path_equals_gather():
    spec, x, w, scale, _ = _data(2, n=32, b=16, out=24)
    T = build_grouped_tables(w, spec, scale, 4)
    a = pcilt_linear(x, T, spec, scale, 4, path="kernel")
    b = pcilt_linear(x, T, spec, scale, 4, path="gather")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_scalar_tables_match_grouped_g1():
    spec, x, w, scale, xq = _data(4)
    Ts = build_scalar_tables(w, spec, scale)       # [n, K, out]
    Tg = build_grouped_tables(w, spec, scale, 1)   # [n, K, out]
    np.testing.assert_allclose(Ts, Tg, rtol=1e-6)


def test_shared_tables_exact_and_dedup():
    spec, x, w, scale, _ = _data(3)
    wq = jnp.round(w * 2) / 2  # low actual cardinality
    st = build_shared_tables(wq, spec, scale)
    codes = quantize(x, spec, scale)
    want = dequantize(codes, spec, scale) @ wq
    np.testing.assert_allclose(st.lookup(codes), want, rtol=1e-5, atol=1e-5)
    st2 = build_shared_tables(wq, spec, scale, dedup_values=True)
    np.testing.assert_allclose(st2.lookup(codes), want, rtol=1e-5, atol=1e-5)
    assert st.actual_cardinality <= wq.size


def test_custom_convolutional_function():
    """Extension 2: any f(w, a) builds and fetches at identical cost."""
    spec, x, w, scale, xq = _data(2)
    T = build_grouped_tables(w, spec, scale, 2, fn=log_mul_fn)
    got = pcilt_linear(x, T, spec, scale, 2)
    want = jnp.sum(log_mul_fn(w[None], xq[:, :, None]), axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_conv2d_matches_lax_conv():
    spec = QuantSpec(bits=2)
    img = jax.random.uniform(KEY, (2, 10, 9, 3)) * 2
    f = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 3, 3, 4))
    s = calibrate(img, spec)
    got = pcilt_conv2d(img, f, spec, s, group=3)
    imq = dequantize(quantize(img, spec, s), spec, s)
    want = jax.lax.conv_general_dilated(
        imq, f, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_strided_same_matches_lax_conv():
    """Stride-2 "SAME" on even extents: pads must follow the XLA split
    (pad_total//2 low), not the stride-agnostic (k-1)//2."""
    spec = QuantSpec(bits=2)
    img = jax.random.uniform(KEY, (1, 8, 10, 2)) * 2
    f = jax.random.normal(jax.random.fold_in(KEY, 7), (3, 3, 2, 4))
    s = calibrate(img, spec)
    got = pcilt_conv2d(img, f, spec, s, group=2, stride=2, padding="SAME")
    imq = dequantize(quantize(img, spec, s), spec, s)
    want = jax.lax.conv_general_dilated(
        imq, f, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_strided_valid():
    spec = QuantSpec(bits=2)
    img = jax.random.uniform(KEY, (1, 12, 12, 2)) * 2
    f = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 4, 2, 5))
    s = calibrate(img, spec)
    got = pcilt_conv2d(img, f, spec, s, group=2, stride=2, padding="VALID")
    imq = dequantize(quantize(img, spec, s), spec, s)
    want = jax.lax.conv_general_dilated(
        imq, f, (2, 2), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_depthwise_conv1d_one_fetch_per_output():
    spec = QuantSpec(bits=2)
    x = jax.random.uniform(KEY, (2, 20, 6)) * 2
    f = jax.random.normal(jax.random.fold_in(KEY, 4), (4, 6))
    s = calibrate(x, spec)
    got = pcilt_depthwise_conv1d(x, f, spec, s)
    xq = dequantize(quantize(x, spec, s), spec, s)
    pad = jnp.pad(xq, ((0, 0), (3, 0), (0, 0)))
    want = sum(pad[:, i : i + 20] * f[i][None, None] for i in range(4))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    got_oh = pcilt_depthwise_conv1d(x, f, spec, s, path="onehot")
    np.testing.assert_allclose(got_oh, got, rtol=1e-5, atol=1e-5)


def test_segment_plan_skip_dup_nonadjacent():
    """Fig. 7: non-adjacent grouping, skipped positions, reused positions."""
    spec, x, w, scale, _ = _data(2)
    plan = SegmentPlan(np.array([[0, 3], [5, 5], [-1, 7]], np.int32))
    codes = quantize(x, spec, scale)
    T = build_grouped_tables(w, spec, scale, 2, plan=plan)
    got = lut_lookup(T, plan.pack(codes, spec.bits))
    xv = dequantize(plan.gather_codes(codes), spec, scale)
    want = jnp.einsum("bgj,gjo->bo", xv, plan.gather_weights(w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_generalized_plan_rejected_at_fused_boundary():
    """A generalized SegmentPlan now *executes* on path='fused' (the
    in-VMEM plan gather) and must match the host-packed reference; on the
    shared-pool path — and when the plan is omitted but the tables betray
    one — the boundary still raises a typed, actionable ValueError, not a
    bare shape error from deep inside the kernel wrapper."""
    spec, x, w, scale, _ = _data(2)
    plan = SegmentPlan(np.array([[0, 3], [5, 5], [-1, 7]], np.int32))
    T = build_grouped_tables(w, spec, scale, 2, plan=plan)
    # The plan passed explicitly: fused runs via the plan-gather kernel.
    got_f = pcilt_linear(x, T, spec, scale, 2, plan=plan, path="fused")
    ref = pcilt_linear(x, T, spec, scale, 2, plan=plan, path="gather")
    np.testing.assert_allclose(got_f, ref, rtol=1e-5, atol=1e-5)
    from repro.core import build_shared_grouped_tables

    st = build_shared_grouped_tables(w, spec, scale, 2, plan=plan)
    with pytest.raises(ValueError, match="SegmentPlan"):
        pcilt_linear(x, st, spec, scale, 2, plan=plan, path="shared")
    # Spelling 2: tables *built* from the plan (G*group != n) with plan
    # omitted — the boundary must still name the SegmentPlan cause and
    # point at passing the plan (which fused now executes).
    with pytest.raises(ValueError, match="generalized SegmentPlan"):
        pcilt_linear(x, T, spec, scale, 2, path="fused")
    with pytest.raises(ValueError, match="plan="):
        pcilt_linear(x, T, spec, scale, 2, path="fused")
    # The plan still executes on the host-packed paths it is pointed at.
    codes = quantize(x, spec, scale)
    got = pcilt_linear(x, T, spec, scale, 2, plan=plan, path="gather")
    np.testing.assert_allclose(got, lut_lookup(T, plan.pack(codes, spec.bits)),
                               rtol=1e-5, atol=1e-5)


def test_learnable_pcilt_trains():
    """Extension 4: table entries receive gradients and reduce a loss."""
    spec = QuantSpec(bits=2)
    x = jax.random.uniform(KEY, (16, 8)) * 2
    y = jax.random.normal(jax.random.fold_in(KEY, 5), (16, 3))
    scale = float(calibrate(x, spec))
    p = init_learnable_pcilt(KEY, 8, 3, spec, scale, group=2,
                             granularity="entry")

    def loss(p):
        pred = apply_learnable_pcilt(p, x, spec, scale, 2)
        return jnp.mean((pred - y) ** 2)

    l0 = loss(p)
    for _ in range(40):
        g = jax.grad(loss)(p)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
    assert loss(p) < 0.5 * l0


@pytest.mark.parametrize("gran", ["filter", "table", "offset", "entry"])
def test_learnable_granularities(gran):
    spec = QuantSpec(bits=2)
    scale = 0.5
    p = init_learnable_pcilt(KEY, 8, 3, spec, scale, group=2, granularity=gran)
    x = jax.random.uniform(KEY, (4, 8))
    out = apply_learnable_pcilt(p, x, spec, scale, 2)
    assert out.shape == (4, 3)
    g = jax.grad(lambda p: apply_learnable_pcilt(p, x, spec, scale, 2).sum())(p)
    learnable = {"filter": "filter_scale", "table": "table_scale",
                 "offset": "offset_delta", "entry": "entry_delta"}[gran]
    assert bool(jnp.any(g[learnable] != 0))


def test_extract_filters_roundtrip():
    spec, x, w, scale, _ = _data(4)
    T = build_grouped_tables(w, spec, scale, 2)
    w_rec = extract_filters(T, spec, float(scale), 2)
    np.testing.assert_allclose(w_rec, w, rtol=1e-3, atol=1e-3)
