"""Calibration-drift sentinel: in-kernel saturation counters vs the host
oracle, EWMA drift classification, and online table recalibration.

The quantizer in every fused fetch kernel *clips* silently — an activation
outside ``[-amax, amax]`` maps to the edge code and the output is plausibly
wrong with no byte corrupted.  The counters close that hole: each monitored
kernel call also returns how many elements saturated and the peak
``|x|/scale`` ratio, reduced in VMEM.  These tests pin

* the host oracle (``quantize_with_stats``) to ``quantize``'s exact
  arithmetic and to first-principles saturation counting;
* every counter kernel to the host oracle, bit-exactly, across ragged
  shapes x f32/bf16 tables x batch {1, R} (padding invariance: group
  alignment, paired phantom segments, and causal pads all quantize to the
  in-range zero point, so kernel and host see identical statistics);
* the monitor's EWMA classification and typed drift response;
* online recalibration: hot-swapped tables bit-equal a fresh
  conversion-arithmetic build at the new scale, checksums re-recorded,
  layer repromoted — and the sticky cases (conv's global scale, exhausted
  budget) stay demoted;
* the serving engine end to end: inject drift -> sentinel fires -> demote
  -> recalibrate -> repromote, with no request lost.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantSpec, build_grouped_tables, calibrate, quantize,
                        quantize_with_stats)
from repro.core.lut_layers import build_dwconv_tables
from repro.core.pcilt import (build_paired_stacked_tables,
                              build_paired_tables, table_checksum)
from repro.kernels import autotune as atn
from repro.kernels import ops

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _private_cache(tmp_path):
    atn.reset_cache(str(tmp_path / "tiles.json"))
    yield
    atn.reset_cache()


def _host_stats(x, spec, scale):
    _, c, r = quantize_with_stats(x, spec, scale)
    return int(c), np.float32(r)


# ----------------------------------------------------------------------------
# Host oracle
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_with_stats_codes_bit_equal_and_counts(bits, dtype):
    spec = QuantSpec(bits)
    x = jnp.asarray(RNG.normal(size=(7, 33)) * 3, dtype)
    scale = calibrate(x.astype(jnp.float32), spec) * 0.4  # force clipping
    codes, count, ratio = quantize_with_stats(x, spec, scale)
    assert codes.dtype == quantize(x, spec, scale).dtype
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(quantize(x, spec, scale)))
    # first-principles count: round(x/scale)+zp outside [0, K-1]
    q = np.round(np.asarray(x, np.float64) / float(scale)) + spec.zero_point
    want = int(((q < 0) | (q > spec.cardinality - 1)).sum())
    assert count.dtype == jnp.int32 and int(count) == want > 0
    assert ratio.dtype == jnp.float32
    assert np.isclose(float(ratio),
                      float(np.abs(np.asarray(x, np.float64)).max())
                      / float(scale), rtol=1e-2)


def test_clip_edge_values_are_in_range():
    """An element exactly on the representable edge rounds to an edge code
    — in range.  Saturation means *beyond* the grid, not on its boundary."""
    spec = QuantSpec(2)
    scale = jnp.asarray(0.5, jnp.float32)
    edge = float(scale) * (spec.cardinality - 1 - spec.zero_point)
    x = jnp.asarray([[edge, -float(scale) * spec.zero_point, 0.0]],
                    jnp.float32)
    _, count, _ = quantize_with_stats(x, spec, scale)
    assert int(count) == 0
    _, count, _ = quantize_with_stats(x * 1.5, spec, scale)
    assert int(count) > 0


def test_zero_padding_invariance():
    """Zero slots quantize to the (in-range) zero point, so stats computed
    on padded and unpadded activations agree — the property that lets the
    kernels count over their padded tiles and still match the host."""
    spec = QuantSpec(2)
    x = jnp.asarray(RNG.normal(size=(3, 10)) * 2, jnp.float32)
    scale = calibrate(x, spec) * 0.3
    _, c0, r0 = quantize_with_stats(x, spec, scale)
    xp = jnp.concatenate([x, jnp.zeros((3, 6), x.dtype)], axis=1)
    _, c1, r1 = quantize_with_stats(xp, spec, scale)
    assert int(c0) == int(c1)
    assert float(r0) == float(r1)


# ----------------------------------------------------------------------------
# Kernel counters == host oracle (bit-exact)
# ----------------------------------------------------------------------------

SHAPES = [  # (n, O, L) — ragged O and layer counts
    (16, 32, 1),
    (24, 33, 2),
    (8, 100, 3),
]


@pytest.mark.parametrize("B", [1, 5])
@pytest.mark.parametrize("tdt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,O,L", SHAPES)
def test_stacked_gemv_counters_match_host(B, tdt, n, O, L):
    spec, group = QuantSpec(2), 2
    ws = jnp.asarray(RNG.normal(size=(L, n, O)), jnp.float32)
    xs = jnp.asarray(RNG.normal(size=(L, B, n)) * 2.5, jnp.float32)
    scales = jnp.asarray(
        [float(calibrate(xs[l], spec)) * 0.5 for l in range(L)], jnp.float32)
    stack = jnp.stack([build_grouped_tables(ws[l], spec, scales[l], group)
                       for l in range(L)]).astype(tdt)
    for l in range(L):
        out, count, ratio = ops.pcilt_fused_gemv_stacked(
            xs[l], stack, l, spec, scales[l], group, with_stats=True)
        ref = ops.pcilt_fused_gemv_stacked(xs[l], stack, l, spec, scales[l],
                                           group)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        hc, hr = _host_stats(xs[l], spec, scales[l])
        assert int(count) == hc > 0
        assert np.float32(ratio) == hr


@pytest.mark.parametrize("B", [1, 5])
@pytest.mark.parametrize("tdt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,O", [(16, 32), (24, 33), (8, 100)])
def test_paired_gemv_counters_match_host(B, tdt, n, O):
    spec, group = QuantSpec(2), 2
    w = jnp.asarray(RNG.normal(size=(n, O)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, n)) * 2.5, jnp.float32)
    scale = calibrate(x, spec) * 0.5
    t = build_paired_tables(w, spec, scale, group).astype(tdt)
    out, count, ratio = ops.pcilt_fused_gemv_paired(
        x, t, spec, scale, group, with_stats=True)
    ref = ops.pcilt_fused_gemv_paired(x, t, spec, scale, group)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    hc, hr = _host_stats(x, spec, scale)
    assert int(count) == hc > 0
    assert np.float32(ratio) == hr


@pytest.mark.parametrize("B", [1, 5])
@pytest.mark.parametrize("tdt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,O,L", [(16, 32, 2), (8, 33, 3)])
def test_paired_stacked_gemv_counters_match_host(B, tdt, n, O, L):
    spec, group = QuantSpec(2), 2
    ws = jnp.asarray(RNG.normal(size=(L, n, O)), jnp.float32)
    xs = jnp.asarray(RNG.normal(size=(L, B, n)) * 2.5, jnp.float32)
    scales = jnp.asarray(
        [float(calibrate(xs[l], spec)) * 0.5 for l in range(L)], jnp.float32)
    stack = build_paired_stacked_tables(ws, spec, scales, group).astype(tdt)
    for l in range(L):
        out, count, ratio = ops.pcilt_fused_gemv_paired_stacked(
            xs[l], stack, l, spec, scales[l], group, with_stats=True)
        ref = ops.pcilt_fused_gemv_paired_stacked(xs[l], stack, l, spec,
                                                  scales[l], group)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        hc, hr = _host_stats(xs[l], spec, scales[l])
        assert int(count) == hc > 0
        assert np.float32(ratio) == hr


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("tdt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,C,padding", [(16, 24, "CAUSAL"), (9, 40, "CAUSAL"),
                                         (4, 24, "VALID")])
def test_dwconv1d_counters_match_host(B, tdt, T, C, padding):
    spec, k = QuantSpec(2), 4
    filters = jnp.asarray(RNG.normal(size=(k, C)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, T, C)) * 2.5, jnp.float32)
    scale = calibrate(x, spec) * 0.5
    t = build_dwconv_tables(filters, spec, scale).astype(tdt)
    out, count, ratio = ops.pcilt_fused_dwconv1d(
        x, t, spec, scale, k, padding=padding, with_stats=True)
    ref = ops.pcilt_fused_dwconv1d(x, t, spec, scale, k, padding=padding)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    hc, hr = _host_stats(x, spec, scale)
    assert int(count) == hc > 0
    assert np.float32(ratio) == hr


# ----------------------------------------------------------------------------
# Monitor: EWMA classification + recalibration (smoke Mamba model)
# ----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def env():
    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig
    from repro.models import build_model
    from repro.nn import materialize
    from repro.nn.layers import Ctx

    cfg = get_smoke_config("mamba2-130m")
    cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                              dtype=jnp.float32)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = materialize(model.param_specs(), key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    _, cache = model.prefill(params, {"tokens": toks}, Ctx())
    tok = jax.random.randint(jax.random.fold_in(key, 1), (2, 1), 0, cfg.vocab)
    return dict(cfg=cfg, model=model, params=params, cache=cache, tok=tok,
                calib=toks)


def _fresh(env):
    """A fresh conversion + monitor (recalibration tests mutate tables)."""
    from repro.core.serving import HealthMonitor, convert_mamba_decode

    eng = convert_mamba_decode(env["model"], env["params"], env["calib"])
    mon = HealthMonitor(eng, env["params"], oracle_every=0)
    return eng, mon


def test_monitored_step_bit_identical_and_stats_shapes(env):
    eng, mon = _fresh(env)
    L = env["cfg"].n_layers
    lo, ho = mon.ok_masks()
    out0, c0 = eng.step(env["params"], env["cache"], env["tok"], lo, ho)
    out1, c1, sat = eng.step(env["params"], env["cache"], env["tok"], lo, ho,
                             with_stats=True)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for grid in ("in", "conv", "out"):
        assert sat[grid]["count"].shape == (L,)
        assert sat[grid]["count"].dtype == jnp.int32
        assert sat[grid]["ratio"].shape == (L,)
        assert sat[grid]["ratio"].dtype == jnp.float32


def test_demoted_layer_still_reports_stats(env):
    """The oracle branch computes the same host-side stats, so a demoted
    layer keeps feeding the sentinel — recovery stays observable."""
    eng, mon = _fresh(env)
    lo, ho = mon.ok_masks()
    _, _, sat0 = eng.step(env["params"], env["cache"], env["tok"], lo, ho,
                          with_stats=True)
    lo2 = lo.at[1].set(False)
    _, _, sat1 = eng.step(env["params"], env["cache"], env["tok"], lo2, ho,
                          with_stats=True)
    for grid in ("in", "conv", "out"):
        np.testing.assert_array_equal(np.asarray(sat0[grid]["count"]),
                                      np.asarray(sat1[grid]["count"]))


def test_ewma_classification_and_typed_demotion(env):
    eng, mon = _fresh(env)
    L = mon.n_layers
    z = {"count": np.zeros(L, np.int64), "ratio": np.zeros(L)}

    def sat(grid, layer, rate, ratio):
        s = {g: dict(z) for g in mon.SAT_GRIDS}
        cnt = np.zeros(L, np.int64)
        cnt[layer] = int(rate * mon._sat_elems[grid])
        rat = np.zeros(L)
        rat[layer] = ratio
        s[grid] = {"count": cnt, "ratio": rat}
        return s

    # healthy: below both thresholds, forever
    assert mon.observe_saturation(0, sat("in", 0, 0.0, 0.8), rows=1) == []
    assert mon.saturation_state("in", 0) == "healthy"
    # sustained low-grade drift: crosses the EWMA threshold, not the hard one
    tick, breaches = 1, []
    while not breaches:
        assert tick < 50, "EWMA never crossed sat_drift"
        breaches = mon.observe_saturation(tick, sat("out", 1, 0.05, 2.0),
                                          rows=1)
        tick += 1
    assert breaches[0]["kind"] == "drift"
    assert breaches[0]["state"] == "drifting"
    assert breaches[0]["layer"] == 1 and breaches[0]["grid"] == "out"
    assert not mon.layer_ok[1]
    assert (1, "out") in mon.drift_pending
    # instant saturation: one breach of the hard threshold demotes now
    breaches = mon.observe_saturation(tick, sat("in", 0, 0.9, 30.0), rows=1)
    assert breaches and breaches[0]["state"] == "saturated"
    assert breaches[0]["layer"] == 0 and breaches[0]["grid"] == "in"
    # demoted layers are skipped (no demotion storm)
    assert mon.observe_saturation(tick + 1, sat("in", 0, 0.9, 30.0),
                                  rows=1) == []


def test_recalibration_hot_swaps_repromotes_and_reverifies(env):
    eng, mon = _fresh(env)
    DL = 1
    proj = eng.pcilt["proj"]
    old_scale = float(np.asarray(proj["scales"]["wo"][DL]))
    old_tab = np.asarray(proj["tables"]["wo"])[DL].copy()
    # as if the sentinel had seen "out" activations 8x past calibration
    mon.sat_peak["out"][DL] = 8.0
    mon.layer_ok[DL] = False
    ev = mon.recalibrate_layer(DL, "out", tick=3)
    assert ev["kind"] == "recalibrate"
    new_scale = float(np.asarray(proj["scales"]["wo"][DL]))
    assert new_scale > old_scale
    assert mon.layer_ok[DL] and mon.tainted
    assert int(mon.last_verified[DL]) == 3
    got = np.asarray(proj["tables"]["wo"])[DL]
    assert not np.array_equal(got, old_tab)
    # bit-equal to a fresh conversion-arithmetic build at the new scale
    wf = jnp.asarray(env["params"]["blocks"]["mixer"]["wo"]["kernel"][DL],
                     jnp.float32)
    pad = (-wf.shape[0]) % proj["group"]
    if pad:
        wf = jnp.concatenate([wf, jnp.zeros((pad, wf.shape[1]), wf.dtype)], 0)
    want = build_grouped_tables(wf, proj["spec"], new_scale, proj["group"])
    np.testing.assert_array_equal(got, np.asarray(want).astype(got.dtype))
    # integrity record re-recorded for the swapped slice — CRC verification
    # still passes (rehoist(verify=True) already ran inside recalibrate)
    assert eng.pcilt["integrity"]["proj"]["wo"][DL] == table_checksum(got)
    assert eng.verify_layer(DL) == []
    # untouched layer 0 kept its original bytes and record
    assert eng.verify_layer(0) == []


def test_conv_grid_and_exhausted_budget_stay_sticky(env):
    eng, mon = _fresh(env)
    mon.layer_ok[0] = False
    ev = mon.recalibrate_layer(0, "conv", tick=1)
    assert ev["kind"] == "drift_sticky"
    assert not mon.layer_ok[0]  # conv shares one global scale: stays demoted
    mon.layer_ok[1] = False
    mon.sat_peak["out"][1] = 4.0
    mon.recalibrations[1] = mon.max_recalibrations
    ev = mon.recalibrate_layer(1, "out", tick=2)
    assert ev["kind"] == "drift_sticky"
    assert not mon.layer_ok[1]


def test_rehoist_verify_raises_on_corrupt_tables(env):
    from repro.runtime.faults import FaultInjector

    eng, _ = _fresh(env)
    eng.rehoist(verify=True)  # clean bundle passes
    tabs = eng.pcilt["proj"]["tables"]
    tabs["wx"] = FaultInjector(seed=3).corrupt_table(tabs["wx"], n_flips=1)
    with pytest.raises(RuntimeError, match="integrity"):
        eng.rehoist(verify=True)


# ----------------------------------------------------------------------------
# Serving end to end: inject -> detect -> demote -> recalibrate -> repromote
# ----------------------------------------------------------------------------


def test_engine_drift_chaos_end_to_end(env):
    from repro.launch.serve import (DRIFT_LAYER, Engine, Request,
                                    _chaos_drift_plan)
    from repro.runtime.faults import FaultInjector

    cfg = env["cfg"]
    eng = Engine(cfg, max_len=64, slots=2, pcilt=True)
    assert eng.sentinel
    injector = FaultInjector(seed=0)
    eng.chaos = _chaos_drift_plan(eng, injector)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, cfg.vocab, size=6), max_new=4)
            for i in range(3)]
    stats = eng.run(reqs)
    assert all(r.outcome in ("served", "degraded") for r in reqs)
    events = stats["health_events"]
    demotions = [e for e in events if e["kind"] == "drift"]
    recals = [e for e in events if e["kind"] == "recalibrate"]
    assert demotions and all(e["layer"] == DRIFT_LAYER for e in demotions)
    assert recals, [e["kind"] for e in events]
    assert all(eng.monitor.layer_ok), "drifted layer was not repromoted"
    assert stats["recalibrations"] >= 1
    assert stats["rollbacks"] >= 1
    # the per-tick telemetry carries the sentinel block
    assert all("saturation" in t for t in stats["telemetry"])
    # drifted-range commits are marked: taint persists after recalibration
    assert stats["degraded"] >= 1
