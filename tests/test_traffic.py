"""Open-loop traffic harness + overload control (docs/serving.md).

The overload contract under test:

* arrival generators are seeded-deterministic and profile-shaped;
* the virtual clock makes every deadline/backoff/arrival path replayable;
* admission is bounded: queue-full and unmeetable-deadline arrivals are
  shed *at the door* with the typed ``rejected`` outcome;
* scheduling is EDF with backoff eligibility; deadlines are enforced on
  the queue as well as the slots (evictions counted separately);
* every request ends in exactly one outcome and the counts partition the
  offered set — no admitted request is ever silently dropped, faults and
  overload included.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import (Engine, Request, token_latencies,
                                verify_accounting)
from repro.runtime import (VirtualClock, WallClock, burst_arrivals,
                           make_arrivals, poisson_arrivals, ramp_arrivals)

STEP = 1e-3  # simulated seconds per engine step


def _cfg():
    return get_smoke_config("qwen3-0.6b")


def _engine(slots=2, **kw):
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("step_cost_s", STEP)
    return Engine(_cfg(), max_len=64, slots=slots, **kw)


def _reqs(cfg, n=3, max_new=4, deadline=None, seed=1, max_retries=2):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(3, 7)),
                    max_new, deadline_s=deadline, max_retries=max_retries)
            for i in range(n)]


# ---- clocks -----------------------------------------------------------------


def test_virtual_clock_advances_only_by_sleep():
    c = VirtualClock(start=5.0)
    assert c.time() == 5.0
    c.sleep(0.25)
    c.sleep(0)  # non-positive sleeps are no-ops, not time travel
    c.sleep(-1)
    assert c.time() == 5.25
    c.advance(0.75)
    assert c.time() == 6.0


def test_wall_clock_is_real_time():
    c = WallClock()
    t0 = c.time()
    c.sleep(0.01)
    assert c.time() - t0 >= 0.009


# ---- arrival generators -----------------------------------------------------


def test_poisson_arrivals_seeded_and_monotone():
    a = poisson_arrivals(100, rate=50.0, seed=7)
    b = poisson_arrivals(100, rate=50.0, seed=7)
    assert a.shape == (100,)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0) and a[0] > 0
    # mean inter-arrival ~ 1/rate (loose: 100 samples)
    assert 0.5 / 50.0 < np.diff(a).mean() < 2.0 / 50.0
    c = poisson_arrivals(100, rate=50.0, seed=8)
    assert not np.array_equal(a, c)
    off = poisson_arrivals(10, rate=50.0, seed=7, t0=100.0)
    np.testing.assert_allclose(off, a[:10] + 100.0)


def test_burst_arrivals_groups():
    a = burst_arrivals(10, rate=40.0, burst=4, seed=0)
    assert a.shape == (10,)
    assert np.all(np.diff(a) >= 0)
    # first group: 4 simultaneous arrivals; trailing partial group allowed
    assert a[0] == a[1] == a[2] == a[3] < a[4]


def test_ramp_arrivals_accelerate():
    a = ramp_arrivals(400, rate=20.0, seed=3)  # ramps to 2x by default
    gaps = np.diff(a)
    assert np.all(gaps >= 0)
    assert gaps[:100].mean() > gaps[-100:].mean()  # later arrivals come faster


def test_make_arrivals_dispatch_and_errors():
    np.testing.assert_array_equal(make_arrivals("poisson", 5, 10.0, seed=1),
                                  poisson_arrivals(5, 10.0, seed=1))
    with pytest.raises(ValueError, match="profile"):
        make_arrivals("tsunami", 5, 10.0)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(5, rate=0.0)


# ---- admission control ------------------------------------------------------


def test_queue_full_sheds_typed_rejected():
    eng = _engine(slots=1, queue_limit=1)
    reqs = _reqs(eng.cfg, n=5, max_new=3)
    stats = eng.run(reqs)
    verify_accounting(reqs, stats)
    assert stats["rejected"] >= 2  # 1 active + 1 queued admitted at the door
    assert stats["served"] + stats["rejected"] == 5
    for r in reqs:
        if r.outcome == "rejected":
            assert r.done and r.out == [] and r.t_admit == 0.0
    assert stats["shed_rate"] == stats["rejected"] / 5


def test_estimated_service_time_rejects_unmeetable_deadline():
    eng = _engine(slots=1, queue_limit=100)
    eng._tick_ema = STEP  # a tick has been observed
    eng.queue = list(_reqs(eng.cfg, n=4, max_new=50, seed=2))  # deep backlog
    doomed = Request(99, np.array([3, 4, 5]), 4, deadline_s=STEP)
    assert eng._submit(doomed, now=eng.clock.time()) is False
    assert doomed.outcome == "rejected" and doomed.done
    # same deadline with no backlog estimate yet: admit (never reject blind)
    eng2 = _engine(slots=1)
    fine = Request(1, np.array([3, 4, 5]), 4, deadline_s=STEP)
    assert eng2._submit(fine, now=eng2.clock.time()) is True
    assert fine.outcome == "queued" and not fine.done


# ---- scheduling -------------------------------------------------------------


def test_edf_pick_orders_by_deadline_with_fifo_tiebreak():
    eng = _engine()
    a = Request(0, np.array([3]), 2, deadline_s=None)
    b = Request(1, np.array([3]), 2, deadline_s=5.0)
    c = Request(2, np.array([3]), 2, deadline_s=1.0)
    d = Request(3, np.array([3]), 2, deadline_s=1.0)
    for i, r in enumerate((a, b, c, d)):
        r.t_enqueue = 0.0
    eng.queue = [a, b, c, d]
    assert eng._edf_pick(now=0.0) == 2  # earliest deadline; FIFO beats d
    c.not_before = 10.0  # backing off: ineligible
    assert eng._edf_pick(now=0.0) == 3
    d.not_before = 10.0
    assert eng._edf_pick(now=0.0) == 1
    b.not_before = 10.0
    assert eng._edf_pick(now=0.0) == 0  # no-deadline request sorts last
    a.not_before = 10.0
    assert eng._edf_pick(now=0.0) is None


# ---- deadline enforcement (queue side) --------------------------------------


def test_enforce_deadlines_scans_the_queue_too():
    """The eviction pass must cover queued requests, not just active slots:
    a queued request past its attempt window is evicted *there* (counted in
    ``queue_evictions``), without ever burning prefill ticks."""
    eng = _engine(slots=1)
    eng.clock.sleep(1.0)  # now = 1.0
    dead = Request(0, np.array([3]), 2, deadline_s=0.1, max_retries=0)
    dead.t_enqueue = 0.0  # attempt window long expired
    retry = Request(1, np.array([3]), 2, deadline_s=0.1, max_retries=3)
    retry.t_enqueue = 0.0
    fresh = Request(2, np.array([3]), 2, deadline_s=10.0)
    fresh.t_enqueue = 1.0
    eng.queue = [dead, retry, fresh]
    eng._enforce_deadlines()
    assert dead.outcome == "failed" and dead.done and dead.t_admit == 0.0
    assert eng.queue == [retry, fresh]  # retry requeued, fresh untouched
    assert retry.retries == 1 and retry.not_before > 1.0
    assert retry.t_enqueue == retry.not_before  # window opens post-backoff
    assert fresh.retries == 0
    assert eng.queue_evictions == 2 and eng.slot_evictions == 0


def test_retry_exhaustion_while_the_only_slot_is_busy():
    """Bounded retries must exhaust (typed ``failed``) across *both*
    eviction paths: EDF runs the doomed deadline request first, the slot
    evicts it mid-decode, and its post-backoff attempt expires on the queue
    while the long-running neighbor owns the engine."""
    eng = _engine(slots=1)
    hog = _reqs(eng.cfg, n=1, max_new=100, seed=4)[0]
    doomed = Request(7, np.array([3, 4]), 8, deadline_s=4 * STEP,
                     max_retries=1)
    stats = eng.run([hog, doomed])
    verify_accounting([hog, doomed], stats)
    assert doomed.outcome == "failed"
    assert doomed.retries == doomed.max_retries + 1  # bounded, then failed
    assert hog.outcome == "served"
    assert stats["slot_evictions"] == 1  # first attempt died in the slot
    assert stats["queue_evictions"] == 1  # second never got one


def test_all_queued_backing_off_takes_idle_tick():
    """Active slots empty + every queued request in backoff must idle the
    clock forward (never spin, never deadlock) until a backoff expires."""
    eng = _engine(slots=1)
    # service needs ~prompt+max_new ticks > deadline: the only request is
    # slot-evicted, requeued with a 50ms backoff — and the engine is then
    # empty except for that backing-off request, which is the idle branch
    lone = Request(0, np.array([3, 4, 5, 6]), 8, deadline_s=6 * STEP,
                   max_retries=1)
    t0 = eng.clock.time()
    stats = eng.run([lone])
    verify_accounting([lone], stats)
    assert lone.outcome == "failed" and lone.retries == 2
    assert stats["slot_evictions"] >= 1
    # the 50ms backoff dwarfs simulated service time: the idle branch must
    # have slept the virtual clock through it, with no decode ticks between
    # the eviction and the retry window
    assert eng.clock.time() - t0 >= 0.05
    ts = [e["t"] for e in stats["telemetry"]]
    assert max(np.diff(ts)) >= 0.04


# ---- open loop --------------------------------------------------------------


def test_run_traffic_gates_on_arrival_times():
    eng = _engine(slots=2)
    reqs = _reqs(eng.cfg, n=3, max_new=3, seed=6)
    arrivals = [0.5, 1.0, 1.5]
    stats = eng.run_traffic(reqs, arrivals)
    verify_accounting(reqs, stats)
    assert all(r.outcome == "served" for r in reqs)
    for r, t in zip(reqs, arrivals):
        assert r.t_arrive == t  # never seen before its arrival
        assert r.t_done > t
    assert stats["wall_s"] >= 1.5 - eng.clock.time() * 0  # ran past last arrival
    lats = token_latencies(reqs)
    assert len(lats) == 3 and all(l > 0 for l in lats)


def test_run_traffic_rejects_mismatched_trace():
    eng = _engine()
    with pytest.raises(ValueError, match="arrival"):
        eng.run_traffic(_reqs(eng.cfg, n=2), [0.0])


def test_run_traffic_deterministic_on_virtual_clock():
    outs = []
    for _ in range(2):
        eng = _engine(slots=2, queue_limit=2)
        reqs = _reqs(eng.cfg, n=6, max_new=3, seed=7)
        arrivals = poisson_arrivals(6, rate=60.0, seed=7)
        stats = eng.run_traffic(reqs, arrivals)
        verify_accounting(reqs, stats)
        outs.append(([tuple(r.out) for r in reqs],
                     [r.outcome for r in reqs],
                     stats["decode_ticks"], stats["rejected"]))
    assert outs[0] == outs[1]


def test_telemetry_records_backpressure():
    eng = _engine(slots=1, queue_limit=4)
    reqs = _reqs(eng.cfg, n=4, max_new=3, seed=8)
    stats = eng.run_traffic(reqs, [0.0] * 4)  # burst: all at once
    tel = stats["telemetry"]
    assert tel and tel == eng.telemetry
    for e in tel:
        assert set(e) >= {"tick", "t", "queue_depth", "pending",
                          "active_slots", "occupancy", "queue_evictions",
                          "slot_evictions", "tick_s"}
    assert max(e["queue_depth"] for e in tel) >= 1  # backlog was visible
    assert tel[-1]["queue_depth"] == 0
    assert [e["tick"] for e in tel] == sorted(e["tick"] for e in tel)


def test_pending_arrivals_survive_fault_restore():
    """A restore must rewind *pending arrivals* too: requests that arrived
    after the checkpoint are re-admitted on replay, not lost."""
    boom = {"n": 0}

    def fault(e):
        boom["n"] += 1
        raise RuntimeError("injected mid-stream fault")

    eng = _engine(slots=1, chaos={6: [fault]})
    reqs = _reqs(eng.cfg, n=3, max_new=3, seed=9)
    arrivals = [0.0, 2 * STEP, 20 * STEP]  # last arrives near the fault
    stats = eng.run_traffic(reqs, arrivals)
    verify_accounting(reqs, stats)
    assert boom["n"] == 1 and stats["restarts"] == 1
    assert all(r.outcome == "served" for r in reqs)


def test_accounting_verifier_trips_on_lost_request():
    eng = _engine(slots=1)
    reqs = _reqs(eng.cfg, n=2, max_new=3, seed=10)
    stats = eng.run(reqs)
    reqs[0].outcome = "queued"  # simulate a silently dropped request
    with pytest.raises(SystemExit, match="accounting"):
        verify_accounting(reqs, stats)
    reqs[0].outcome = "served"
    bad = dict(stats, rejected=stats["rejected"] + 1)
    with pytest.raises(SystemExit, match="accounting"):
        verify_accounting(reqs, bad)
