"""Paired multi-scalar (TL1-style) PCILT tables: ``[G/2, V^2, O]``.

Covers the PR 8 tentpole and the carried fused-path bugfixes:

* **bit-exactness sweep** — the paired build pre-sums each adjacent segment
  pair into one double-wide table entry, so on an exact-arithmetic grid
  (integer weights, power-of-two scale: every table entry is a dyadic
  rational and every f32 summation order is exact) the paired fetch must
  equal the unpaired fetch *bit for bit* — across V ∈ {2, 4}, odd and even
  G (the odd case pads a phantom segment whose table column is exactly
  zero), f32 and bf16 tables, batch ∈ {1, 4};
* the **seg-major stacked** kernel (``[G2, L, V^2, O]``, layer folded into
  the value axis under scalar prefetch) against per-layer unstacked fetches;
* full paired **decode vs the fake-quant dense oracle** and vs the unpaired
  engine, through ``convert_mamba_decode(paired=True)``;
* the ``fused_gemv_paired*`` **autotune-key contract**: keys carry the
  paired-space G and V, warm caches dispatch with zero timing runs, and a
  failed tune records strict-JSON ``us: null``;
* **generalized SegmentPlans on the fused path** (bugfix: previously a
  hard raise) — the in-VMEM plan gather vs the host ``plan.pack()`` paths,
  including skipped (-1) and reused positions;
* **scalar-level SharedTables** (bugfix: previously ``materialize()`` +
  gather) — routed through the 1-wide segment pool on both ``gather`` and
  ``shared`` paths, dense tables never expanded in HBM;
* slow-marked **multi-shard paired decode parity** at model ∈ {2, 4}
  (seg-axis-0 sharded stacks, one psum per step).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORCE_FLAG = "--xla_force_host_platform_device_count=8"


def _device_count() -> int:
    import jax

    return jax.device_count()


MULTI = _device_count() >= 8
multi_device = pytest.mark.skipif(
    not MULTI,
    reason="needs 8 forced host devices (re-run via the subprocess wrapper)",
)

RNG = np.random.default_rng(11)


@pytest.fixture
def tune_cache(tmp_path):
    from repro.kernels import autotune as atn

    path = str(tmp_path / "tiles.json")
    atn.reset_cache(path)
    atn.TIMING_RUNS = 0
    yield path
    atn.TIMING_RUNS = 0
    atn.reset_cache()


# ----------------------------------------------------------------------------
# Builder arithmetic + bit-exactness vs the unpaired tables
# ----------------------------------------------------------------------------


def _exact_problem(bits, group, G_dense, O, batch):
    """Integer weights on a power-of-two scale: exact arithmetic, so the
    paired and unpaired summation orders must agree bit-for-bit."""
    import jax.numpy as jnp
    from repro.core import QuantSpec

    n = G_dense * group
    w = jnp.asarray(RNG.integers(-2, 3, size=(n, O)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(batch, n)), jnp.float32)
    # 1-bit symmetric is rejected (no zero-straddling 2-value grid)
    spec = QuantSpec(bits=bits, symmetric=bits > 1)
    return w, x, spec, jnp.float32(0.5)


def test_paired_entry_is_sum_of_the_pair():
    """T2[s, e + o*V] == T[2s, e] + T[2s+1, o] — the little-endian pair
    index matching the fused kernels' ``_pack_flat`` shift-or."""
    import jax.numpy as jnp
    from repro.core import QuantSpec
    from repro.core.pcilt import build_grouped_tables, build_paired_tables

    bits, group, O = 2, 2, 6
    w, _, spec, scale = _exact_problem(bits, group, G_dense=4, O=O, batch=1)
    V = 1 << (bits * group)
    t = build_grouped_tables(w, spec, scale, group)     # [4, V, O]
    t2 = build_paired_tables(w, spec, scale, group)     # [2, V^2, O]
    assert t2.shape == (2, V * V, O)
    for s in range(2):
        for e in range(V):
            for o in range(V):
                np.testing.assert_array_equal(
                    np.asarray(t2[s, e + o * V]),
                    np.asarray(t[2 * s, e] + t[2 * s + 1, o]))


@pytest.mark.parametrize("batch", [1, 4])
@pytest.mark.parametrize("table_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("G_dense", [5, 6])  # odd G exercises the phantom
@pytest.mark.parametrize("bits,group", [(1, 1), (2, 1), (1, 2)])  # V ∈ {2,4}
def test_paired_matches_unpaired_bit_exact(tune_cache, bits, group, G_dense,
                                           table_dtype, batch):
    import jax.numpy as jnp
    from repro.core.lut_layers import pcilt_linear
    from repro.core.pcilt import build_grouped_tables, build_paired_tables

    w, x, spec, scale = _exact_problem(bits, group, G_dense, O=8, batch=batch)
    dt = jnp.dtype(table_dtype)
    # integer-valued entries scaled by 0.5 are exactly representable in bf16
    t_u = build_grouped_tables(w, spec, scale, group).astype(dt)
    t_p = build_paired_tables(w, spec, scale, group).astype(dt)
    out_u = pcilt_linear(x, t_u, spec, scale, group, path="fused")
    out_p = pcilt_linear(x, t_p, spec, scale, group, path="fused",
                         paired=True)
    np.testing.assert_array_equal(np.asarray(out_u), np.asarray(out_p))
    # the reference paths agree too (gather runs the paired layout as a
    # plain 2*group-wide grouped fetch)
    out_g = pcilt_linear(x, t_p, spec, scale, group, path="gather",
                         paired=True)
    np.testing.assert_array_equal(np.asarray(out_u), np.asarray(out_g))


def test_odd_g_phantom_column_is_exactly_zero():
    """Odd G pads a phantom segment: the last paired table must be constant
    along the phantom (odd) half of the pair index — the phantom's
    contribution is exactly zero for every code."""
    import jax.numpy as jnp
    from repro.core.pcilt import build_paired_tables

    bits, group, O = 2, 2, 4
    w, _, spec, scale = _exact_problem(bits, group, G_dense=5, O=O, batch=1)
    V = 1 << (bits * group)
    t2 = build_paired_tables(w, spec, scale, group)
    assert t2.shape[0] == 3  # ceil(5 / 2)
    last = np.asarray(t2[-1]).reshape(V, V, O)  # [off_odd, off_even, O]
    for o in range(1, V):
        np.testing.assert_array_equal(last[o], last[0])


# ----------------------------------------------------------------------------
# Seg-major stacked kernel
# ----------------------------------------------------------------------------


def _stacked_paired_problem(L=3, n=24, O=16, B=4, bits=2, group=2):
    import jax.numpy as jnp
    from repro.core import QuantSpec
    from repro.core.pcilt import build_paired_stacked_tables

    spec = QuantSpec(bits=bits, symmetric=True)
    ws = jnp.asarray(RNG.normal(size=(L, n, O)), jnp.float32)
    scales = jnp.asarray(0.1 + 0.05 * np.arange(L), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, n)), jnp.float32)
    tabs = build_paired_stacked_tables(ws, spec, scales, group)
    return x, ws, tabs, scales, spec, group


def test_paired_stacked_matches_unstacked_per_layer(tune_cache):
    """The seg-major stack fetches the identical table rows as the per-layer
    paired tables — same entries, same summation order, bit-equal."""
    from repro.core.pcilt import build_paired_tables
    from repro.kernels import ops

    x, ws, tabs, scales, spec, group = _stacked_paired_problem()
    for l in range(tabs.shape[1]):
        t_l = build_paired_tables(ws[l], spec, scales[l], group)
        want = ops.pcilt_fused_gemv_paired(x, t_l, spec, scales[l], group)
        got = ops.pcilt_fused_gemv_paired_stacked(x, tabs, l, spec,
                                                  scales[l], group)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paired_stacked_reference_path_matches_fused(tune_cache):
    from repro.core.lut_layers import pcilt_linear

    x, ws, tabs, scales, spec, group = _stacked_paired_problem()
    for l in range(tabs.shape[1]):
        got = pcilt_linear(x, tabs, spec, scales[l], group, path="fused",
                           paired=True, stacked=l)
        ref = pcilt_linear(x, tabs, spec, scales[l], group, path="gather",
                           paired=True, stacked=l)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ----------------------------------------------------------------------------
# Full paired decode vs the fake-quant dense oracle
# ----------------------------------------------------------------------------

BITS, GROUP = 2, 2


def _pcilt_cfg():
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig

    cfg = get_smoke_config("mamba2-130m")
    return dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=BITS,
                                                      group=GROUP),
                               dtype=jnp.float32)


@pytest.fixture(scope="module")
def paired_problem(tmp_path_factory):
    """One smoke MambaLM converted both paired and unpaired (the table
    builds and calibration prefill run once per module)."""
    import jax
    from repro.core.serving import convert_mamba_decode
    from repro.kernels import autotune as atn
    from repro.models import build_model
    from repro.nn import materialize
    from repro.nn.layers import Ctx

    atn.reset_cache(str(tmp_path_factory.mktemp("tune") / "tiles.json"))
    cfg = _pcilt_cfg()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = materialize(model.param_specs(), key)
    ctx = Ctx()
    calib = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    eng_u = convert_mamba_decode(model, params, calib)
    eng_p = convert_mamba_decode(model, params, calib, paired=True)
    yield {"cfg": cfg, "model": model, "params": params, "ctx": ctx,
           "calib": calib, "eng_u": eng_u, "eng_p": eng_p, "key": key}
    atn.reset_cache()


def _prefill(pb, B):
    import jax

    model, params, ctx = pb["model"], pb["params"], pb["ctx"]
    toks = jax.random.randint(pb["key"], (B, 16), 0, pb["cfg"].vocab)
    _, cache = model.prefill(params, {"tokens": toks}, ctx)
    tok = jax.random.randint(jax.random.fold_in(pb["key"], 1), (B, 1), 0,
                             pb["cfg"].vocab)
    return cache, tok


def test_paired_bundle_layout(paired_problem):
    from repro.nn.ssm import PROJ_NAMES

    pb = paired_problem
    proj = pb["eng_p"].pcilt["proj"]
    assert proj["paired"] is True
    L = pb["cfg"].n_layers
    V2 = 1 << (2 * BITS * GROUP)
    for name in PROJ_NAMES:
        t = proj["tables"][name]
        assert t.ndim == 4 and t.shape[1] == L and t.shape[2] == V2
        # half the fetch count of the dense stack for the same projection
        t_u = pb["eng_u"].pcilt["proj"]["tables"][name]
        assert t.shape[0] == -(-t_u.shape[1] // 2)


@pytest.mark.parametrize("batch", [1, 4])
def test_paired_decode_matches_fakequant_oracle(paired_problem, batch):
    import jax

    pb = paired_problem
    model, params, ctx = pb["model"], pb["params"], pb["ctx"]
    eng = pb["eng_p"]
    cache, tok = _prefill(pb, batch)
    logits, nc = eng.step(params, cache, tok)
    oracle_pc = dict(eng.pcilt, proj=dict(eng.pcilt["proj"],
                                          path="dense_fq"))
    l_oracle, nc_o = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx, pcilt=oracle_pc)
    )(params, cache, tok)
    assert logits.shape == (batch, pb["cfg"].padded_vocab)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(l_oracle),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(nc["layers"]["ssd"]),
                               np.asarray(nc_o["layers"]["ssd"]),
                               rtol=2e-4, atol=2e-4)
    assert int(nc["pos"]) == int(nc_o["pos"])


def test_paired_decode_matches_unpaired(paired_problem):
    pb = paired_problem
    cache, tok = _prefill(pb, 2)
    l_u, _ = pb["eng_u"].step(pb["params"], cache, tok)
    l_p, _ = pb["eng_p"].step(pb["params"], cache, tok)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_u),
                               rtol=2e-4, atol=2e-4)


def test_paired_integrity_localizes_per_layer(paired_problem):
    """Seg-major stacks checksum along axis 1 — a single flipped entry in
    one layer's slice is caught at that layer and no other."""
    import jax.numpy as jnp

    pb = paired_problem
    eng = pb["eng_p"]
    assert eng.verify_integrity() == []
    orig = eng.pcilt["proj"]["tables"]["wx"]
    t = np.asarray(orig).copy()
    t[0, 1, 3, 0] += 1.0
    eng.pcilt["proj"]["tables"]["wx"] = jnp.asarray(t)
    try:
        assert ("wx", 1) in eng.verify_layer(1)
        assert eng.verify_layer(0) == []
    finally:
        eng.pcilt["proj"]["tables"]["wx"] = orig
    assert eng.verify_integrity() == []


# ----------------------------------------------------------------------------
# fused_gemv_paired* autotune-key contract
# ----------------------------------------------------------------------------


def test_paired_keys_carry_paired_space_dims(tune_cache):
    """Keys record the staged operand's geometry: segment *pairs* and the
    squared cardinality — and a warm cache dispatches with zero timing."""
    from repro.core.pcilt import build_paired_tables
    from repro.kernels import autotune as atn
    from repro.kernels import ops

    w, x, spec, scale = _exact_problem(2, GROUP, G_dense=6, O=8, batch=4)
    t_p = build_paired_tables(w, spec, scale, GROUP)
    G2, V2 = t_p.shape[0], t_p.shape[1]
    ops.pcilt_fused_gemv_paired(x, t_p, spec, scale, GROUP, autotune=True)
    entries = json.load(open(tune_cache))
    keys = [k for k in entries if k.startswith("fused_gemv_paired|")]
    assert len(keys) == 1
    assert f"G={G2}," in keys[0] and f"V={V2}," in keys[0]
    assert f"g={GROUP}" in keys[0] and "bits=2" in keys[0]
    atn.reset_cache(tune_cache)
    atn.TIMING_RUNS = 0
    ops.pcilt_fused_gemv_paired(x, t_p, spec, scale, GROUP, autotune=True)
    assert atn.TIMING_RUNS == 0


def test_paired_stacked_key_carries_L(tune_cache):
    from repro.kernels import ops

    x, ws, tabs, scales, spec, group = _stacked_paired_problem(L=3)
    ops.pcilt_fused_gemv_paired_stacked(x, tabs, 0, spec, scales[0], group,
                                        autotune=True)
    entries = json.load(open(tune_cache))
    key = next(k for k in entries
               if k.startswith("fused_gemv_paired_stacked|"))
    assert "L=3," in key and f"G={tabs.shape[0]}," in key
    assert f"V={tabs.shape[2]}," in key


def test_paired_failed_tune_records_null(tune_cache, monkeypatch):
    """All candidates failing must still record strict JSON (``us: null``)
    and dispatch via the heuristic fallback."""
    from repro.kernels import autotune as atn
    from repro.kernels import ops

    def boom(fn, reps, warmup):
        raise RuntimeError("no candidate can run")

    monkeypatch.setattr(atn, "_time_one", boom)
    w, x, spec, scale = _exact_problem(2, GROUP, G_dense=6, O=8, batch=4)
    from repro.core.pcilt import build_paired_tables

    t_p = build_paired_tables(w, spec, scale, GROUP)
    out = ops.pcilt_fused_gemv_paired(x, t_p, spec, scale, GROUP,
                                      autotune=True)
    assert out.shape == (x.shape[0], t_p.shape[-1])
    raw = open(tune_cache).read()
    assert "NaN" not in raw
    entries = json.loads(raw)
    key = next(k for k in entries if k.startswith("fused_gemv_paired|"))
    assert entries[key]["us"] is None and entries[key]["candidates"] == 0


def test_paired_rejects_plan_shared_pool_and_shared_path(tune_cache):
    import jax.numpy as jnp
    from repro.core import QuantSpec
    from repro.core.lut_layers import pcilt_linear
    from repro.core.offsets import SegmentPlan
    from repro.core.pcilt import build_paired_tables

    w, x, spec, scale = _exact_problem(2, GROUP, G_dense=4, O=8, batch=2)
    t_p = build_paired_tables(w, spec, scale, GROUP)
    with pytest.raises(ValueError, match="plan"):
        pcilt_linear(x, t_p, spec, scale, GROUP, paired=True,
                     plan=SegmentPlan.contiguous(4, GROUP))
    with pytest.raises(ValueError, match="shared"):
        pcilt_linear(x, t_p, spec, scale, GROUP, paired=True, path="shared")


# ----------------------------------------------------------------------------
# Bugfix: generalized SegmentPlans run on the fused path
# ----------------------------------------------------------------------------


def test_plan_fused_matches_packed_reference(tune_cache):
    """A plan with a skipped slot (-1) and a reused position executes fused
    via the in-VMEM plan gather and matches the host plan.pack() paths."""
    import jax.numpy as jnp
    from repro.core import QuantSpec
    from repro.core.lut_layers import pcilt_linear
    from repro.core.offsets import SegmentPlan
    from repro.core.pcilt import build_grouped_tables

    spec = QuantSpec(bits=BITS, symmetric=True)
    scale = jnp.float32(0.25)
    # 3 segments over a 5-wide input: position 2 reused, one slot unused
    plan = SegmentPlan(index=np.asarray(
        [[0, 1], [2, -1], [2, 3]], np.int32))
    w = jnp.asarray(RNG.normal(size=(5, 8)), jnp.float32)
    tables = build_grouped_tables(w, spec, scale, GROUP, plan=plan)
    x = jnp.asarray(RNG.normal(size=(3, 5)), jnp.float32)
    out_f = pcilt_linear(x, tables, spec, scale, GROUP, plan=plan,
                         path="fused")
    out_g = pcilt_linear(x, tables, spec, scale, GROUP, plan=plan,
                         path="gather")
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_g),
                               rtol=1e-6, atol=1e-6)


def test_plan_grid_mismatch_raises(tune_cache):
    import jax.numpy as jnp
    from repro.core import QuantSpec
    from repro.core.lut_layers import pcilt_linear
    from repro.core.offsets import SegmentPlan
    from repro.core.pcilt import build_grouped_tables

    spec = QuantSpec(bits=BITS, symmetric=True)
    w = jnp.asarray(RNG.normal(size=(8, 8)), jnp.float32)
    tables = build_grouped_tables(w, spec, jnp.float32(0.25), GROUP)
    x = jnp.asarray(RNG.normal(size=(2, 8)), jnp.float32)
    with pytest.raises(ValueError, match="plan grid"):
        # 3 segments vs the tables' 4
        pcilt_linear(x, tables, spec, jnp.float32(0.25), GROUP,
                     plan=SegmentPlan.contiguous(6, GROUP), path="fused")


# ----------------------------------------------------------------------------
# Bugfix: scalar SharedTables execute via the 1-wide segment pool
# ----------------------------------------------------------------------------


def test_scalar_shared_tables_route_through_pool(tune_cache):
    """A scalar-level SharedTables passed to pcilt_linear executes through
    ``as_grouped_pool()`` (the fused shared kernel / pointer gather) and
    matches the materialize() dense oracle on both paths."""
    import jax.numpy as jnp
    from repro.core import QuantSpec, fake_quant
    from repro.core.lut_layers import pcilt_linear
    from repro.core.pcilt import build_shared_tables

    spec = QuantSpec(bits=BITS, symmetric=True)
    scale = jnp.float32(0.25)
    # low-cardinality weights: the dedup regime the pool targets
    w = jnp.asarray(RNG.integers(-1, 2, size=(12, 8)), jnp.float32)
    st = build_shared_tables(w, spec, scale)
    x = jnp.asarray(RNG.normal(size=(3, 12)), jnp.float32)
    want = fake_quant(x, spec, scale) @ w
    for path in ("gather", "shared"):
        got = pcilt_linear(x, st, spec, scale, group=1, path=path)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # the pool is built once and cached on the instance
    assert st._grouped is not None
    assert st.as_grouped_pool() is st._grouped
    assert st._grouped.group == 1


# ----------------------------------------------------------------------------
# Multi-shard paired parity (slow tier: 8 forced host devices)
# ----------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running with forced devices")
def test_paired_parity_reruns_with_forced_devices(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["REPRO_PCILT_TUNE_CACHE"] = str(tmp_path / "tiles.json")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x",
         os.path.abspath(__file__), "-m", "slow or not slow"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, (
        f"paired parity suite failed under {FORCE_FLAG}:\n"
        f"{r.stdout}\n{r.stderr}")


@pytest.mark.slow
@multi_device
@pytest.mark.parametrize("model_shards", [2, 4])
def test_paired_decode_sharded_matches_single_device(
        paired_problem, tune_cache, model_shards):
    """Seg-major paired stacks shard on axis 0 (segment pairs) over the
    model axis — one psum per step — and match the single-device engine;
    the shard-local tunes record under the local ``G2/D`` key."""
    from repro.core.serving import convert_mamba_decode
    from repro.launch.mesh import make_decode_mesh

    pb = paired_problem
    model, params = pb["model"], pb["params"]
    cache, tok = _prefill(pb, 1)
    l_ref, nc_ref = pb["eng_p"].step(params, cache, tok)

    mesh = make_decode_mesh(model_shards)
    eng_m = convert_mamba_decode(model, params, pb["calib"], mesh=mesh,
                                 paired=True)
    eng_m.tune(batch=1)
    proj = eng_m.pcilt["proj"]
    assert proj["paired"] is True
    G2 = proj["tables"]["wz"].shape[0]
    entries = json.load(open(tune_cache))
    assert any(k.startswith("fused_gemv_paired_stacked|")
               and f"G={G2 // model_shards}," in k for k in entries), \
        "tune must record the local shard's paired-space G"
    l_m, nc_m = eng_m.step(params, cache, tok)
    np.testing.assert_allclose(np.asarray(l_m), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(nc_m["layers"]["ssd"]),
                               np.asarray(nc_ref["layers"]["ssd"]),
                               rtol=2e-4, atol=2e-4)
