"""End-to-end launcher integration: the train loop learns + survives injected
faults; the serve engine completes request streams; the PCILT serving path
matches the dense path on the quantized grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.launch import serve as serve_mod


def test_train_loop_loss_decreases(tmp_path, capsys):
    train_mod.main([
        "--arch", "qwen3-0.6b", "--steps", "30", "--seq", "64",
        "--batch", "4", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path / "ck"), "--log-every", "5",
    ])
    out = capsys.readouterr().out
    losses = [float(l.split("loss")[1].split()[0])
              for l in out.splitlines() if l.startswith("step")]
    assert len(losses) >= 4
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses}"


def test_train_loop_survives_fault(tmp_path, capsys):
    train_mod.main([
        "--arch", "qwen2.5-3b", "--steps", "30", "--seq", "32",
        "--batch", "4", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "10", "--fail-at", "15", "--log-every", "10",
    ])
    out = capsys.readouterr().out
    assert "restored checkpoint at step 10" in out
    assert "restarts=1" in out


def test_serve_engine_completes(capsys):
    serve_mod.main(["--arch", "qwen3-0.6b", "--requests", "3",
                    "--max-new", "4", "--slots", "2"])
    out = capsys.readouterr().out
    assert "served 3 requests" in out


def test_pcilt_decode_matches_dense_on_quantized_grid():
    """The paper's serving integration: a projection converted to grouped
    PCILTs fetches exactly what the dense matmul computes on quantized
    activations (per-layer exactness; the LM serving example composes it)."""
    from repro.core import QuantSpec, calibrate, quantize, dequantize
    from repro.core.serving import convert_kernel

    rng = np.random.default_rng(0)
    d, f = 64, 128
    kernel = jnp.asarray(rng.normal(size=(d, f)) * 0.1, jnp.float32)
    x = jnp.asarray(np.abs(rng.normal(size=(4, d))), jnp.float32)
    spec = QuantSpec(bits=4)
    scale = calibrate(x, spec)
    lin = convert_kernel(kernel, spec, scale, group=2)
    got = lin(x)
    xq = dequantize(quantize(x, spec, scale), spec, scale)
    np.testing.assert_allclose(got, xq @ kernel, rtol=1e-4, atol=1e-4)

    # with weight quantization (shared-PCILT precondition): both sides see
    # the same quantized weights -> still exact
    lin4 = convert_kernel(kernel, spec, scale, group=2, weight_bits=4)
    wspec = QuantSpec(bits=4, symmetric=True)
    wscale = calibrate(kernel, wspec)
    wq = dequantize(quantize(kernel, wspec, wscale), wspec, wscale)
    np.testing.assert_allclose(lin4(x), xq @ wq, rtol=1e-4, atol=1e-4)


def test_pcilt_mamba_conv_frontend():
    """DESIGN §6: the SSM depthwise conv frontend through the PCILT path."""
    from repro.core import QuantSpec, calibrate, pcilt_depthwise_conv1d, quantize, dequantize

    rng = np.random.default_rng(1)
    x = jnp.asarray(np.abs(rng.normal(size=(2, 32, 16))), jnp.float32)
    filt = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    spec = QuantSpec(bits=2)
    s = calibrate(x, spec)
    y = pcilt_depthwise_conv1d(x, filt, spec, s, path="kernel")
    xq = dequantize(quantize(x, spec, s), spec, s)
    pad = jnp.pad(xq, ((0, 0), (3, 0), (0, 0)))
    want = sum(pad[:, i:i + 32] * filt[i][None, None] for i in range(4))
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
