"""End-to-end launcher integration: the train loop learns + survives injected
faults; the serve engine completes request streams; the PCILT serving path
matches the dense path on the quantized grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.launch import serve as serve_mod


def test_train_loop_loss_decreases(tmp_path, capsys):
    train_mod.main([
        "--arch", "qwen3-0.6b", "--steps", "30", "--seq", "64",
        "--batch", "4", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path / "ck"), "--log-every", "5",
    ])
    out = capsys.readouterr().out
    losses = [float(l.split("loss")[1].split()[0])
              for l in out.splitlines() if l.startswith("step")]
    assert len(losses) >= 4
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses}"


def test_train_loop_survives_fault(tmp_path, capsys):
    train_mod.main([
        "--arch", "qwen2.5-3b", "--steps", "30", "--seq", "32",
        "--batch", "4", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "10", "--fail-at", "15", "--log-every", "10",
    ])
    out = capsys.readouterr().out
    assert "restored checkpoint at step 10" in out
    assert "restarts=1" in out


def test_serve_engine_completes(capsys):
    serve_mod.main(["--arch", "qwen3-0.6b", "--requests", "3",
                    "--max-new", "4", "--slots", "2"])
    out = capsys.readouterr().out
    assert "served 3 requests" in out


def test_pcilt_decode_matches_dense_on_quantized_grid():
    """The paper's serving integration: a projection converted to grouped
    PCILTs fetches exactly what the dense matmul computes on quantized
    activations (per-layer exactness; the LM serving example composes it)."""
    from repro.core import QuantSpec, calibrate, quantize, dequantize
    from repro.core.serving import convert_kernel

    rng = np.random.default_rng(0)
    d, f = 64, 128
    kernel = jnp.asarray(rng.normal(size=(d, f)) * 0.1, jnp.float32)
    x = jnp.asarray(np.abs(rng.normal(size=(4, d))), jnp.float32)
    spec = QuantSpec(bits=4)
    scale = calibrate(x, spec)
    lin = convert_kernel(kernel, spec, scale, group=2)
    got = lin(x)
    xq = dequantize(quantize(x, spec, scale), spec, scale)
    np.testing.assert_allclose(got, xq @ kernel, rtol=1e-4, atol=1e-4)

    # with weight quantization (shared-PCILT precondition): both sides see
    # the same quantized weights -> still exact
    lin4 = convert_kernel(kernel, spec, scale, group=2, weight_bits=4)
    wspec = QuantSpec(bits=4, symmetric=True)
    wscale = calibrate(kernel, wspec)
    wq = dequantize(quantize(kernel, wspec, wscale), wspec, wscale)
    np.testing.assert_allclose(lin4(x), xq @ wq, rtol=1e-4, atol=1e-4)


def test_pcilt_mamba_conv_frontend():
    """DESIGN §6: the SSM depthwise conv frontend through the PCILT paths —
    host-packed and fused both match the quantized-grid oracle."""
    from repro.core import QuantSpec, calibrate, pcilt_depthwise_conv1d, quantize, dequantize

    rng = np.random.default_rng(1)
    x = jnp.asarray(np.abs(rng.normal(size=(2, 32, 16))), jnp.float32)
    filt = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    spec = QuantSpec(bits=2)
    s = calibrate(x, spec)
    xq = dequantize(quantize(x, spec, s), spec, s)
    pad = jnp.pad(xq, ((0, 0), (3, 0), (0, 0)))
    want = sum(pad[:, i:i + 32] * filt[i][None, None] for i in range(4))
    for path in ("kernel", "fused"):
        y = pcilt_depthwise_conv1d(x, filt, spec, s, path=path)
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"path={path}")


def test_serving_conv_layers_cache_conversion(tmp_path, monkeypatch):
    """PCILTConv2d / PCILTDwConv1d hoist every per-call host cost into the
    offline conversion: tables are built exactly once, repeated calls hit
    the cached jitted executor, and outputs stay on the quantized grid."""
    from repro.core import QuantSpec, calibrate, quantize, dequantize
    from repro.core import lut_layers
    from repro.core.serving import convert_conv_kernel, convert_dwconv

    monkeypatch.setenv("REPRO_PCILT_TUNE_CACHE", str(tmp_path / "t.json"))
    rng = np.random.default_rng(2)
    spec = QuantSpec(bits=2)

    # conv2d: parity vs the quantized-grid dense conv on every path
    x = jnp.asarray(np.abs(rng.normal(size=(2, 8, 8, 3))), jnp.float32)
    f = jnp.asarray(rng.normal(size=(3, 3, 3, 5)) * 0.3, jnp.float32)
    s = calibrate(x, spec)
    conv = convert_conv_kernel(f, spec, s, group=2)
    xq = dequantize(quantize(x, spec, s), spec, s)
    want = jax.lax.conv_general_dilated(
        xq, f, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    for path in ("gather", "fused", "kernel"):
        np.testing.assert_allclose(np.asarray(conv(x, path=path)),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)
    assert set(conv._exec) == {"gather", "fused", "kernel"}  # jit cached

    # table build is offline-only: __call__ must not rebuild
    calls = []
    orig = lut_layers.build_grouped_tables
    monkeypatch.setattr(lut_layers, "build_grouped_tables",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    conv(x, path="fused")
    assert not calls, "serving call rebuilt tables per step"

    # dwconv: conversion builds [C, V] tables once; fused/kernel parity
    xt = jnp.asarray(np.abs(rng.normal(size=(2, 16, 6))), jnp.float32)
    ft = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    st = calibrate(xt, spec)
    dw = convert_dwconv(ft, spec, st)
    assert dw.tables.shape == (6, 2 ** (spec.bits * 4))
    ref = dw(xt, path="gather")
    for path in ("fused", "kernel"):
        np.testing.assert_allclose(np.asarray(dw(xt, path=path)),
                                   np.asarray(ref), rtol=1e-5, atol=1e-5)
    out = dw.tune(xt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_conv_same_pads_memoized():
    from repro.core.lut_layers import conv_same_pads

    conv_same_pads.cache_clear()
    a = conv_same_pads(14, 14, 5, 5, 2)
    b = conv_same_pads(14, 14, 5, 5, 2)
    assert a == b
    assert conv_same_pads.cache_info().hits >= 1


def test_ssm_conv1d_pcilt_matches_quantized_oracle(tmp_path, monkeypatch):
    """``nn.ssm._conv1d`` with PCILT tables — the exact integration point the
    decode scan dispatches — equals the tap-dot on fake-quantized inputs, in
    both the decode-window and full-sequence branches (the fetch is exact on
    the quantized grid, so quantization is the *only* difference)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig
    from repro.core import quantize, dequantize
    from repro.nn.ssm import _conv1d, build_pcilt_conv, mamba_spec
    from repro.nn import materialize

    monkeypatch.setenv("REPRO_PCILT_TUNE_CACHE", str(tmp_path / "t.json"))
    cfg = get_smoke_config("mamba2-130m")
    cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=4, group=2))
    params = materialize(mamba_spec(cfg), jax.random.PRNGKey(3))
    k = cfg.ssm.conv_kernel
    C = params["conv_w"].shape[1]
    rng = np.random.default_rng(4)
    scale = jnp.float32(0.1)
    pc = build_pcilt_conv(params, cfg, scale)
    spec = pc["spec"]

    def fq(v):
        return dequantize(quantize(v, spec, scale), spec, scale)

    # decode branch: assembled [B, k, C] window -> one output
    x1 = jnp.asarray(rng.normal(size=(2, 1, C)) * 0.3, jnp.float32)
    state = jnp.asarray(rng.normal(size=(2, k - 1, C)) * 0.3, jnp.float32)
    got, new_state = _conv1d(params, cfg, x1, conv_state=state, pcilt=pc)
    window = jnp.concatenate([state, x1], axis=1)
    want = jnp.einsum("bkc,kc->bc", fq(window), params["conv_w"])[:, None] \
        + params["conv_b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(new_state),
                                  np.asarray(window[:, 1:]))

    # full-sequence branch (prefill): causal over [B, T, C]
    xs = jnp.asarray(rng.normal(size=(2, 8, C)) * 0.3, jnp.float32)
    got_seq, _ = _conv1d(params, cfg, xs, pcilt=pc)
    pad = jnp.pad(fq(xs), ((0, 0), (k - 1, 0), (0, 0)))
    want_seq = sum(pad[:, i:i + 8] * params["conv_w"][i][None, None]
                   for i in range(k)) + params["conv_b"]
    np.testing.assert_allclose(np.asarray(got_seq), np.asarray(want_seq),
                               rtol=1e-4, atol=1e-4)


def test_mamba_decode_step_with_pcilt(tmp_path, monkeypatch):
    """End-to-end wiring: MambaLM.decode_step(pcilt=...) scans the stacked
    [L, C, V] tables alongside the parameters, advances the cache, and
    produces finite logits on the decode path."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig
    from repro.models import build_model
    from repro.nn import materialize
    from repro.nn.layers import Ctx

    monkeypatch.setenv("REPRO_PCILT_TUNE_CACHE", str(tmp_path / "t.json"))
    cfg = get_smoke_config("mamba2-130m")
    cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=4, group=2))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    params = materialize(model.param_specs(), key)
    ctx = Ctx()
    _, cache = model.prefill(params, {"tokens": jax.random.randint(
        key, (B, S), 0, cfg.vocab)}, ctx)
    pcilt = model.build_pcilt(params, jnp.float32(0.1))
    assert pcilt["tables"].shape[0] == cfg.n_layers
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, new_cache = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx, pcilt=pcilt)
    )(params, cache, tok)
    assert logits.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(new_cache["pos"]) == S + 1
