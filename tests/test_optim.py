"""Optimizer substrate: AdamW convergence, int8 moment fidelity, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig, adamw_init, adamw_init_specs, adamw_update, cosine_schedule,
    global_norm, clip_by_global_norm,
)
from repro.nn.module import ParamSpec


def _quad_problem():
    target = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]),
              "b": jnp.asarray([0.3, -0.7])}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))
    return params, loss


def _run(params, loss, cfg, steps=300):
    state = adamw_init(params, cfg)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(g, state, params, cfg)
    return params, loss(params)


def test_adamw_converges():
    params, loss = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    _, final = _run(params, loss, cfg)
    assert float(final) < 1e-3


def test_adamw_int8_moments_converge():
    params, loss = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, quantize_moments=True)
    _, final = _run(params, loss, cfg)
    assert float(final) < 5e-3  # int8 moments track fp32 closely


def test_int8_state_shapes_and_specs():
    params = {"w": jnp.zeros((8, 256)), "b": jnp.zeros((16,))}
    cfg = AdamWConfig(quantize_moments=True)
    st = adamw_init(params, cfg)
    assert st["m"]["w"]["q"].dtype == jnp.int8
    assert st["m"]["w"]["q"].shape == (8, 256)
    assert st["m"]["w"]["scale"].shape == (8, 1)
    specs = {"w": ParamSpec((8, 256), ("embed", "mlp")),
             "b": ParamSpec((16,), (None,))}
    sspecs = adamw_init_specs(specs, cfg)
    assert sspecs["v"]["w"]["q"].shape == (8, 256)
    assert sspecs["v"]["w"]["scale"].axes == ("embed", None)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(55))) < 1.0
    assert abs(float(lr(jnp.asarray(100))) - 0.1) < 1e-2


def test_clipping():
    tree = {"a": jnp.ones((4,)) * 10.0}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(n) > 1.0


def test_weight_decay_only_matrices():
    """Norms/bias (ndim<2) skip decay."""
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    st = adamw_init(params, cfg)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(zero_g, st, params, cfg)
    assert float(jnp.abs(new_p["w"] - 1.0).max()) > 1e-3  # decayed
    assert float(jnp.abs(new_p["b"] - 1.0).max()) < 1e-6  # untouched
