"""Static VMEM/grid verifier (repro.analysis.vmem): the shipped candidate
generators are proven in-budget without executing a kernel, and the verifier
is *sound* — shrinking the budget or seeding a broken BlockSpec makes it
reject."""

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import vmem
from repro.kernels import autotune as atn


def _rules(findings):
    return sorted({f.rule for f in findings})


# ----------------------------------------------------------------------------
# The shipped generators + kernels verify clean (the acceptance gate)
# ----------------------------------------------------------------------------


def test_shipped_generators_verify_clean_quick():
    fs = vmem.verify_all(sweep="quick")
    errors = [f for f in fs if f.severity == "error"]
    assert errors == [], "\n".join(f.render() for f in errors)


def test_every_family_is_covered():
    names = {f.name for f in vmem.FAMILIES()}
    assert names == {"gemv_host", "fused_gemv", "fused_gemv_stacked",
                     "fused_gemv_paired", "fused_gemv_paired_stacked",
                     "fused_gemv_plan", "conv2d_host", "fused_conv2d",
                     "shared_gemv", "shared_conv2d", "fused_dwconv1d",
                     "fused_gemv_stacked_sat", "fused_gemv_paired_sat",
                     "fused_gemv_paired_stacked_sat", "fused_dwconv1d_sat"}


def test_no_kernel_execution_happens(monkeypatch):
    # the verifier must stay abstract: poison timing and fail if any
    # candidate is ever *run* rather than traced
    def boom(*a, **k):  # pragma: no cover - failing path
        raise AssertionError("verifier executed a kernel")

    monkeypatch.setattr(atn, "tune", boom)
    monkeypatch.setattr(atn, "_time_one", boom)
    fs = vmem.verify_all(sweep="quick", families=["fused_gemv"])
    assert [f for f in fs if f.severity == "error"] == []


# ----------------------------------------------------------------------------
# Soundness: a shrunk budget must be rejected (the pass is not vacuous)
# ----------------------------------------------------------------------------


def test_shrunk_scratch_budget_rejects():
    fs = vmem.verify_all(sweep="quick", scratch_budget=1024)
    assert "VMEM001" in _rules(fs)
    msg = next(f for f in fs if f.rule == "VMEM001").message
    assert "SCRATCH_BUDGET" in msg and "_fit_scratch_gb" in msg


def test_shrunk_total_vmem_rejects_fallback(monkeypatch):
    monkeypatch.setattr(vmem, "TOTAL_VMEM_BUDGET", 1)
    fs = vmem.verify_all(sweep="quick", families=["fused_gemv"])
    rules = _rules(fs)
    assert "VMEM005" in rules, "fallback candidate must be VMEM-gated"
    assert "VMEM006" in rules, "tuned candidates get the warning variant"
    assert all(f.severity == "warning" for f in fs if f.rule == "VMEM006")


# ----------------------------------------------------------------------------
# Seeded broken kernels: bounds, coverage, and model-drift detection
# ----------------------------------------------------------------------------


def _trace_bad_pallas(in_index_map, out_index_map, grid=(4,)):
    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2

    def run(x):
        return pl.pallas_call(
            kern, grid=grid,
            in_specs=[pl.BlockSpec((8, 8), in_index_map)],
            out_specs=pl.BlockSpec((8, 8), out_index_map),
            out_shape=jax.ShapeDtypeStruct((32, 8), jnp.float32),
            interpret=True)(x)

    j = jax.make_jaxpr(run)(jax.ShapeDtypeStruct((32, 8), jnp.float32))
    return vmem._find_pallas_eqn(j.jaxpr)


def test_out_of_bounds_index_map_fires_vmem002():
    eqn = _trace_bad_pallas(lambda i: (i + 1, 0), lambda i: (i, 0))
    fs = vmem._check_blocks(vmem.FAMILIES()[0], "probe", eqn, None)
    assert "VMEM002" in _rules(fs)
    msg = next(f for f in fs if f.rule == "VMEM002").message
    assert "block 4 outside [0, 4)" in msg


def test_gapped_grid_walk_fires_vmem003():
    # output always writes block 0: 3 of 4 output blocks never visited
    eqn = _trace_bad_pallas(lambda i: (i, 0), lambda i: (0, 0))
    fs = vmem._check_blocks(vmem.FAMILIES()[0], "probe", eqn, None)
    assert "VMEM003" in _rules(fs)
    assert any("never visited" in f.message for f in fs)


def test_correct_tiling_is_clean():
    eqn = _trace_bad_pallas(lambda i: (i, 0), lambda i: (i, 0))
    fs = vmem._check_blocks(vmem.FAMILIES()[0], "probe", eqn, None)
    assert fs == []


def test_witness_search_detects_model_drift():
    eqn = _trace_bad_pallas(lambda i: (i, 0), lambda i: (i, 0))
    assert vmem._has_witness(eqn, [(8, 8)])          # the staged block shape
    assert not vmem._has_witness(eqn, [(3, 3)])      # a shape the body lacks


def test_prefetch_index_map_bounds_checked_for_every_layer():
    # stacked decode kernel: the layer axis is scalar-prefetch-driven; it is
    # exempt from grid coverage but every layer value must stay in-bounds —
    # exercised through the real family sweep (which traces the shipped
    # PrefetchScalarGridSpec kernel).
    fs = vmem.verify_all(sweep="quick", families=["fused_gemv_stacked"])
    assert [f for f in fs if f.severity == "error"] == []


def test_verify_all_rejects_unknown_sweep():
    with pytest.raises(ValueError, match="quick"):
        vmem.verify_all(sweep="exhaustive")
