"""Typed shape errors: every former bare ``assert`` in the kernel zoo (and
the two library sites outside it) now raises a ``ValueError`` that *names the
offending shapes* — callers debugging a mis-built table stack get the numbers,
not a naked AssertionError tuple, and the checks survive ``python -O``.

One test per raise site, matching on message content (the numbers and the
operand names), plus the lint-side guarantee that ``src/repro`` is
assert-free lives in test_analysis_lint.py.
"""

from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec
from repro.data.pipeline import SyntheticLM
from repro.kernels import ops
from repro.kernels.pcilt_conv2d import pcilt_conv2d_pallas
from repro.kernels.pcilt_dwconv1d import (pcilt_dwconv1d_pallas,
                                          pcilt_fused_dwconv1d_pallas)
from repro.kernels.pcilt_fused import (pcilt_fused_conv2d_pallas,
                                       pcilt_fused_gemv_pallas,
                                       pcilt_fused_gemv_stacked_pallas)
from repro.kernels.pcilt_gemv import pcilt_gemv_pallas
from repro.kernels.pcilt_shared import (pcilt_shared_conv2d_pallas,
                                        pcilt_shared_gemv_pallas)
from repro.models.transformer import TransformerLM

S2 = jnp.ones((1, 1), jnp.float32)
SEG2 = jnp.zeros((1, 1), jnp.int32)


def test_gemv_host_segment_mismatch():
    off = jnp.zeros((4, 8), jnp.int32)
    tab = jnp.zeros((9, 16, 128), jnp.float32)
    with pytest.raises(ValueError, match=r"segment dim 8 != .*9"):
        pcilt_gemv_pallas(off, tab, interpret=True)


def test_conv2d_host_segment_mismatch():
    off = jnp.zeros((1, 4, 8, 8), jnp.int32)
    tab = jnp.zeros((9, 16, 128), jnp.float32)
    with pytest.raises(ValueError, match=r"segment dim 8 != .*9"):
        pcilt_conv2d_pallas(off, tab, interpret=True)


def test_dwconv1d_host_channel_mismatch():
    off = jnp.zeros((1, 8, 16), jnp.int32)
    tab = jnp.zeros((17, 4), jnp.float32)
    with pytest.raises(ValueError, match=r"channel dim 16 != .*17"):
        pcilt_dwconv1d_pallas(off, tab, interpret=True)


def test_fused_dwconv1d_kernel_channel_mismatch():
    x = jnp.zeros((1, 11, 16), jnp.float32)
    tab = jnp.zeros((17, 256), jnp.float32)
    with pytest.raises(ValueError, match=r"channel dim 16 != .*17"):
        pcilt_fused_dwconv1d_pallas(x, S2, tab, bits=2, zero_point=2, k=4,
                                    tiles=(8, 16), interpret=True)


def test_fused_dwconv1d_dispatch_channel_mismatch():
    x = jnp.asarray(np.zeros((1, 8, 16)), jnp.float32)
    tab = jnp.zeros((17, 256), jnp.float32)
    with pytest.raises(ValueError, match=r"channel dim 16 != .*17"):
        ops.pcilt_fused_dwconv1d(x, tab, QuantSpec(2), 1.0, k=4)


def test_fused_gemv_group_mismatch():
    x = jnp.zeros((8, 30), jnp.float32)
    tab = jnp.zeros((16, 16, 128), jnp.float32)
    with pytest.raises(ValueError, match=r"trailing dim 30 != G\*group = 16\*2"):
        pcilt_fused_gemv_pallas(x, S2, tab, bits=2, zero_point=2, group=2,
                                tiles=(8, 16, 128), interpret=True)


def test_fused_gemv_stacked_group_mismatch():
    l1 = jnp.zeros((1,), jnp.int32)
    x = jnp.zeros((8, 30), jnp.float32)
    tab = jnp.zeros((3, 16, 16, 128), jnp.float32)
    with pytest.raises(ValueError, match=r"trailing dim 30 != G\*group = 16\*2"):
        pcilt_fused_gemv_stacked_pallas(l1, x, S2, tab, bits=2, zero_point=2,
                                        group=2, tiles=(8, 16, 128),
                                        interpret=True)


def test_fused_conv2d_n_total_too_small():
    x = jnp.zeros((1, 6, 6, 4), jnp.float32)
    tab = jnp.zeros((4, 16, 128), jnp.float32)
    with pytest.raises(ValueError,
                       match=r"n_total 10 .*kh\*kw\*C = 36.*G\*group = 4\*2"):
        pcilt_fused_conv2d_pallas(x, S2, SEG2, tab, bits=2, zero_point=2,
                                  group=2, kh=3, kw=3, n_total=10,
                                  tiles=(1, 1, 128), interpret=True)


def test_shared_gemv_group_mismatch():
    x = jnp.zeros((4, 10), jnp.float32)
    idx = jnp.zeros((1, 4), jnp.int32)
    pool = jnp.zeros((2, 16, 128), jnp.float32)
    with pytest.raises(ValueError, match=r"trailing dim 10 != G\*group = 4\*2"):
        pcilt_shared_gemv_pallas(x, S2, idx, pool, bits=2, zero_point=2,
                                 group=2, tiles=(8, 4, 128), interpret=True)


def test_shared_conv2d_n_total_too_small():
    x = jnp.zeros((1, 6, 6, 4), jnp.float32)
    idx = jnp.zeros((1, 4), jnp.int32)
    pool = jnp.zeros((2, 16, 128), jnp.float32)
    with pytest.raises(ValueError,
                       match=r"n_total 10 .*kh\*kw\*C = 36.*G\*group = 4\*2"):
        pcilt_shared_conv2d_pallas(x, S2, SEG2, idx, pool, bits=2,
                                   zero_point=2, group=2, kh=3, kw=3,
                                   n_total=10, tiles=(1, 1, 128),
                                   interpret=True)


def test_ops_fused_dwconv1d_survives_python_O():
    # The former bare assert vanished under `python -O`; the ValueError is
    # raise-based and must fire regardless of optimization level.
    import subprocess
    import sys

    code = (
        "import jax.numpy as jnp\n"
        "from repro.kernels.pcilt_gemv import pcilt_gemv_pallas\n"
        "try:\n"
        "    pcilt_gemv_pallas(jnp.zeros((4, 8), jnp.int32),\n"
        "                      jnp.zeros((9, 16, 128), jnp.float32),\n"
        "                      interpret=True)\n"
        "except ValueError as e:\n"
        "    assert 'segment dim 8' in str(e), str(e)\n"
        "    print('OK')\n"
    )
    res = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr


def test_transformer_interleave_mismatch():
    lm = TransformerLM(cfg=SimpleNamespace(
        n_layers=5, moe=SimpleNamespace(interleave=2)))
    with pytest.raises(ValueError, match=r"n_layers 5 .*unit size 2"):
        lm._n_units()


def test_pipeline_shard_mismatch():
    ds = SyntheticLM(vocab=16, seq_len=8, global_batch=5, n_shards=2)
    with pytest.raises(ValueError, match=r"global_batch 5 .*n_shards 2"):
        ds.local_batch
