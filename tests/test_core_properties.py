"""Hypothesis property tests for the PCILT invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    QuantSpec, calibrate, quantize, dequantize,
    pack_offsets, unpack_offsets, offset_grid,
    build_grouped_tables, pcilt_linear,
    table_bytes, grouped_table_bytes, shared_table_bytes,
    build_cost_multiplies,
)

SET = settings(max_examples=25, deadline=None)


@SET
@given(bits=st.integers(1, 8), sym=st.booleans(),
       seed=st.integers(0, 2**16))
def test_quantize_bounds_and_grid(bits, sym, seed):
    """Codes stay in [0, K); dequantization error ≤ scale/2 inside the grid
    range (+ the clip distance outside it)."""
    if bits == 1 and sym:
        return  # rejected by QuantSpec validation
    spec = QuantSpec(bits=bits, symmetric=sym)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32,)) if sym
                    else np.abs(rng.normal(size=(32,))), jnp.float32)
    scale = float(calibrate(x, spec))
    codes = quantize(x, spec, scale)
    assert int(codes.min()) >= 0 and int(codes.max()) < spec.cardinality
    xr = np.asarray(dequantize(codes, spec, scale))
    xn = np.asarray(x)
    lo = (0 - spec.zero_point) * scale
    hi = (spec.cardinality - 1 - spec.zero_point) * scale
    bound = scale / 2 + np.maximum(0, xn - hi) + np.maximum(0, lo - xn) + 1e-6
    assert (np.abs(xr - xn) <= bound).all()


@SET
@given(bits=st.integers(1, 4), group=st.integers(1, 4),
       seed=st.integers(0, 2**16))
def test_pack_unpack_inverse(bits, group, seed):
    if bits * group > 16:
        return
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, 1 << bits, size=(3, 4 * group)), jnp.int32)
    off = pack_offsets(codes, bits, group)
    back = unpack_offsets(off, bits, group)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    assert int(off.max()) < 1 << (bits * group)


@SET
@given(bits=st.integers(1, 3), group=st.integers(1, 3),
       n_groups=st.integers(1, 4), out=st.integers(1, 9),
       seed=st.integers(0, 2**16))
def test_pcilt_equals_quantized_matmul(bits, group, n_groups, out, seed):
    """The paper's exactness claim, over arbitrary shapes/cardinalities."""
    if bits * group > 12:
        return
    n = group * n_groups
    rng = np.random.default_rng(seed)
    spec = QuantSpec(bits=bits)
    x = jnp.asarray(np.abs(rng.normal(size=(5, n))) * 2, jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
    scale = calibrate(x, spec)
    T = build_grouped_tables(w, spec, scale, group)
    got = pcilt_linear(x, T, spec, scale, group)
    want = dequantize(quantize(x, spec, scale), spec, scale) @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@SET
@given(bits=st.integers(1, 4), group=st.integers(1, 3))
def test_offset_grid_enumerates_exactly(bits, group):
    if bits * group > 12:
        return
    g = np.asarray(offset_grid(bits, group))
    assert g.shape == (1 << (bits * group), group)
    # every row distinct and within code range
    assert len(np.unique(g, axis=0)) == g.shape[0]
    assert g.min() >= 0 and g.max() < (1 << bits)


@SET
@given(n=st.integers(1, 10_000), bits=st.integers(1, 8),
       vb=st.sampled_from([1, 2, 4]))
def test_memory_formulas(n, bits, vb):
    """Grouping with g=1 degenerates to the basic formula; shared-table
    memory never exceeds per-weight memory for the same value count."""
    assert grouped_table_bytes(n, bits, 1, vb) == table_bytes(n, bits, vb)
    assert shared_table_bytes(min(n, 16), [bits], vb) <= table_bytes(
        max(n, 16), bits, vb)
    assert build_cost_multiplies(n, bits) == n * (1 << bits)


@SET
@given(bits=st.integers(1, 3), seed=st.integers(0, 2**16))
def test_build_then_infer_is_pure(bits, seed):
    """Tables are pure functions of (w, spec, scale): rebuilt tables fetch
    identically (the 'calculated once per lifetime' property)."""
    rng = np.random.default_rng(seed)
    spec = QuantSpec(bits=bits)
    w = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    T1 = build_grouped_tables(w, spec, 0.37, 2)
    T2 = build_grouped_tables(w, spec, 0.37, 2)
    np.testing.assert_array_equal(np.asarray(T1), np.asarray(T2))
