"""Property tests for the PCILT invariants.

Runs under Hypothesis when it is installed (CI: ``requirements-dev.txt``).
Without it, the ``@given`` tests report skipped — and the newer properties
(``conv_same_pads`` vs the XLA oracle, quantize→dequantize codebook
round-trips) additionally ship a seeded random sweep so those invariants
stay locked even in environments without Hypothesis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any strategy call -> None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(**kwargs):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            def placeholder():
                pass

            placeholder.__name__ = fn.__name__
            placeholder.__doc__ = fn.__doc__
            return pytest.mark.skip(
                reason="hypothesis not installed (seeded sweeps below still "
                       "run)")(placeholder)
        return deco

from repro.core import (
    QuantSpec, calibrate, quantize, dequantize, fake_quant, code_values,
    pack_offsets, unpack_offsets, offset_grid,
    build_grouped_tables, pcilt_linear, conv_same_pads, im2col,
    table_bytes, grouped_table_bytes, shared_table_bytes,
    build_cost_multiplies,
)

SET = settings(max_examples=25, deadline=None)


@SET
@given(bits=st.integers(1, 8), sym=st.booleans(),
       seed=st.integers(0, 2**16))
def test_quantize_bounds_and_grid(bits, sym, seed):
    """Codes stay in [0, K); dequantization error ≤ scale/2 inside the grid
    range (+ the clip distance outside it)."""
    if bits == 1 and sym:
        return  # rejected by QuantSpec validation
    spec = QuantSpec(bits=bits, symmetric=sym)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32,)) if sym
                    else np.abs(rng.normal(size=(32,))), jnp.float32)
    scale = float(calibrate(x, spec))
    codes = quantize(x, spec, scale)
    assert int(codes.min()) >= 0 and int(codes.max()) < spec.cardinality
    xr = np.asarray(dequantize(codes, spec, scale))
    xn = np.asarray(x)
    lo = (0 - spec.zero_point) * scale
    hi = (spec.cardinality - 1 - spec.zero_point) * scale
    bound = scale / 2 + np.maximum(0, xn - hi) + np.maximum(0, lo - xn) + 1e-6
    assert (np.abs(xr - xn) <= bound).all()


@SET
@given(bits=st.integers(1, 4), group=st.integers(1, 4),
       seed=st.integers(0, 2**16))
def test_pack_unpack_inverse(bits, group, seed):
    if bits * group > 16:
        return
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, 1 << bits, size=(3, 4 * group)), jnp.int32)
    off = pack_offsets(codes, bits, group)
    back = unpack_offsets(off, bits, group)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    assert int(off.max()) < 1 << (bits * group)


@SET
@given(bits=st.integers(1, 3), group=st.integers(1, 3),
       n_groups=st.integers(1, 4), out=st.integers(1, 9),
       seed=st.integers(0, 2**16))
def test_pcilt_equals_quantized_matmul(bits, group, n_groups, out, seed):
    """The paper's exactness claim, over arbitrary shapes/cardinalities."""
    if bits * group > 12:
        return
    n = group * n_groups
    rng = np.random.default_rng(seed)
    spec = QuantSpec(bits=bits)
    x = jnp.asarray(np.abs(rng.normal(size=(5, n))) * 2, jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
    scale = calibrate(x, spec)
    T = build_grouped_tables(w, spec, scale, group)
    got = pcilt_linear(x, T, spec, scale, group)
    want = dequantize(quantize(x, spec, scale), spec, scale) @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@SET
@given(bits=st.integers(1, 4), group=st.integers(1, 3))
def test_offset_grid_enumerates_exactly(bits, group):
    if bits * group > 12:
        return
    g = np.asarray(offset_grid(bits, group))
    assert g.shape == (1 << (bits * group), group)
    # every row distinct and within code range
    assert len(np.unique(g, axis=0)) == g.shape[0]
    assert g.min() >= 0 and g.max() < (1 << bits)


@SET
@given(n=st.integers(1, 10_000), bits=st.integers(1, 8),
       vb=st.sampled_from([1, 2, 4]))
def test_memory_formulas(n, bits, vb):
    """Grouping with g=1 degenerates to the basic formula; shared-table
    memory never exceeds per-weight memory for the same value count."""
    assert grouped_table_bytes(n, bits, 1, vb) == table_bytes(n, bits, vb)
    assert shared_table_bytes(min(n, 16), [bits], vb) <= table_bytes(
        max(n, 16), bits, vb)
    assert build_cost_multiplies(n, bits) == n * (1 << bits)


@SET
@given(bits=st.integers(1, 3), seed=st.integers(0, 2**16))
def test_build_then_infer_is_pure(bits, seed):
    """Tables are pure functions of (w, spec, scale): rebuilt tables fetch
    identically (the 'calculated once per lifetime' property)."""
    rng = np.random.default_rng(seed)
    spec = QuantSpec(bits=bits)
    w = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    T1 = build_grouped_tables(w, spec, 0.37, 2)
    T2 = build_grouped_tables(w, spec, 0.37, 2)
    np.testing.assert_array_equal(np.asarray(T1), np.asarray(T2))


# ----------------------------------------------------------------------------
# conv_same_pads vs the XLA oracle, and quantize<->dequantize round-trips.
# These properties lock the PR 2 stride-aware "SAME" fix; they run under
# Hypothesis when available and as a seeded random sweep otherwise.
# ----------------------------------------------------------------------------


def _check_conv_same_pads(h, w, kh, kw, stride):
    """``conv_same_pads`` must agree with XLA: identical pad amounts
    (``lax.padtype_to_pads`` is the oracle), identical output extents from
    ``lax.conv_general_dilated``, and an im2col convolution built on those
    pads must reproduce the lax convolution's values."""
    pads = conv_same_pads(h, w, kh, kw, stride)
    assert pads[0] == (0, 0) and pads[3] == (0, 0)
    oracle = jax.lax.padtype_to_pads((h, w), (kh, kw), (stride, stride),
                                     "SAME")
    assert tuple(map(int, pads[1])) == tuple(map(int, oracle[0]))
    assert tuple(map(int, pads[2])) == tuple(map(int, oracle[1]))

    rng = np.random.default_rng(h * 1000 + w * 100 + kh * 10 + kw + stride)
    x = jnp.asarray(rng.normal(size=(1, h, w, 2)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(kh, kw, 2, 3)), jnp.float32)
    want = jax.lax.conv_general_dilated(
        x, f, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    patches = im2col(x, kh, kw, stride, "SAME")
    assert patches.shape[1:3] == want.shape[1:3], (
        f"im2col extent {patches.shape[1:3]} != lax {want.shape[1:3]}")
    got = patches @ f.reshape(kh * kw * 2, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def _check_codebook_roundtrip(bits, sym, scale):
    """Every representable grid value quantizes to its own code and
    dequantizes back bit-exactly, and fake-quant is idempotent — the
    codebook is a fixed point of quantize∘dequantize."""
    spec = QuantSpec(bits=bits, symmetric=sym)
    cv = code_values(spec, scale)  # [K] the representable values
    codes = quantize(cv, spec, scale)
    np.testing.assert_array_equal(
        np.asarray(codes), np.arange(spec.cardinality, dtype=np.uint8))
    np.testing.assert_array_equal(
        np.asarray(dequantize(codes, spec, scale)), np.asarray(cv))
    rng = np.random.default_rng(bits * 7 + int(sym))
    x = jnp.asarray(rng.normal(size=(64,)) * 3 * scale, jnp.float32)
    once = fake_quant(x, spec, scale)
    twice = fake_quant(once, spec, scale)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


if HAVE_HYPOTHESIS:

    @SET
    @given(h=st.integers(1, 17), w=st.integers(1, 17),
           kh=st.integers(1, 5), kw=st.integers(1, 5),
           stride=st.integers(1, 3))
    def test_conv_same_pads_matches_lax(h, w, kh, kw, stride):
        _check_conv_same_pads(h, w, kh, kw, stride)

    @SET
    @given(bits=st.integers(1, 8), sym=st.booleans(),
           log_scale=st.floats(-3.0, 3.0))
    def test_codebook_roundtrip(bits, sym, log_scale):
        if bits == 1 and sym:
            return  # rejected by QuantSpec validation
        _check_codebook_roundtrip(bits, sym, float(10.0 ** log_scale))

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_conv_same_pads_matches_lax(seed):
        rng = np.random.default_rng(seed)
        _check_conv_same_pads(
            int(rng.integers(1, 18)), int(rng.integers(1, 18)),
            int(rng.integers(1, 6)), int(rng.integers(1, 6)),
            int(rng.integers(1, 4)))

    @pytest.mark.parametrize("seed", range(25))
    def test_codebook_roundtrip(seed):
        rng = np.random.default_rng(seed)
        bits = int(rng.integers(1, 9))
        sym = bool(rng.integers(0, 2)) and bits > 1
        _check_codebook_roundtrip(bits, sym,
                                  float(10.0 ** rng.uniform(-3, 3)))
