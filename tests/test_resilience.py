"""Serving resilience: fault injection, table integrity, health-checked
degradation to the dense oracle, and checkpointed engine recovery.

The chaos contract under test (docs/resilience.md):

* every fault class is *detected* (zero false negatives for single-entry
  table flips — a CRC-32 property, tested exhaustively here);
* recoverable faults (step faults, poisoned state) restore-and-replay to
  **token-identical** output;
* table corruption demotes only the breached layer/head to its exact dense
  fake-quant oracle — serving continues, degraded and logged, never wrong;
* deadline-missed requests requeue with bounded retries, never silently
  lost.

The converted PCILT bundle is built once (module fixture) and shared via
nested-dict copies: corruption replaces dict entries, so copies isolate
tests without re-running the conversion.
"""

import dataclasses as dc
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import PCILTConfig
from repro.core import fake_quant, table_checksum, stacked_checksums
from repro.core.quantization import QuantSpec, scale_from_amax
from repro.core.serving import (HealthMonitor, PCILTMambaDecode,
                                convert_kernel)
from repro.launch.serve import Engine, Request
from repro.launch.steps import make_ctx
from repro.nn.module import materialize
from repro.runtime.faults import FaultInjector

BITS, GROUP = 4, 2


def _cfg():
    cfg = get_smoke_config("mamba2-130m")
    return dc.replace(cfg, pcilt=PCILTConfig(act_bits=BITS, group=GROUP),
                      dtype=jnp.float32)


def _copy_bundle(obj):
    """Nested dict/list copy, arrays shared: corruption *replaces* entries,
    so a copy isolates a test's mutations from the donor bundle."""
    if isinstance(obj, dict):
        return {k: _copy_bundle(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_copy_bundle(v) for v in obj]
    return obj


@pytest.fixture(scope="module")
def donor():
    """One converted PCILT engine; tests clone its bundle, never mutate it."""
    return Engine(_cfg(), max_len=64, slots=2, pcilt=True)


def _pcilt_engine(donor, **kw):
    return Engine(_cfg(), max_len=64, slots=2, pcilt=True,
                  pcilt_bundle=_copy_bundle(donor.pdecode.pcilt), **kw)


def _requests(cfg, n=3, max_new=4, deadline=None, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(3, 7)),
                    max_new, deadline_s=deadline) for i in range(n)]


@pytest.fixture(scope="module")
def ref_run(donor):
    """Fault-free reference serving run (token ground truth)."""
    eng = _pcilt_engine(donor)
    reqs = _requests(eng.cfg)
    stats = eng.run(reqs)
    assert all(r.outcome == "served" for r in reqs)
    return [list(r.out) for r in reqs], stats


# ---- fault injector primitives ----------------------------------------------


def test_corrupt_table_flips_and_records():
    inj = FaultInjector(seed=3)
    t = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    bad = inj.corrupt_table(t, n_flips=3)
    diff = np.asarray(bad != t)
    assert bad.shape == t.shape and bad.dtype == t.dtype
    assert diff.sum() == 3
    (ev,) = inj.events
    assert ev["kind"] == "table_corruption" and len(ev["sites"]) == 3
    assert all(diff[s] for s in ev["sites"])


def test_flip_seg_idx_stays_in_pool_range():
    inj = FaultInjector(seed=0)
    seg = jnp.asarray(np.arange(16) % 8, jnp.int32)
    bad = inj.flip_seg_idx(seg, n_pool=8, n_flips=4)
    moved = np.nonzero(np.asarray(bad != seg))[0]
    assert len(moved) == 4
    assert np.asarray(bad).min() >= 0 and np.asarray(bad).max() < 8


def test_flip_seg_idx_single_row_pool_goes_out_of_range():
    inj = FaultInjector(seed=0)
    seg = jnp.zeros((5,), jnp.int32)
    bad = inj.flip_seg_idx(seg, n_pool=1, n_flips=1)
    # the only wrong pointer a 1-row pool admits is an out-of-range one
    assert int(np.asarray(bad).max()) == 1


def test_poison_plants_nan_and_inf():
    inj = FaultInjector(seed=1)
    x = jnp.zeros((4, 4), jnp.float32)
    assert int(jnp.isnan(inj.poison(x, "nan", n=3)).sum()) == 3
    assert int(jnp.isinf(inj.poison(x, "inf", n=2)).sum()) == 2
    assert [e["kind"] for e in inj.events] == ["activation_poison"] * 2


def test_garble_file_modes(tmp_path):
    inj = FaultInjector()
    p = str(tmp_path / "tiles.json")
    payload = json.dumps({"k": list(range(50))}).encode()
    for mode, check in [
        ("truncate", lambda b: 0 < len(b) < len(payload)),
        ("garbage", lambda b: b and b != payload),
        ("empty", lambda b: b == b""),
    ]:
        with open(p, "wb") as f:
            f.write(payload)
        inj.garble_file(p, mode)
        with open(p, "rb") as f:
            got = f.read()
        assert check(got), mode
        with pytest.raises(ValueError):
            json.loads(got.decode("utf-8", errors="strict") or "x")
    inj.garble_file(str(tmp_path / "absent.json"), "truncate")
    assert inj.events[-1]["absent"] is True


def test_maybe_fail_fires_once_then_replays_clean():
    inj = FaultInjector(fail_at=(5,))
    inj.maybe_fail(4)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(5)
    inj.maybe_fail(5)  # replay after restore: clean
    assert [e["kind"] for e in inj.events] == ["step_fault"]


# ---- checksum integrity: zero false negatives --------------------------------


def _flip(a, i):
    flat = a.reshape(-1).copy()
    if np.issubdtype(flat.dtype, np.integer):
        flat[i] = flat[i] + 1
    else:
        old = float(np.float32(flat[i]))
        flat[i] = flat.dtype.type(old + (1.0 + abs(old)))
    return flat.reshape(a.shape)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
def test_checksum_detects_every_single_entry_flip(dtype):
    """CRC-32 detects all burst errors <= 32 bits; a single flipped table
    entry is exactly that.  Exhaustive: flip *every* entry, expect *every*
    flip detected — a measured zero false-negative rate, not a spot check."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(3, 4, 5)), getattr(jnp, dtype)) \
        if dtype != "int32" else jnp.asarray(
            rng.integers(0, 100, size=(3, 4, 5)), jnp.int32)
    base = table_checksum(a)
    host = np.asarray(a)
    misses = [i for i in range(host.size)
              if table_checksum(_flip(host, i)) == base]
    assert misses == []


def test_stacked_checksums_localize_the_corrupt_layer():
    rng = np.random.default_rng(1)
    t = jnp.asarray(rng.normal(size=(4, 3, 8)), jnp.float32)
    clean = stacked_checksums(t)
    assert len(clean) == 4
    inj = FaultInjector(seed=2)
    bad = np.asarray(t).copy()
    bad[2] = np.asarray(inj.corrupt_table(t[2], n_flips=1))
    dirty = stacked_checksums(jnp.asarray(bad))
    assert [i for i in range(4) if dirty[i] != clean[i]] == [2]


# ---- converted-layer integrity ----------------------------------------------


def test_pcilt_linear_carries_and_verifies_integrity():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    spec = QuantSpec(bits=BITS, symmetric=True)
    scale = scale_from_amax(jnp.asarray(1.0), spec)
    lin = convert_kernel(k, spec, scale, GROUP, weight_bits=4, shared=True)
    assert set(lin.integrity) == {"pool", "seg_idx"}
    assert all(lin.verify_integrity().values())
    inj = FaultInjector(seed=0)
    lin.shared = dc.replace(
        lin.shared, pool=inj.corrupt_table(lin.shared.pool, n_flips=1))
    assert lin.verify_integrity()["pool"] is False
    assert lin.verify_integrity()["seg_idx"] is True


def test_decode_bundle_verified_at_load(donor):
    inj = FaultInjector(seed=0)
    bundle = _copy_bundle(donor.pdecode.pcilt)
    bundle["tables"] = inj.corrupt_table(bundle["tables"], n_flips=1)
    ctx = make_ctx(None, None, decode=True)
    with pytest.raises(RuntimeError, match="integrity"):
        PCILTMambaDecode(donor.model, bundle, ctx)
    # explicit opt-out (the chaos path): loads, detection deferred to the
    # monitor
    pd = PCILTMambaDecode(donor.model, bundle, ctx, verify=False)
    assert pd.verify_integrity() != []


def test_monitor_demotes_only_the_breached_layer(donor):
    inj = FaultInjector(seed=4)
    bundle = _copy_bundle(donor.pdecode.pcilt)
    pd = PCILTMambaDecode(donor.model, bundle, donor.pdecode.ctx)
    mon = HealthMonitor(pd, donor.params)
    for t in range(3):
        assert mon.on_tick(t) == []
    assert mon.last_verified.min() >= 0
    tabs = pd.pcilt["proj"]["tables"]
    bad_layer = 1
    full = np.asarray(tabs["wx"]).copy()
    full[bad_layer] = np.asarray(
        inj.corrupt_table(tabs["wx"][bad_layer], n_flips=1))
    tabs["wx"] = jnp.asarray(full)
    breaches = []
    for t in range(3, 3 + 2 * mon.n_layers):
        breaches += mon.on_tick(t)
    assert [b["layer"] for b in breaches] == [bad_layer]
    assert list(mon.layer_ok) == [l != bad_layer
                                  for l in range(mon.n_layers)]
    assert mon.head_ok  # head untouched
    # the breached layer stops being re-verified; healthy ones continue
    assert mon.on_tick(99) == []


def test_health_masks_exact_and_demoted_matches_oracle(donor):
    """All-healthy masks are bitwise-identical to running unmasked (the
    cond's live branch is the same fetch), and an all-demoted step matches
    the dense fake-quant oracle — 'degraded, never wrong'."""
    pd = donor.pdecode
    cfg = donor.cfg
    B = 2
    cache = materialize(donor.model.cache_specs(B, 16), jax.random.PRNGKey(7))
    cache = dict(cache, pos=jnp.asarray(1, jnp.int32))
    tok = jnp.full((B, 1), 3, jnp.int32)
    base, base_c = pd.step(donor.params, cache, tok)
    ones, ones_c = pd.step(donor.params, cache, tok,
                           layer_ok=jnp.ones((cfg.n_layers,), bool),
                           head_ok=jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(ones))
    np.testing.assert_array_equal(np.asarray(base_c["layers"]["ssd"]),
                                  np.asarray(ones_c["layers"]["ssd"]))

    demoted, _ = pd.step(donor.params, cache, tok,
                         layer_ok=jnp.zeros((cfg.n_layers,), bool),
                         head_ok=jnp.asarray(False))
    pc_fq = _copy_bundle(pd.pcilt)
    pc_fq["proj"]["path"] = "dense_fq"
    oracle_step = jax.jit(lambda p, c, t: donor.model.decode_step(
        p, c, t, pd.ctx, pcilt=pc_fq, head_ok=jnp.asarray(False)))
    want, _ = oracle_step(donor.params, cache, tok)
    assert np.all(np.isfinite(np.asarray(demoted)))
    np.testing.assert_allclose(np.asarray(demoted), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.argmax(np.asarray(demoted), -1),
                          np.argmax(np.asarray(want), -1))


# ---- engine: continuous batching correctness (satellite fix) ----------------


def test_prefill_overlap_matches_serial():
    """Two overlapping requests must produce the same tokens as serving them
    serially — regression for prefill ticks dropping active slots' sampled
    tokens (Mamba arch: per-slot recurrent state, position-free)."""
    cfg = get_smoke_config("mamba2-130m")
    mk = lambda: [Request(0, [5, 7, 9, 11, 13], 4), Request(1, [4, 6, 8], 4)]
    overlapped = Engine(cfg, max_len=64, slots=2)
    reqs = mk()
    overlapped.run(reqs)
    serial = Engine(cfg, max_len=64, slots=1)
    ref = mk()
    serial.run(ref)
    assert [r.out for r in reqs] == [q.out for q in ref]
    assert all(r.outcome == "served" for r in reqs)


# ---- engine: chaos ----------------------------------------------------------


def test_engine_restore_replay_token_identical(donor, ref_run):
    """Step fault + NaN-poisoned recurrent state: both detected, both
    recovered by checkpoint restore, and the replayed serving run is
    token-identical to the fault-free reference."""
    ref_tokens, _ = ref_run
    inj = FaultInjector(fail_at=(2,), seed=0)

    def poison_state(e):
        layers = e.cache["layers"]
        e.cache = dict(e.cache, layers=dict(
            layers, ssd=inj.poison(layers["ssd"], "nan", n=2)))

    eng = _pcilt_engine(donor, chaos={2: [lambda e: inj.maybe_fail(2)],
                                      9: [poison_state]})
    reqs = _requests(eng.cfg)
    stats = eng.run(reqs)
    assert not eng.chaos  # every scheduled fault fired
    assert stats["restarts"] == 2
    assert [e["kind"] for e in inj.events] == ["step_fault",
                                               "activation_poison"]
    assert [r.outcome for r in reqs] == ["served"] * len(reqs)
    assert [list(r.out) for r in reqs] == ref_tokens


def test_engine_corruption_degrades_never_lost(donor):
    """Corrupted projection stack + flipped head pointers: the monitor
    demotes the breached layer and the head, the engine rolls back to the
    last verified tick, and every request still completes."""
    inj = FaultInjector(seed=5)

    def corrupt_proj(e):
        tabs = e.pdecode.pcilt["proj"]["tables"]
        tabs["wx"] = inj.corrupt_table(tabs["wx"], n_flips=1)
        e.pdecode.rehoist()

    def flip_head(e):
        head = e.pdecode.pcilt["head"]
        head["seg_idx"] = inj.flip_seg_idx(
            head["seg_idx"], n_pool=head["pool"].shape[0])
        e.pdecode.rehoist()

    eng = _pcilt_engine(donor, chaos={3: [corrupt_proj], 6: [flip_head]})
    reqs = _requests(eng.cfg)
    stats = eng.run(reqs)
    assert not eng.chaos
    assert all(r.outcome in ("served", "degraded") for r in reqs)
    assert stats["rollbacks"] >= 1
    kinds = {e["kind"] for e in eng.monitor.events}
    assert kinds == {"layer", "head"}
    assert not eng.monitor.layer_ok.all() and not eng.monitor.head_ok
    # demotion is per-layer: the clean layer keeps fetching
    assert eng.monitor.layer_ok.sum() == eng.monitor.n_layers - 1


def test_engine_deadline_requeues_then_fails_bounded():
    """A request that can never meet its deadline is evicted, requeued with
    backoff, and failed after max_retries — bounded, never silently lost."""
    cfg = get_smoke_config("qwen3-0.6b")
    doomed = Request(0, np.asarray([5, 6, 7]), max_new=64, deadline_s=1e-4,
                     max_retries=1)
    fine = Request(1, np.asarray([3, 4]), max_new=3)
    eng = Engine(cfg, max_len=128, slots=2)
    stats = eng.run([doomed, fine])
    assert doomed.outcome == "failed"
    assert doomed.retries == doomed.max_retries + 1
    assert fine.outcome == "served" and len(fine.out) == 3
    assert stats["failed"] == 1 and stats["retried"] == 1
    assert stats["outcomes"] == {0: "failed", 1: "served"}


def test_deadline_eviction_races_checkpoint_restore(donor):
    """A step fault forces a restore to a checkpoint taken *before* a
    deadline eviction: the replay must re-run the eviction from restored
    state — the evicted request fails exactly once (retries never
    double-counted) and its slot state is never resurrected."""
    inj = FaultInjector(fail_at=(5,), seed=2)
    doomed = Request(0, np.asarray([5, 6, 7]), max_new=64, deadline_s=1e-4,
                     max_retries=0)
    fine = Request(1, np.asarray([3, 4]), max_new=4)
    # prefills cover steps 0..4, so the fault hits the first decode tick —
    # the restore target predates the eviction the same tick would commit
    eng = _pcilt_engine(donor, chaos={5: [lambda e: inj.maybe_fail(5)]})
    stats = eng.run([doomed, fine])
    assert not eng.chaos and stats["restarts"] == 1
    assert doomed.outcome == "failed"
    assert doomed.retries == doomed.max_retries + 1  # once, not per replay
    assert doomed.out == []  # evicted state never resurrected by the replay
    assert fine.outcome == "served" and len(fine.out) == 4
    assert all(r is None for r in eng.active) and eng.queue == []
    assert stats["outcomes"] == {0: "failed", 1: "served"}
    assert stats["slot_evictions"] == 1


def test_monitor_demotion_with_two_slots_mid_request(donor):
    """Table corruption lands while BOTH slots are mid-request: the breach
    rolls every slot back to the last verified tick and replays demoted —
    each request ends degraded with exactly its max_new tokens (no token
    lost or duplicated across the multi-slot rollback)."""
    inj = FaultInjector(seed=6)
    seen = {}

    def corrupt(e):
        seen["active"] = sum(r is not None for r in e.active)
        seen["partial"] = [len(r.out) for r in e.active if r is not None]
        tabs = e.pdecode.pcilt["proj"]["tables"]
        tabs["wx"] = inj.corrupt_table(tabs["wx"], n_flips=1)
        e.pdecode.rehoist()

    reqs = [Request(0, np.asarray([5, 6, 7]), max_new=8),
            Request(1, np.asarray([3, 4, 9]), max_new=8)]
    eng = _pcilt_engine(donor, chaos={7: [corrupt]})
    stats = eng.run(reqs)
    assert not eng.chaos
    assert seen["active"] == 2  # the breach hit with both slots mid-request
    assert all(n >= 1 for n in seen["partial"])
    assert [r.outcome for r in reqs] == ["degraded", "degraded"]
    assert [len(r.out) for r in reqs] == [8, 8]
    assert stats["rollbacks"] >= 1 and stats["degraded"] == 2
    assert eng.monitor.layer_ok.sum() == eng.monitor.n_layers - 1


def test_per_slot_count_executors_cached_and_dropped_on_rehoist(donor):
    """The decode engine hoists one jitted executor per (slot count, stats)
    pair (R is a tuned, keyed axis; the counter outputs change the result
    pytree): repeat lookups hit the cache, distinct row counts and the
    monitored variant get distinct executors, and rehoist drops them all
    for lazy rebuild."""
    pd = donor.pdecode
    e1, e2 = pd.executor(1), pd.executor(2)
    es = pd.executor(1, stats=True)
    assert pd.executor(1) is e1 and pd.executor(2) is e2
    assert pd.executor(1, stats=True) is es
    assert e1 is not e2 and es is not e1
    assert set(pd._execs) == {(1, False), (2, False), (1, True)}
    pd.rehoist()
    assert pd._execs == {}  # stale closures dropped, rebuilt on next step
    assert pd.executor(2) is not e2
