"""Per-architecture smoke tests: reduced same-family config, one forward
loss + one decode step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, SHAPES
from repro.models import build_model
from repro.nn import materialize, count_params
from repro.nn.layers import Ctx

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    b = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                                     cfg.vocab),
    }
    if cfg.encoder_layers:
        b["memory"] = jax.random.normal(KEY, (B, cfg.encoder_len, cfg.d_model))
    if cfg.n_img_tokens:
        b["img_embeds"] = jax.random.normal(KEY, (B, cfg.n_img_tokens,
                                                  cfg.d_model))
    return b


@pytest.fixture(scope="module")
def ctx():
    return Ctx()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_decode(arch, ctx):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, ctx))(
        params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    cache = materialize(model.cache_specs(B, S), KEY)
    cache = dict(cache, pos=jnp.asarray(S - 1, jnp.int32))
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
    logits, new_cache = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, ctx))(params, cache, tok)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    # cache advances
    assert int(new_cache["pos"]) == S


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect, f"{arch}: {got} != {expect}"


def test_moe_arch_extras():
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.moe.n_experts == 128 and l4.moe.top_k == 1
    gr = get_config("granite-moe-3b-a800m")
    assert gr.moe.n_experts == 40 and gr.moe.top_k == 8
    mm = get_config("mamba2-130m")
    assert mm.ssm.d_state == 128
    zb = get_config("zamba2-7b")
    assert zb.ssm.d_state == 64 and zb.shared_attn_period == 6
    lv = get_config("llava-next-mistral-7b")
    assert lv.window == 4096
    qw = get_config("qwen1.5-4b")
    assert qw.qkv_bias
    q3 = get_config("qwen3-0.6b")
    assert q3.qk_norm


def test_param_count_sanity():
    """Full-config parameter counts land near the published sizes."""
    import math
    from repro.nn.module import count_params

    targets = {  # (arch, nominal params, tolerance fraction)
        "deepseek-coder-33b": (33e9, 0.15),
        "qwen2.5-3b": (3.1e9, 0.25),
        "qwen3-0.6b": (0.6e9, 0.4),
        "mamba2-130m": (130e6, 0.4),
        "llava-next-mistral-7b": (7.1e9, 0.15),
        "granite-moe-3b-a800m": (3.4e9, 0.3),
    }
    for arch, (target, tol) in targets.items():
        cfg = get_config(arch)
        n = count_params(build_model(cfg).param_specs())
        assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B vs {target/1e9:.2f}B"


def test_long_500k_eligibility():
    """DESIGN.md §7: SSM/hybrid/SWA run long_500k; full-attention skip."""
    eligible = {a: get_config(a).sub_quadratic for a in ARCHS}
    assert eligible["mamba2-130m"] and eligible["zamba2-7b"]
    assert eligible["llava-next-mistral-7b"]  # sliding window 4096
    for a in ("qwen3-0.6b", "deepseek-coder-33b", "whisper-medium",
              "llama4-maverick-400b-a17b"):
        assert not eligible[a]
