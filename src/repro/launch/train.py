"""Training launcher.

Production shape:  ``python -m repro.launch.train --arch qwen3-0.6b
--steps 200`` — builds the mesh from available devices, materializes sharded
params, and runs the supervised train loop (watchdog + async checkpointing +
auto-restart on step failure).  On this CPU container it runs the smoke
config by default; on a pod the same file runs the full config
(``--full``) — the step function, sharding rules and checkpoint format are
identical, only the mesh and config size change.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.nn.module import materialize, shardings, ShardingRules, count_params
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.checkpoint import Checkpointer
from repro.runtime import Supervisor, StepWatchdog, FaultInjector
from repro.launch.steps import make_train_step
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--full", action="store_true",
                   help="full config (pod-scale; default: smoke config)")
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--data-shards", type=int, default=1)
    p.add_argument("--fail-at", type=int, nargs="*", default=[],
                   help="inject step faults (fault-tolerance demo)")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    model = build_model(cfg)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev, model=1) if n_dev > 1 else None

    specs = model.param_specs()
    print(f"arch={cfg.name} params={count_params(specs)/1e6:.2f}M "
          f"devices={n_dev}")
    params = materialize(specs, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=cosine_schedule(args.lr, 10, args.steps),
                       weight_decay=0.01)
    opt_state = adamw_init(params, ocfg)

    data = SyntheticLM(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        memory_len=cfg.encoder_len if cfg.encoder_layers else 0,
        img_tokens=cfg.n_img_tokens, d_model=cfg.d_model,
    )
    step_fn = jax.jit(make_train_step(cfg, mesh, ocfg), donate_argnums=(0, 1))
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    injector = FaultInjector(args.fail_at)

    def batch_for(step):
        b = data.batch(step)
        if cfg.n_img_tokens:
            b = dict(b)
            for k in ("tokens", "labels", "loss_mask"):
                b[k] = b[k][:, : args.seq - cfg.n_img_tokens]
        return jax.tree.map(jnp.asarray, b)

    def run_step(state, step):
        injector.maybe_fail(step)
        params, opt_state = state
        params, opt_state, metrics = step_fn(params, opt_state, batch_for(step))
        if step % args.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {step:5d} loss {m['loss']:.4f} ce {m.get('ce', 0):.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}", flush=True)
        return params, opt_state

    def save(state, step):
        ckpt.save_async(step, {"params": state[0], "opt": state[1]},
                        extra={"arch": cfg.name})

    def restore():
        got = ckpt.restore_latest({"params": params, "opt": opt_state})
        if got[0] is None:
            return None
        step, tree, _ = got
        print(f"restored checkpoint at step {step}")
        return step, (tree["params"], tree["opt"])

    sup = Supervisor(step_fn=run_step, save_fn=save, restore_fn=restore,
                     ckpt_every=args.ckpt_every, max_restarts=3)
    t0 = time.time()
    step, state, stats = sup.run((params, opt_state), args.steps)
    ckpt.wait()
    print(f"done: {step} steps in {time.time()-t0:.1f}s; "
          f"restarts={stats['restarts']} stragglers={stats['straggler_steps']}")


if __name__ == "__main__":
    main()
