"""ShapeDtypeStruct stand-ins for every model input — the dry-run's food.

``input_specs(arch, shape, mesh)`` returns (kwargs for the step function,
in_shardings-compatible structs): weak-type-correct, shardable, and **never
allocated** — a 400B-parameter cell lowers on a CPU host.

Shapes follow the assignment: ``train_*``/``prefill_*`` provide
``[global_batch, seq]`` token grids (+ stub modality embeddings);
``decode_*`` provide one new token + a filled KV cache of ``seq_len``
(rolling-window archs cap the buffer at their window; SSM archs carry
constant-size states).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.models import build_model
from repro.nn.module import ShardingRules, shape_structs, logical_to_partition_spec

__all__ = ["input_specs", "batch_specs", "param_structs", "data_spec"]


def _named(mesh: Optional[Mesh], rules, axes, shape):
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_partition_spec(axes, shape, rules))


def _struct(shape, dtype, mesh, rules, axes):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=_named(mesh, rules, axes, shape))


def data_spec(mesh: Optional[Mesh], rule_overrides=None):
    if mesh is None:
        return None
    from repro.nn.module import DEFAULT_RULES

    rules = dict(DEFAULT_RULES)
    if rule_overrides:
        rules.update(rule_overrides)
    return ShardingRules.for_mesh(mesh, rules)


def batch_specs(cfg, shape_name: str, mesh: Optional[Mesh],
                rule_overrides=None):
    """Training/prefill batch structs for one (arch, shape)."""
    sh = SHAPES[shape_name]
    rules = data_spec(mesh, rule_overrides)
    B = sh.global_batch
    S = sh.seq_len
    tok_axes = ("batch", None)
    n_text = S - cfg.n_img_tokens if cfg.n_img_tokens else S
    out = {
        "tokens": _struct((B, n_text), jnp.int32, mesh, rules, tok_axes),
    }
    if sh.kind == "train":
        out["labels"] = _struct((B, n_text), jnp.int32, mesh, rules, tok_axes)
        out["loss_mask"] = _struct((B, n_text), jnp.float32, mesh, rules, tok_axes)
    if cfg.encoder_layers:
        out["memory"] = _struct((B, cfg.encoder_len, cfg.d_model), jnp.float32,
                                mesh, rules, ("batch", None, None))
    if cfg.n_img_tokens:
        out["img_embeds"] = _struct((B, cfg.n_img_tokens, cfg.d_model),
                                    jnp.float32, mesh, rules,
                                    ("batch", None, None))
    return out


def input_specs(arch: str, shape_name: str, mesh: Optional[Mesh],
                cfg=None, rule_overrides=None, zero1: bool = False) -> Dict[str, Any]:
    """Everything a step function consumes, as ShapeDtypeStructs.

    train  -> {params, opt_state, batch}
    prefill-> {params, batch}
    decode -> {params, cache, tokens}

    zero1: ZeRO-1 variant — combined with the ``{"embed": None,
    "opt_embed": ("data", "pod")}`` rule override it stores params
    model-sharded/data-replicated while the optimizer moments shard over the
    data axis (§Perf).
    """
    cfg = cfg or get_config(arch)
    sh = SHAPES[shape_name]
    model = build_model(cfg)
    rules = data_spec(mesh, rule_overrides)
    pspecs = model.param_specs()
    params = shape_structs(pspecs, mesh, rules)
    if sh.kind == "train":
        from repro.optim import AdamWConfig, adamw_init_specs

        ocfg = AdamWConfig(quantize_moments=cfg.name.startswith("llama4"))
        ospecs = adamw_init_specs(
            pspecs, ocfg, remap_axes={"embed": "opt_embed"} if zero1 else None)
        return {
            "params": params,
            "opt_state": shape_structs(ospecs, mesh, rules),
            "batch": batch_specs(cfg, shape_name, mesh, rule_overrides),
        }
    if sh.kind == "prefill":
        return {"params": params,
                "batch": batch_specs(cfg, shape_name, mesh, rule_overrides)}
    # decode
    cspecs = model.cache_specs(sh.global_batch, sh.seq_len)
    cache = shape_structs(cspecs, mesh, rules)
    tokens = _struct((sh.global_batch, 1), jnp.int32, mesh, rules,
                     ("batch", None))
    return {"params": params, "cache": cache, "tokens": tokens}


def param_structs(cfg, mesh: Optional[Mesh]):
    model = build_model(cfg)
    return shape_structs(model.param_specs(), mesh)
