"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` (and a naive grep of the HLO text) counts
a ``while`` body **once** — but our models scan over layers, so flops,
bytes and collective traffic inside the loop execute ``trip_count`` times.
This module walks the compiled HLO module:

* splits it into computations,
* builds a per-computation symbol table (``%name -> shape``),
* costs each computation: dot flops (2·|out|·|contraction|), collective
  bytes per kind (largest typed buffer on the op line — a faithful per-device
  proxy for AR(out=in)/AG(out)/RS(in)/A2A), and an HBM-traffic proxy
  (Σ output-buffer bytes of top-level ops, ×2 for reads),
* recursively multiplies ``while`` bodies by their trip count (parsed from
  the loop condition's comparison constant) and follows ``call``/fusion
  references,
* returns totals for the entry computation.

Validated against analytic 6·N·D math in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "COLLECTIVES"]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

#: ops whose outputs the HBM-traffic proxy counts.  The dry-run compiles for
#: the CPU backend, which fuses far less aggressively than TPU — standalone
#: elementwise/convert/broadcast ops would fuse into their consumers on TPU,
#: so counting them would overstate HBM traffic ~10x.  We count the ops that
#: genuinely materialize buffers on TPU: matmuls, fusions, data movement,
#: reductions and scatter/gather.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "copy", "transpose",
    "concatenate", "pad", "reverse", "sort", "select-and-scatter", "slice",
    "iota", "rng", "cholesky", "triangular-solve", "fft",
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"(\d+)"')


def _shape_info(typestr: str) -> Tuple[int, List[int], Optional[str]]:
    """bytes, dims, dtype of the *first* typed buffer in a type string."""
    m = _SHAPE_RE.search(typestr)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0, [], None
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt], dims, dt


def _all_buffer_bytes(line: str) -> List[int]:
    out = []
    for dt, dims in _SHAPE_RE.findall(line):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.shapes: Dict[str, Tuple[int, List[int], Optional[str]]] = {}


def _split_computations(hlo: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = _Comp(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ls = line.strip()
        cur.lines.append(ls)
        dm = _DEF_RE.match(ls)
        if dm:
            cur.shapes[dm.group(1)] = _shape_info(dm.group(2))
    return comps, entry


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\),.*?condition=%?([\w\.\-]+),.*?body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: _Comp) -> int:
    """Scan-canonical loops compare the induction var against a constant."""
    best = 1
    for ls in cond.lines:
        if "compare(" in ls:
            # constant may be inline or defined earlier in the computation
            m = _CONST_RE.search(ls)
            if m:
                best = max(best, int(m.group(1)))
            else:
                for op in _OPERAND_RE.findall(ls.split("compare(")[1]):
                    for l2 in cond.lines:
                        if l2.startswith(f"%{op} ") or l2.startswith(f"{op} "):
                            m2 = _CONST_RE.search(l2)
                            if m2:
                                best = max(best, int(m2.group(1)))
    return best


def _op_name(ls: str) -> Optional[str]:
    """The HLO opcode of a definition line."""
    dm = _DEF_RE.match(ls)
    if not dm:
        return None
    rhs = dm.group(2)
    # strip the output type: first token(s) up to the op name
    m = re.search(r"(?:\)|\]|\}|\w)\s+([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else None


def _dot_flops(comp: _Comp, ls: str) -> float:
    dm = _DEF_RE.match(ls)
    if not dm:
        return 0.0
    out_bytes, out_dims, out_dt = _shape_info(dm.group(2))
    out_numel = math.prod(out_dims) if out_dims else 0
    # contraction size: product of lhs contracting dims
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ls)
    inner = ls.split("dot(", 1)[1] if "dot(" in ls else ""
    args = inner.split("),", 1)[0] if inner else ""
    ops = _OPERAND_RE.findall(args)
    contract = 1
    if cm and ops:
        lhs = comp.shapes.get(ops[0])
        if lhs and lhs[1]:
            for d in cm.group(1).split(","):
                if d and int(d) < len(lhs[1]):
                    contract *= lhs[1][int(d)]
        else:
            # operand type inline in the dot args
            _, dims, _ = _shape_info(args)
            for d in cm.group(1).split(","):
                if d and int(d) < len(dims):
                    contract *= dims[int(d)]
    return 2.0 * out_numel * contract


def _conv_flops(comp: _Comp, ls: str) -> float:
    dm = _DEF_RE.match(ls)
    if not dm:
        return 0.0
    _, out_dims, _ = _shape_info(dm.group(2))
    out_numel = math.prod(out_dims) if out_dims else 0
    inner = ls.split("convolution(", 1)[1] if "convolution(" in ls else ""
    ops = _OPERAND_RE.findall(inner.split("),", 1)[0]) if inner else []
    if len(ops) >= 2:
        rhs = comp.shapes.get(ops[1])
        if rhs and rhs[1]:
            _, out_full, _ = _shape_info(dm.group(2))
            # flops = 2 * out_numel * (kernel numel / out_channels)
            kn = math.prod(rhs[1])
            # out feature dim is usually the last dim of out
            of = out_full[-1] if out_full else 1
            return 2.0 * out_numel * (kn / max(of, 1))
    return 0.0


def _dus_update_bytes(comp: "_Comp", comps: Dict[str, "_Comp"], ls: str,
                      op: str) -> Optional[int]:
    """Bytes actually written by (possibly fused) dynamic-update-slice."""
    def update_size(c: _Comp, line: str) -> Optional[int]:
        inner = line.split("dynamic-update-slice(", 1)
        if len(inner) < 2:
            return None
        ops = _OPERAND_RE.findall(inner[1].split(")", 1)[0])
        if len(ops) >= 2 and ops[1] in c.shapes:
            return c.shapes[ops[1]][0]
        return None

    if op == "dynamic-update-slice":
        return update_size(comp, ls)
    if op == "fusion":
        for ref in _CALLS_RE.findall(ls):
            sub = comps.get(ref)
            if sub is None:
                continue
            for l2 in sub.lines:
                if l2.startswith("ROOT") and "dynamic-update-slice(" in l2:
                    return update_size(sub, l2)
    return None


def analyze_hlo(hlo: str, top_k: int = 25) -> Dict:
    comps, entry = _split_computations(hlo)
    cache: Dict[str, Dict] = {}
    _OPNAME_RE = re.compile(r'op_name="([^"]+)"')

    def _z():
        return {"flops": 0.0, "bytes_out": 0.0,
                "coll": {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES},
                "coll_lines": [], "buf_lines": []}

    def _merge(total, sub, scale):
        total["flops"] += scale * sub["flops"]
        total["bytes_out"] += scale * sub["bytes_out"]
        for k in COLLECTIVES:
            total["coll"][k]["count"] += scale * sub["coll"][k]["count"]
            total["coll"][k]["bytes"] += scale * sub["coll"][k]["bytes"]
        for kind, b, label in sub["coll_lines"]:
            total["coll_lines"].append((kind, scale * b, label))
        for b, label in sub["buf_lines"]:
            total["buf_lines"].append((scale * b, label))

    def _label(ls: str) -> str:
        m = _OPNAME_RE.search(ls)
        if m:
            return m.group(1)[-120:]
        return ls.split(",")[0][:120]

    def cost(name: str, stack=()) -> Dict:
        if name in cache:
            return cache[name]
        comp = comps.get(name)
        if comp is None or name in stack:
            return _z()
        total = _z()
        for ls in comp.lines:
            op = _op_name(ls)
            if op is None:
                continue
            if op == "while":
                m = _WHILE_RE.search(ls)
                if m:
                    tm = _TRIP_RE.search(ls)  # XLA annotates scan loops
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = _trip_count(comps.get(m.group(1), _Comp(""))) 
                    _merge(total, cost(m.group(2), stack + (name,)), trips)
                continue
            if op in ("call", "fusion", "conditional", "async-start"):
                for ref in _CALLS_RE.findall(ls):
                    _merge(total, cost(ref, stack + (name,)), 1)
                # fusions also produce an output buffer (counted below)
            matched_coll = None
            for k in COLLECTIVES:
                if op == k or op == f"{k}-start":
                    matched_coll = k
                    break
            if matched_coll:
                bufs = _all_buffer_bytes(ls)
                b = max(bufs) if bufs else 0
                total["coll"][matched_coll]["count"] += 1
                total["coll"][matched_coll]["bytes"] += b
                total["coll_lines"].append((matched_coll, b, _label(ls)))
                continue
            if op == "dot":
                total["flops"] += _dot_flops(comp, ls)
            elif op == "convolution":
                total["flops"] += _conv_flops(comp, ls)
            dm = _DEF_RE.match(ls)
            if dm and op in _TRAFFIC_OPS:
                b = _shape_info(dm.group(2))[0]
                # dynamic-update-slice writes only the *update*, not the whole
                # aliased buffer (scan stacking would otherwise over-count by
                # the trip count) — use the update operand's size.
                ub = _dus_update_bytes(comp, comps, ls, op)
                if ub is not None:
                    b = ub
                total["bytes_out"] += b
                if b >= 16 * 2**20:  # track big buffers for diagnostics
                    total["buf_lines"].append((b, _label(ls)))
        # aggregate duplicate labels so cache entries stay small
        def _agg_coll(lines):
            agg = {}
            for kind, b, label in lines:
                key = (kind, label)
                agg[key] = agg.get(key, 0.0) + b
            return [(k[0], v, k[1]) for k, v in
                    sorted(agg.items(), key=lambda kv: -kv[1])[: top_k]]

        def _agg_buf(lines):
            agg = {}
            for b, label in lines:
                agg[label] = agg.get(label, 0.0) + b
            return [(v, k) for k, v in
                    sorted(agg.items(), key=lambda kv: -kv[1])[: top_k]]

        total["coll_lines"] = _agg_coll(total["coll_lines"])
        total["buf_lines"] = _agg_buf(total["buf_lines"])
        cache[name] = total
        return total

    if entry is None:
        return {"flops": 0.0, "bytes_traffic_est": 0.0,
                "coll": {k: {"count": 0, "bytes": 0} for k in COLLECTIVES},
                "collective_bytes": 0.0, "top_collectives": [],
                "top_buffers": []}
    e = cost(entry)
    return {
        "flops": e["flops"],
        "bytes_traffic_est": 2.0 * e["bytes_out"],  # writes + reads proxy
        "coll": e["coll"],
        "collective_bytes": sum(v["bytes"] for v in e["coll"].values()),
        "top_collectives": [
            {"kind": k, "bytes": b, "op": lab} for k, b, lab in e["coll_lines"]
        ],
        "top_buffers": [
            {"bytes": b, "op": lab} for b, lab in e["buf_lines"]
        ],
    }
