"""Production mesh construction.

v5e pod topology: 16×16 = 256 chips per pod; the multi-pod mesh adds a
leading "pod" axis (2 pods = 512 chips) used purely as an extra
data-parallel axis (batch shards over ("pod", "data")) — cross-pod traffic
is then only the gradient reduction, which is the right thing to put on the
slower inter-pod links.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import; tests run
on 1 device).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_decode_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_decode_mesh(model: int = 0):
    """Tensor-parallel decode mesh: all of ``model`` on one axis, data=1.

    The batch-starved decode GEMV has no batch to shard; what needs sharding
    is the *weight state* — for PCILT layers the ``[G, V, O]`` tables, whose
    segment axis shards over ``"model"`` (``nn.module.DEFAULT_RULES``
    ``"table_seg"``) with the partial adder-tree sums psum'd.  ``model=0``
    (default) spans every local device; tests pass 1/2/4/8 under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    return make_host_mesh(1, model or jax.device_count())
