"""Serving launcher: batched prefill + decode with continuous batching,
hardened for faults.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 8`` runs a small
request stream through the engine on CPU (smoke config); on a pod the same
engine serves the full config with the production mesh.

Engine: fixed decode batch of slots; requests queue in, prefill fills a
slot's state, decode steps the whole batch every tick, finished slots are
recycled (continuous batching).  With ``--pcilt`` the decode runs the
paper's converted table path (``core.serving.convert_mamba_decode``) under a
:class:`repro.core.serving.HealthMonitor`: table integrity is spot-checked
one layer per tick, and a breached layer is demoted to its exact dense
fake-quant oracle — serving continues, degraded and logged, never wrong.

Resilience contract (``docs/resilience.md`` has the full matrix):

* **tick-level try/restore** — every committed tick checkpoints the full
  engine state (cache, tokens, slots, queue, request fields) into a bounded
  ring; any step fault restores the latest checkpoint and replays, up to
  ``max_restarts`` (``Supervisor`` semantics, applied to serving);
* **never wrong** — a table-corruption breach detected at tick ``k`` may
  have poisoned commits back to the breached layer's ``last_verified``
  tick, so the engine rolls back *to that tick* and replays with the layer
  demoted: every token a request ends up with was produced by verified
  tables or the dense oracle;
* **deadlines** — a request exceeding ``deadline_s`` is evicted, its slot
  state zeroed, and requeued with exponential backoff for up to
  ``max_retries`` attempts before it is failed (bounded, never lost
  silently);
* **watchdog** — decode tick wall times feed a
  :class:`repro.runtime.StepWatchdog`; straggler ticks land in the stats;
* **accounting** — every request ends in exactly one outcome
  (``served`` / ``degraded`` / ``failed``), derived from request state at
  the end so checkpoint replays can never double-count.

``--chaos`` drives the engine through every injected fault class
(scheduled tick fault, NaN-poisoned state, corrupted projection stack,
flipped head ``seg_idx`` pointers, garbled autotune cache) and exits
non-zero if any request is lost or the served tokens diverge from a
fault-free reference run — the CI smoke for the resilience layer.
"""

from __future__ import annotations

import argparse
import logging
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.nn.module import materialize, shape_structs
from repro.launch.steps import make_decode_step, make_prefill_step, make_ctx
from repro.runtime import StepWatchdog

log = logging.getLogger("repro.serve")


class _Degraded(Exception):
    """Health breach: roll back to ``target_tick`` and replay demoted."""

    def __init__(self, target_tick: int, events):
        super().__init__(f"health breach; replay from tick {target_tick}")
        self.target_tick = target_tick
        self.events = events


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int,
                 deadline_s: Optional[float] = None, max_retries: int = 2):
        self.rid = rid
        self.prompt = np.asarray(prompt)
        self.max_new = max_new
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.out: List[int] = []
        self.done = False
        #: queued | active | served | degraded | failed
        self.outcome = "queued"
        self.retries = 0
        #: True when any committed token was produced under demotion
        self.degraded = False
        self.t_admit = 0.0
        self.not_before = 0.0  # backoff gate for requeued requests


class Engine:
    """Slot-based continuous batching with checkpointed fault recovery."""

    def __init__(self, cfg, max_len: int = 256, slots: int = 4, mesh=None, *,
                 pcilt: bool = False, pcilt_bundle: Optional[Dict] = None,
                 oracle_every: int = 4, max_restarts: int = 8,
                 ckpt_keep: Optional[int] = None, chaos: Optional[Dict] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.slots = slots
        self.mesh = mesh
        self.max_restarts = max_restarts
        self.params = materialize(self.model.param_specs(), jax.random.PRNGKey(0))
        cspecs = self.model.cache_specs(slots, max_len)
        self.cache = materialize(cspecs, jax.random.PRNGKey(1))
        self.cache = dict(self.cache, pos=jnp.asarray(0, jnp.int32))
        self.decode = jax.jit(make_decode_step(cfg, mesh))
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        #: chaos schedule {step_count: [fn(engine)]} keyed on the monotone
        #: ``self.steps`` counter (prefill + decode steps; never rewound by a
        #: restore); entries pop one-shot, so a checkpoint replay of a
        #: faulted step runs clean
        self.chaos = dict(chaos or {})
        self.ckpts: deque = deque(
            maxlen=ckpt_keep or (int(cfg.n_layers) + 4))
        self.queue: List[Request] = []
        self._requests: List[Request] = []
        self.tick = 0
        self.steps = 0  # monotone prefill+decode step count (chaos clock)
        self.prefill_ticks = 0
        self.restarts = 0
        self.rollbacks = 0

        self.pdecode = None
        self.monitor = None
        if pcilt:
            from repro.core.serving import (HealthMonitor, PCILTMambaDecode,
                                            convert_mamba_decode)

            if cfg.pcilt is None:
                raise ValueError(
                    "Engine(pcilt=True) requires cfg.pcilt (a configs.base."
                    "PCILTConfig) — set cfg = dataclasses.replace(cfg, "
                    "pcilt=PCILTConfig(...)) before constructing")
            ctx = make_ctx(mesh, None, decode=True)
            if pcilt_bundle is not None:
                self.pdecode = PCILTMambaDecode(self.model, pcilt_bundle, ctx)
            else:
                calib = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                           cfg.vocab)
                self.pdecode = convert_mamba_decode(
                    self.model, self.params, calib, ctx, head="shared")
            self.monitor = HealthMonitor(self.pdecode, self.params,
                                         oracle_every=oracle_every)

    # -- stepping ------------------------------------------------------------

    def _raw_step(self):
        toks = jnp.asarray(self.tokens)
        if self.pdecode is not None:
            lmask, hmask = self.monitor.ok_masks()
            logits, new_cache = self.pdecode.step(self.params, self.cache,
                                                  toks, lmask, hmask)
            if self.cfg.padded_vocab > self.cfg.vocab:  # never sample padding
                neg = jnp.full((self.cfg.padded_vocab - self.cfg.vocab,),
                               -1e30, logits.dtype)
                logits = logits.at[..., self.cfg.vocab:].set(neg)
        else:
            logits, new_cache = self.decode(self.params, self.cache, toks)
        return logits, new_cache

    def _step(self):
        # chaos clock: fire every due injection exactly once, before the
        # forward — a raise here surfaces as a step fault (restore + replay)
        for k in sorted(k for k in self.chaos if k <= self.steps):
            for act in self.chaos.pop(k):
                act(self)
        self.steps += 1
        logits, new_cache = self._raw_step()
        # finite gate BEFORE committing: NaN/Inf outputs (poisoned state,
        # numerical blowup) trigger restore-and-replay, never a sampled token.
        # The recurrent state must be gated too, not just the logits: the
        # PCILT path quantizes activations to integer table indices, which
        # *launders* NaN into a valid (wrong) lookup — poisoned ssd state
        # yields finite logits while the corruption persists in the cache.
        checks = [jnp.all(jnp.isfinite(logits))]
        checks += [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(new_cache)
                   if jnp.issubdtype(l.dtype, jnp.floating)]
        if not bool(jnp.all(jnp.stack(checks))):
            raise RuntimeError("non-finite decode outputs or state (NaN/Inf)")
        self.cache = new_cache
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _prefill_into_slot(self, slot: int, req: Request):
        """Feed the prompt through decode steps (teacher-forced prefill).

        Production pods run the fused ``prefill_step`` over the whole prompt;
        the slot engine replays tokens through the decode path so a single
        compiled step serves both phases (classic small-deployment trade).

        Concurrently active slots keep *generating* during these ticks —
        their cache advances either way, so their sampled tokens must be
        committed, not dropped (dropping them skipped every token a slot
        sampled while a neighbor prefilled).  The step that consumes the
        final prompt token emits the request's first generated token."""
        req.outcome = "active"
        req.t_admit = time.time()
        # an idle slot still steps with the batch (its outputs dropped), so
        # its recurrent state is garbage by now — start from a clean slate or
        # the request's tokens depend on what the slot did while unowned
        self._reset_slot(slot)
        last = 0
        for t in req.prompt:
            self.tokens[slot, 0] = int(t)
            out = self._step()
            self.prefill_ticks += 1
            self._commit_tokens(out, skip=slot)
            last = int(out[slot])
        self.active[slot] = req
        req.out.append(last)
        self.tokens[slot, 0] = last
        self._finish_if_done(slot)

    def _commit_tokens(self, nxt, skip: Optional[int] = None):
        degraded_now = self.monitor is not None and self.monitor.degraded
        for s, req in enumerate(self.active):
            if req is None or s == skip:
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self.tokens[s, 0] = tok
            if degraded_now:
                req.degraded = True
            self._finish_if_done(s)

    def _finish_if_done(self, s: int):
        req = self.active[s]
        if req is not None and len(req.out) >= req.max_new:
            req.done = True
            req.outcome = "degraded" if req.degraded else "served"
            self.active[s] = None
            self._reset_slot(s)

    def _reset_slot(self, s: int):
        """Zero one slot's recurrent/cache state so a recycled (or evicted)
        slot can never leak a previous request's context into the next."""
        def z(a):
            if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == self.slots:
                return a.at[:, s].set(0)
            return a

        self.cache = dict(self.cache,
                          layers=jax.tree.map(z, self.cache["layers"]))

    # -- checkpoint ring -----------------------------------------------------

    def _checkpoint(self):
        """Snapshot the full engine state (jax arrays are immutable — holding
        the refs *is* the snapshot; host-side state is copied)."""
        self.ckpts.append({
            "tick": self.tick,
            "cache": self.cache,
            "tokens": self.tokens.copy(),
            "active": list(self.active),
            "queue": list(self.queue),
            "reqs": {r.rid: (list(r.out), r.done, r.outcome, r.retries,
                             r.degraded, r.t_admit, r.not_before)
                     for r in self._requests},
        })

    def _restore(self, target_tick: int):
        """Restore the newest checkpoint at or before ``target_tick``
        (falling back to the oldest retained — the ring bounds how far back
        a restore can reach, and the monitor's per-tick verification bounds
        how far back one ever *needs* to reach)."""
        snaps = [c for c in self.ckpts if c["tick"] <= target_tick]
        snap = snaps[-1] if snaps else self.ckpts[0]
        # drop now-stale snapshots of ticks the replay will redo
        keep = [c for c in self.ckpts if c["tick"] <= snap["tick"]
                and c is not snap] + [snap]
        self.ckpts = deque(keep, maxlen=self.ckpts.maxlen)
        self.cache = snap["cache"]
        self.tokens = snap["tokens"].copy()
        self.active = list(snap["active"])
        self.queue = list(snap["queue"])
        for r in self._requests:
            out, done, outcome, retries, degraded, t_admit, nb = \
                snap["reqs"][r.rid]
            r.out, r.done, r.outcome = list(out), done, outcome
            r.retries, r.degraded, r.t_admit, r.not_before = \
                retries, degraded, t_admit, nb
        self.tick = snap["tick"]
        if self.monitor is not None:
            # a verification recorded at a now-rewound tick no longer vouches
            # for any committed token — clamp so a later breach rolls back
            # far enough
            np.minimum(self.monitor.last_verified, self.tick,
                       out=self.monitor.last_verified)
            self.monitor.head_last_verified = min(
                self.monitor.head_last_verified, self.tick)
        log.warning("restored engine state at tick %d", self.tick)

    # -- deadlines -----------------------------------------------------------

    def _enforce_deadlines(self):
        now = time.time()
        for s, req in enumerate(self.active):
            if req is None or req.deadline_s is None:
                continue
            if now - req.t_admit <= req.deadline_s:
                continue
            self.active[s] = None
            self._reset_slot(s)
            req.out = []
            req.degraded = False
            req.retries += 1
            if req.retries > req.max_retries:
                req.done = True
                req.outcome = "failed"
                log.error("req %d failed: deadline %.3fs exceeded %d times",
                          req.rid, req.deadline_s, req.retries)
            else:
                req.not_before = now + 0.05 * (2 ** (req.retries - 1))
                req.outcome = "queued"
                self.queue.append(req)
                log.warning("req %d missed deadline; requeued (retry %d/%d, "
                            "backoff %.3fs)", req.rid, req.retries,
                            req.max_retries, req.not_before - now)

    # -- main loop -----------------------------------------------------------

    def run(self, requests: List[Request], greedy: bool = True):
        self.queue = list(requests)
        self._requests = list(requests)
        for r in requests:
            r.outcome = "queued"
        t0 = time.time()
        self.tick = 0
        self.prefill_ticks = 0
        self.ckpts.clear()
        self._checkpoint()
        watchdog = StepWatchdog()
        while self.queue or any(r is not None for r in self.active):
            try:
                t_tick = time.time()
                now = time.time()
                for s in range(self.slots):
                    if self.active[s] is not None or not self.queue:
                        continue
                    i = next((i for i, r in enumerate(self.queue)
                              if r.not_before <= now), None)
                    if i is None:
                        break  # every queued request is backing off
                    self._prefill_into_slot(s, self.queue.pop(i))
                if not any(r is not None for r in self.active):
                    time.sleep(0.005)  # wait out the shortest backoff
                    continue
                nxt = self._step()
                if self.monitor is not None:
                    breaches = self.monitor.on_tick(self.tick)
                    if breaches:
                        # commits since the breached layer was last verified
                        # may be corrupt — rewind there and replay demoted
                        lv = [int(self.monitor.last_verified[e["layer"]])
                              for e in breaches if e["layer"] is not None]
                        lv += [int(self.monitor.head_last_verified)
                               for e in breaches if e["kind"] == "head"]
                        raise _Degraded(max(min(lv), 0), breaches)
                self._commit_tokens(nxt)
                self._enforce_deadlines()
                watchdog.observe(self.tick, time.time() - t_tick)
                self.tick += 1
                self._checkpoint()
            except _Degraded as d:
                self.rollbacks += 1
                log.warning("rolling back to tick <= %d after %d breach(es)",
                            d.target_tick, len(d.events))
                self._restore(d.target_tick)
            except Exception as e:  # noqa: BLE001 — any tick fault
                self.restarts += 1
                log.error("decode tick %d failed (%s); restart %d/%d",
                          self.tick, e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                self._restore(self.tick)
        dt = time.time() - t0
        # outcome accounting from final request state — replays through the
        # checkpoint ring can never double-count
        outcomes = {r.rid: r.outcome for r in self._requests}
        stats = {
            "decode_ticks": self.tick,
            "prefill_ticks": self.prefill_ticks,
            "wall_s": dt,
            "served": sum(o == "served" for o in outcomes.values()),
            "degraded": sum(o == "degraded" for o in outcomes.values()),
            "failed": sum(o == "failed" for o in outcomes.values()),
            "retried": sum(r.retries > 0 for r in self._requests),
            "restarts": self.restarts,
            "rollbacks": self.rollbacks,
            "straggler_ticks": list(watchdog.flagged),
            "outcomes": outcomes,
        }
        if self.monitor is not None:
            stats["health_events"] = list(self.monitor.events)
        return stats


def _chaos_plan(eng: Engine, injector):
    """The fault schedule the ``--chaos`` smoke drives: one action per fault
    class, each exercising its detection + response end to end."""
    from repro.kernels import autotune as atn

    def garble_autotune(e):
        cache = atn.get_cache()
        # make sure there are bytes to garble, then corrupt them in place;
        # the reload must warn + quarantine, never crash or silently reset
        cache.record("chaos_probe|B=1,dtype=float32|backend=cpu",
                     atn.TileConfig(Bb=8, Gb=1, Ob=128), None, 0)
        injector.garble_file(cache.path, "garbage")
        atn.reset_cache(cache.path)

    def poison_state(e):
        layers = e.cache["layers"]
        e.cache = dict(e.cache, layers=dict(
            layers, ssd=injector.poison(layers["ssd"], "nan", n=4)))

    def corrupt_proj(e):
        tabs = e.pdecode.pcilt["proj"]["tables"]
        tabs["wx"] = injector.corrupt_table(tabs["wx"], n_flips=2)
        e.pdecode.rehoist()  # jit closed over the old arrays

    def flip_head(e):
        head = e.pdecode.pcilt["head"]
        head["seg_idx"] = injector.flip_seg_idx(
            head["seg_idx"], n_pool=head["pool"].shape[0])
        e.pdecode.rehoist()

    # keyed on the monotone step counter (prefill + decode steps) so every
    # entry fires even when requests finish during neighbors' prefill ticks
    return {
        4: [garble_autotune],
        7: [lambda e: injector.maybe_fail(7)],
        11: [poison_state],
        15: [corrupt_proj],
        19: [flip_head],
    }


def _make_requests(cfg, n: int, max_new: int, deadline: Optional[float],
                   seed: int) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(4, 12)),
                    max_new, deadline_s=deadline) for i in range(n)]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--full", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--pcilt", action="store_true",
                   help="serve the converted PCILT decode path (Mamba archs) "
                        "under the health monitor")
    p.add_argument("--chaos", action="store_true",
                   help="drive the fault-injection schedule and verify the "
                        "resilience contract (implies a reference run)")
    p.add_argument("--deadline", type=float, default=None,
                   help="per-request deadline in seconds")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    logging.basicConfig(level=logging.WARNING)
    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.n_img_tokens or cfg.encoder_layers:
        raise SystemExit("serve demo targets text decoder archs")
    if args.pcilt:
        import dataclasses as dc
        import os
        import tempfile

        from repro.configs.base import PCILTConfig

        if cfg.ssm is None:
            raise SystemExit("--pcilt serves the converted Mamba decode "
                             "path; pick an [ssm] arch (e.g. mamba2-130m)")
        cfg = dc.replace(cfg, pcilt=PCILTConfig(act_bits=4, group=2),
                         dtype=jnp.float32)
        if args.chaos and "REPRO_PCILT_TUNE_CACHE" not in os.environ:
            # the chaos plan garbles the autotune cache file — never the
            # user's real one
            from repro.kernels import autotune as atn

            atn.reset_cache(os.path.join(tempfile.mkdtemp(), "tiles.json"))

    reqs = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                          args.seed)

    injector = None
    eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt)
    if args.chaos:
        from repro.runtime.faults import FaultInjector

        injector = FaultInjector(fail_at=(7,), seed=args.seed)
        if eng.pdecode is not None:
            eng.chaos = _chaos_plan(eng, injector)
        else:
            eng.chaos = {4: [lambda e: injector.maybe_fail(7)]}

    stats = eng.run(reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}... "
              f"[{r.outcome}]")
    n_completed = sum(r.outcome in ("served", "degraded") for r in reqs)
    print(f"served {n_completed} requests in {stats['wall_s']:.2f}s "
          f"({stats['decode_ticks']} decode ticks)")
    if stats["degraded"] or stats["restarts"] or stats["rollbacks"]:
        print(f"resilience: degraded={stats['degraded']} "
              f"retried={stats['retried']} failed={stats['failed']} "
              f"restarts={stats['restarts']} rollbacks={stats['rollbacks']}")

    if args.chaos:
        _verify_chaos_contract(cfg, args, eng, reqs, stats, injector)


def _verify_chaos_contract(cfg, args, eng, reqs, stats, injector):
    """The CI gate: no request lost, fault-free-identical tokens, and the
    demoted path equal to the dense fake-quant oracle.  Exits non-zero on
    any violation."""
    lost = [r.rid for r in reqs if r.outcome not in ("served", "degraded")]
    if lost:
        raise SystemExit(f"chaos contract violated: requests lost: {lost}")
    if not injector.events:
        raise SystemExit("chaos smoke injected no faults — schedule never "
                         "fired (engine finished too fast?)")
    if eng.chaos:
        raise SystemExit(f"chaos smoke left faults unfired at step keys "
                         f"{sorted(eng.chaos)} (engine ran only "
                         f"{eng.steps} steps)")

    # fault-free reference run: same params (PRNGKey(0)), same request stream.
    # Undegraded requests must be token-identical; degraded requests ran
    # (partly) through the dense-oracle path, which is allclose-but-not-
    # bitwise to PCILT — their correctness is covered by the oracle-
    # equivalence check below, not token identity.
    ref_eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt)
    ref = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                         args.seed)
    ref_eng.run(ref)
    mismatched = [r.rid for r, q in zip(reqs, ref)
                  if r.outcome == "served" and r.out != q.out]
    if mismatched:
        raise SystemExit(
            f"chaos contract violated: undegraded tokens diverge from the "
            f"fault-free run for requests {mismatched}")
    n_exact = sum(r.outcome == "served" for r in reqs)

    if eng.pdecode is not None:
        # demoted decode == dense fake-quant oracle (one explicit step)
        pc_fq = dict(eng.pdecode.pcilt)
        proj = pc_fq.get("proj")
        B = args.slots
        cspecs = eng.model.cache_specs(B, 256)
        cache = materialize(cspecs, jax.random.PRNGKey(5))
        cache = dict(cache, pos=jnp.asarray(1, jnp.int32))
        tok = np.full((B, 1), 3, np.int32)
        la = jnp.zeros((cfg.n_layers,), bool)
        got, _ = eng.pdecode.step(eng.params, cache, jnp.asarray(tok),
                                  layer_ok=la, head_ok=jnp.asarray(False))
        if proj is not None:
            pc_fq["proj"] = dict(proj, path="dense_fq")
        ref_step = jax.jit(lambda p, c, t: eng.model.decode_step(
            p, c, t, make_ctx(None, None, decode=True), pcilt=pc_fq,
            head_ok=jnp.asarray(False)))
        want, _ = ref_step(eng.params, cache, jnp.asarray(tok))
        if not np.allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                           atol=1e-4):
            raise SystemExit("chaos contract violated: demoted decode "
                             "diverges from the dense fake-quant oracle")
    print(f"chaos contract verified: {len(reqs)} requests completed "
          f"({n_exact} token-identical to fault-free run, "
          f"{len(injector.events)} faults injected, "
          f"{stats['restarts']} restarts, {stats['rollbacks']} rollbacks, "
          f"{stats['degraded']} degraded)")


if __name__ == "__main__":
    main()
