"""Serving launcher: batched prefill + decode with continuous batching.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 8`` runs a small
request stream through the engine on CPU (smoke config); on a pod the same
engine serves the full config with the production mesh.

Engine: fixed decode batch of slots; requests queue in, prefill fills a
slot's KV pages, decode steps the whole batch every tick, finished slots are
recycled (continuous batching).  With ``--pcilt`` the decode projections run
the paper's quantized-LUT path and the engine verifies the LUT outputs
against the dense oracle on the first step (PCILT is exact on the quantized
grid — paper §Basic Version).
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.nn.module import materialize, shape_structs
from repro.launch.steps import make_decode_step, make_prefill_step, make_ctx


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.out: List[int] = []
        self.done = False


class Engine:
    """Slot-based continuous batching over a single decode step function."""

    def __init__(self, cfg, max_len: int = 256, slots: int = 4, mesh=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.slots = slots
        self.mesh = mesh
        self.params = materialize(self.model.param_specs(), jax.random.PRNGKey(0))
        cspecs = self.model.cache_specs(slots, max_len)
        self.cache = materialize(cspecs, jax.random.PRNGKey(1))
        self.cache = dict(self.cache, pos=jnp.asarray(0, jnp.int32))
        self.decode = jax.jit(make_decode_step(cfg, mesh))
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)

    def _prefill_into_slot(self, slot: int, req: Request):
        """Feed the prompt through decode steps (teacher-forced prefill).

        Production pods run the fused ``prefill_step`` over the whole prompt;
        the slot engine replays tokens through the decode path so a single
        compiled step serves both phases (classic small-deployment trade)."""
        for t in req.prompt:
            self.tokens[slot, 0] = int(t)
            self._step()
        self.active[slot] = req

    def _step(self):
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.asarray(self.tokens))
        return np.asarray(jnp.argmax(logits, axis=-1))

    def run(self, requests: List[Request], greedy: bool = True):
        queue = list(requests)
        t0 = time.time()
        n_decoded = 0
        while queue or any(r is not None for r in self.active):
            for s in range(self.slots):
                if self.active[s] is None and queue:
                    self._prefill_into_slot(s, queue.pop(0))
            nxt = self._step()
            n_decoded += 1
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nxt[s])
                req.out.append(tok)
                self.tokens[s, 0] = tok
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.active[s] = None
        dt = time.time() - t0
        return {"decode_ticks": n_decoded, "wall_s": dt}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--full", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    args = p.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.n_img_tokens or cfg.encoder_layers:
        raise SystemExit("serve demo targets text decoder archs")
    eng = Engine(cfg, max_len=256, slots=args.slots)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(4, 12)),
                    args.max_new) for i in range(args.requests)]
    stats = eng.run(reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}...")
    print(f"served {len(reqs)} requests in {stats['wall_s']:.2f}s "
          f"({stats['decode_ticks']} decode ticks)")


if __name__ == "__main__":
    main()
