"""Serving launcher: batched prefill + decode with continuous batching,
hardened for faults *and* load.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 8`` runs a small
request stream through the engine on CPU (smoke config); on a pod the same
engine serves the full config with the production mesh.
``--traffic poisson`` drives the same engine open-loop on a virtual clock
(seeded arrivals, analytic capacity) — the overload-control smoke.

Engine: fixed decode batch of slots; requests queue in, prefill fills a
slot's state, decode steps the whole batch every tick, finished slots are
recycled (continuous batching).  With ``--pcilt`` the decode runs the
paper's converted table path (``core.serving.convert_mamba_decode``) under a
:class:`repro.core.serving.HealthMonitor`: table integrity is spot-checked
one layer per tick, and a breached layer is demoted to its exact dense
fake-quant oracle — serving continues, degraded and logged, never wrong.

Resilience contract (``docs/resilience.md`` has the full matrix):

* **tick-level try/restore** — every committed tick checkpoints the full
  engine state (cache, tokens, slots, queue, pending arrivals, request
  fields) into a bounded ring; any step fault restores the latest
  checkpoint and replays, up to ``max_restarts`` (``Supervisor`` semantics,
  applied to serving);
* **never wrong** — a table-corruption breach detected at tick ``k`` may
  have poisoned commits back to the breached layer's ``last_verified``
  tick, so the engine rolls back *to that tick* and replays with the layer
  demoted: every token a request ends up with was produced by verified
  tables or the dense oracle;
* **deadlines** — a request exceeding ``deadline_s`` is evicted, its slot
  state zeroed, and requeued with exponential backoff for up to
  ``max_retries`` attempts before it is failed (bounded, never lost
  silently);
* **watchdog** — decode tick wall times feed a
  :class:`repro.runtime.StepWatchdog`; straggler ticks land in the stats;
* **accounting** — every request ends in exactly one outcome
  (``served`` / ``degraded`` / ``failed`` / ``rejected``), derived from
  request state at the end so checkpoint replays can never double-count.

Overload contract (``docs/serving.md`` has the full matrix):

* **bounded admission** — ``queue_limit`` caps the queue; a request
  arriving at a full queue is shed *at admission* with the typed
  ``rejected`` outcome (never a timeout discovered minutes later), and the
  estimated-service-time test additionally rejects requests whose deadline
  is already unmeetable given the backlog (doomed work is refused, not
  half-served);
* **EDF scheduling** — free slots take the eligible queued request with
  the earliest deadline (no-deadline requests sort last, FIFO tie-break),
  minimizing deadline misses under load;
* **queue-side deadline eviction** — a request that exceeds its deadline
  *while still queued* is evicted there (counted in
  ``queue_evictions``) instead of burning prefill ticks on a doomed
  attempt;
* **backpressure telemetry** — every tick appends a structured record
  (queue depth, slot occupancy, eviction counters, tick seconds) to
  ``stats["telemetry"]``; ``stats`` also carries the shed rate and the
  resident table bytes.

All time flows through an injectable ``clock`` (``Engine(clock=...)``,
default :class:`repro.runtime.WallClock`); a
:class:`repro.runtime.VirtualClock` plus ``step_cost_s`` makes every
deadline/backoff/arrival path deterministic — the CI traffic smoke runs
thousands of virtual seconds in milliseconds.

``--chaos`` drives the engine through every injected fault class
(scheduled tick fault, NaN-poisoned state, corrupted projection stack,
flipped head ``seg_idx`` pointers, garbled autotune cache) and exits
non-zero if any request is lost or the served tokens diverge from a
fault-free reference run — the CI smoke for the resilience layer.
``--chaos --traffic ...`` composes the two: faults injected mid-burst must
uphold both contracts at once.

``--chaos-drift`` exercises the calibration-drift sentinel: PCILT decode
runs *monitored* (the fused kernels emit in-kernel saturation counters), a
mid-serve parameter drift pushes one layer's activations out of the
calibrated range without corrupting a single table byte, and the contract
requires detect (typed ``drift`` demotion) -> rollback -> online
recalibration (tables rebuilt at the observed range, checksums
re-recorded, ``rehoist(verify=True)``) -> repromote, with undrifted tokens
identical to a fault-free run.  ``--no-sentinel`` is the zero-overhead
opt-out (executors compile without counter outputs).
"""

from __future__ import annotations

import argparse
import logging
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.nn.module import materialize, shape_structs
from repro.launch.steps import make_decode_step, make_prefill_step, make_ctx
from repro.runtime import StepWatchdog, WallClock

log = logging.getLogger("repro.serve")

#: every request ends in exactly one of these
OUTCOMES = ("served", "degraded", "failed", "rejected")


class _Degraded(Exception):
    """Health breach: roll back to ``target_tick`` and replay demoted."""

    def __init__(self, target_tick: int, events):
        super().__init__(f"health breach; replay from tick {target_tick}")
        self.target_tick = target_tick
        self.events = events


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int,
                 deadline_s: Optional[float] = None, max_retries: int = 2):
        self.rid = rid
        self.prompt = np.asarray(prompt)
        self.max_new = max_new
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.out: List[int] = []
        self.done = False
        #: queued | active | served | degraded | failed | rejected
        self.outcome = "queued"
        self.retries = 0
        #: True when any committed token was produced under demotion
        self.degraded = False
        self.t_arrive = 0.0  # when the request hit the engine (clock domain)
        self.t_enqueue = 0.0  # start of the current queued attempt
        self.t_admit = 0.0  # when the current attempt's prefill began
        self.t_done = 0.0  # when a terminal outcome was assigned
        self.not_before = 0.0  # backoff gate for requeued requests


class Engine:
    """Slot-based continuous batching with checkpointed fault recovery and
    bounded-admission overload control."""

    def __init__(self, cfg, max_len: int = 256, slots: int = 4, mesh=None, *,
                 pcilt: bool = False, pcilt_bundle: Optional[Dict] = None,
                 oracle_every: int = 4, max_restarts: int = 8,
                 ckpt_keep: Optional[int] = None, chaos: Optional[Dict] = None,
                 clock=None, queue_limit: Optional[int] = None,
                 step_cost_s: Optional[float] = None, sentinel: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.slots = slots
        self.mesh = mesh
        self.max_restarts = max_restarts
        #: injectable time source (`.time()` / `.sleep(s)`); the default is
        #: the wall clock — tests and the traffic bench pass a VirtualClock
        self.clock = clock if clock is not None else WallClock()
        #: bounded admission queue: None = unbounded (the closed-loop
        #: `run()` semantics), an int caps the queue and sheds beyond it
        self.queue_limit = queue_limit
        #: simulated per-step service time: each engine step advances the
        #: clock by this much (VirtualClock benches/CI); None = real time
        self.step_cost_s = step_cost_s
        self.params = materialize(self.model.param_specs(), jax.random.PRNGKey(0))
        cspecs = self.model.cache_specs(slots, max_len)
        self.cache = materialize(cspecs, jax.random.PRNGKey(1))
        self.cache = dict(self.cache, pos=jnp.asarray(0, jnp.int32))
        self.decode = jax.jit(make_decode_step(cfg, mesh))
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        #: chaos schedule {step_count: [fn(engine)]} keyed on the monotone
        #: ``self.steps`` counter (prefill + decode steps; never rewound by a
        #: restore); entries pop one-shot, so a checkpoint replay of a
        #: faulted step runs clean
        self.chaos = dict(chaos or {})
        self.ckpts: deque = deque(
            maxlen=ckpt_keep or (int(cfg.n_layers) + 4))
        self.queue: List[Request] = []
        self._requests: List[Request] = []
        self._pending: List[Tuple[float, Request]] = []
        self.tick = 0
        self.steps = 0  # monotone prefill+decode step count (chaos clock)
        self.prefill_ticks = 0
        self.restarts = 0
        self.rollbacks = 0
        self.queue_evictions = 0
        self.slot_evictions = 0
        self.telemetry: List[Dict] = []
        self._tick_ema: Optional[float] = None

        self.pdecode = None
        self.monitor = None
        #: calibration-drift sentinel: decode steps run monitored
        #: (``with_stats=True`` — in-kernel saturation counters) and feed the
        #: monitor's per-layer drift EWMAs.  ``sentinel=False`` is the
        #: zero-overhead opt-out: the unmonitored executor compiles without
        #: counter outputs, bit-identical to pre-sentinel serving.
        self.sentinel = bool(sentinel) and pcilt
        self._last_sat = None
        if pcilt:
            from repro.core.serving import (HealthMonitor, PCILTMambaDecode,
                                            convert_mamba_decode)

            if cfg.pcilt is None:
                raise ValueError(
                    "Engine(pcilt=True) requires cfg.pcilt (a configs.base."
                    "PCILTConfig) — set cfg = dataclasses.replace(cfg, "
                    "pcilt=PCILTConfig(...)) before constructing")
            ctx = make_ctx(mesh, None, decode=True)
            if pcilt_bundle is not None:
                self.pdecode = PCILTMambaDecode(self.model, pcilt_bundle, ctx)
            else:
                calib = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                           cfg.vocab)
                self.pdecode = convert_mamba_decode(
                    self.model, self.params, calib, ctx, head="shared")
            self.monitor = HealthMonitor(self.pdecode, self.params,
                                         oracle_every=oracle_every)

    # -- stepping ------------------------------------------------------------

    def _raw_step(self):
        toks = jnp.asarray(self.tokens)
        if self.pdecode is not None:
            lmask, hmask = self.monitor.ok_masks()
            if self.sentinel:
                logits, new_cache, self._last_sat = self.pdecode.step(
                    self.params, self.cache, toks, lmask, hmask,
                    with_stats=True)
            else:
                logits, new_cache = self.pdecode.step(
                    self.params, self.cache, toks, lmask, hmask)
            if self.cfg.padded_vocab > self.cfg.vocab:  # never sample padding
                neg = jnp.full((self.cfg.padded_vocab - self.cfg.vocab,),
                               -1e30, logits.dtype)
                logits = logits.at[..., self.cfg.vocab:].set(neg)
        else:
            logits, new_cache = self.decode(self.params, self.cache, toks)
        return logits, new_cache

    def _step(self):
        # chaos clock: fire every due injection exactly once, before the
        # forward — a raise here surfaces as a step fault (restore + replay)
        for k in sorted(k for k in self.chaos if k <= self.steps):
            for act in self.chaos.pop(k):
                act(self)
        self.steps += 1
        if self.step_cost_s is not None:
            self.clock.sleep(self.step_cost_s)  # simulated service time
        logits, new_cache = self._raw_step()
        # finite gate BEFORE committing: NaN/Inf outputs (poisoned state,
        # numerical blowup) trigger restore-and-replay, never a sampled token.
        # The recurrent state must be gated too, not just the logits: the
        # PCILT path quantizes activations to integer table indices, which
        # *launders* NaN into a valid (wrong) lookup — poisoned ssd state
        # yields finite logits while the corruption persists in the cache.
        checks = [jnp.all(jnp.isfinite(logits))]
        checks += [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(new_cache)
                   if jnp.issubdtype(l.dtype, jnp.floating)]
        if not bool(jnp.all(jnp.stack(checks))):
            raise RuntimeError("non-finite decode outputs or state (NaN/Inf)")
        self.cache = new_cache
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _prefill_into_slot(self, slot: int, req: Request):
        """Feed the prompt through decode steps (teacher-forced prefill).

        Production pods run the fused ``prefill_step`` over the whole prompt;
        the slot engine replays tokens through the decode path so a single
        compiled step serves both phases (classic small-deployment trade).

        Concurrently active slots keep *generating* during these ticks —
        their cache advances either way, so their sampled tokens must be
        committed, not dropped (dropping them skipped every token a slot
        sampled while a neighbor prefilled).  The step that consumes the
        final prompt token emits the request's first generated token."""
        req.outcome = "active"
        req.t_admit = self.clock.time()
        # an idle slot still steps with the batch (its outputs dropped), so
        # its recurrent state is garbage by now — start from a clean slate or
        # the request's tokens depend on what the slot did while unowned
        self._reset_slot(slot)
        last = 0
        for t in req.prompt:
            self.tokens[slot, 0] = int(t)
            out = self._step()
            self.prefill_ticks += 1
            self._commit_tokens(out, skip=slot)
            last = int(out[slot])
        self.active[slot] = req
        req.out.append(last)
        self.tokens[slot, 0] = last
        self._finish_if_done(slot)

    def _commit_tokens(self, nxt, skip: Optional[int] = None):
        # tainted = some layer was online-recalibrated: tokens are correct
        # under the *new* tables but no longer bit-comparable to the original
        # conversion, so they carry the degraded marking too
        degraded_now = self.monitor is not None and (
            self.monitor.degraded or self.monitor.tainted)
        for s, req in enumerate(self.active):
            if req is None or s == skip:
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self.tokens[s, 0] = tok
            if degraded_now:
                req.degraded = True
            self._finish_if_done(s)

    def _finish_if_done(self, s: int):
        req = self.active[s]
        if req is not None and len(req.out) >= req.max_new:
            req.done = True
            req.outcome = "degraded" if req.degraded else "served"
            req.t_done = self.clock.time()
            self.active[s] = None
            self._reset_slot(s)

    def _reset_slot(self, s: int):
        """Zero one slot's recurrent/cache state so a recycled (or evicted)
        slot can never leak a previous request's context into the next."""
        def z(a):
            if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == self.slots:
                return a.at[:, s].set(0)
            return a

        self.cache = dict(self.cache,
                          layers=jax.tree.map(z, self.cache["layers"]))

    # -- checkpoint ring -----------------------------------------------------

    def _checkpoint(self):
        """Snapshot the full engine state (jax arrays are immutable — holding
        the refs *is* the snapshot; host-side state is copied)."""
        self.ckpts.append({
            "tick": self.tick,
            "cache": self.cache,
            "tokens": self.tokens.copy(),
            "active": list(self.active),
            "queue": list(self.queue),
            "pending": list(self._pending),
            "queue_evictions": self.queue_evictions,
            "slot_evictions": self.slot_evictions,
            "reqs": {r.rid: (list(r.out), r.done, r.outcome, r.retries,
                             r.degraded, r.t_admit, r.not_before,
                             r.t_arrive, r.t_enqueue, r.t_done)
                     for r in self._requests},
        })

    def _restore(self, target_tick: int):
        """Restore the newest checkpoint at or before ``target_tick``
        (falling back to the oldest retained — the ring bounds how far back
        a restore can reach, and the monitor's per-tick verification bounds
        how far back one ever *needs* to reach)."""
        snaps = [c for c in self.ckpts if c["tick"] <= target_tick]
        snap = snaps[-1] if snaps else self.ckpts[0]
        # drop now-stale snapshots of ticks the replay will redo
        keep = [c for c in self.ckpts if c["tick"] <= snap["tick"]
                and c is not snap] + [snap]
        self.ckpts = deque(keep, maxlen=self.ckpts.maxlen)
        self.cache = snap["cache"]
        self.tokens = snap["tokens"].copy()
        self.active = list(snap["active"])
        self.queue = list(snap["queue"])
        self._pending = list(snap["pending"])
        self.queue_evictions = snap["queue_evictions"]
        self.slot_evictions = snap["slot_evictions"]
        for r in self._requests:
            (out, done, outcome, retries, degraded, t_admit, nb,
             t_arrive, t_enqueue, t_done) = snap["reqs"][r.rid]
            r.out, r.done, r.outcome = list(out), done, outcome
            r.retries, r.degraded, r.t_admit, r.not_before = \
                retries, degraded, t_admit, nb
            r.t_arrive, r.t_enqueue, r.t_done = t_arrive, t_enqueue, t_done
        self.tick = snap["tick"]
        # telemetry for replayed ticks will be re-recorded
        self.telemetry = [e for e in self.telemetry if e["tick"] < self.tick]
        if self.monitor is not None:
            # a verification recorded at a now-rewound tick no longer vouches
            # for any committed token — clamp so a later breach rolls back
            # far enough
            np.minimum(self.monitor.last_verified, self.tick,
                       out=self.monitor.last_verified)
            self.monitor.head_last_verified = min(
                self.monitor.head_last_verified, self.tick)
        log.warning("restored engine state at tick %d", self.tick)

    # -- admission / scheduling ----------------------------------------------

    def _est_ticks(self, req: Request) -> int:
        """Engine steps one attempt of ``req`` costs end to end (prefill
        replays the prompt through the decode path, then one step per
        generated token)."""
        return len(req.prompt) + req.max_new

    def _est_turnaround_s(self, req: Request) -> Optional[float]:
        """Crude service-time estimate for an arriving request: the backlog
        ahead of it (active remainders + queued attempts, spread over the
        slots) plus its own attempt, priced at the observed per-tick EMA.
        ``None`` until a tick has been measured (never reject blind)."""
        if self._tick_ema is None:
            return None
        backlog = sum(self._est_ticks(r) for r in self.queue)
        backlog += sum(max(0, r.max_new - len(r.out))
                       for r in self.active if r is not None)
        return (backlog / self.slots + self._est_ticks(req)) * self._tick_ema

    def _submit(self, req: Request, now: float) -> bool:
        """Admission control: enqueue or shed with the typed ``rejected``
        outcome.  Two tests, both cheap and both *at the door*:

        * **queue depth** — a full bounded queue sheds immediately;
        * **estimated service time** — a deadline the backlog already makes
          unmeetable is refused rather than admitted, prefillled, and
          evicted later (doomed work is the most expensive kind under
          overload).
        """
        req.t_arrive = req.t_enqueue = now
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            req.done = True
            req.outcome = "rejected"
            req.t_done = now
            log.warning("req %d rejected: queue full (%d >= %d)",
                        req.rid, len(self.queue), self.queue_limit)
            return False
        if req.deadline_s is not None:
            est = self._est_turnaround_s(req)
            if est is not None and est > req.deadline_s:
                req.done = True
                req.outcome = "rejected"
                req.t_done = now
                log.warning("req %d rejected: estimated turnaround %.3fs > "
                            "deadline %.3fs", req.rid, est, req.deadline_s)
                return False
        req.outcome = "queued"
        self.queue.append(req)
        return True

    def _admit_arrivals(self, now: float):
        due = [p for p in self._pending if p[0] <= now]
        if due:
            self._pending = [p for p in self._pending if p[0] > now]
            for _, req in due:
                self._submit(req, now)

    def _edf_pick(self, now: float) -> Optional[int]:
        """Earliest-deadline-first: the eligible (not backing off) queued
        request with the soonest absolute deadline for its current attempt;
        no-deadline requests sort last, FIFO breaks ties."""
        best = None
        best_key = None
        for i, r in enumerate(self.queue):
            if r.not_before > now:
                continue
            d = (r.t_enqueue + r.deadline_s if r.deadline_s is not None
                 else math.inf)
            key = (d, i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    # -- deadlines -----------------------------------------------------------

    def _enforce_deadlines(self):
        now = self.clock.time()
        for s, req in enumerate(self.active):
            if req is None or req.deadline_s is None:
                continue
            if now - req.t_admit <= req.deadline_s:
                continue
            self.active[s] = None
            self._reset_slot(s)
            self.slot_evictions += 1
            req.out = []
            req.degraded = False
            req.retries += 1
            if req.retries > req.max_retries:
                req.done = True
                req.outcome = "failed"
                req.t_done = now
                log.error("req %d failed: deadline %.3fs exceeded %d times",
                          req.rid, req.deadline_s, req.retries)
            else:
                req.not_before = now + 0.05 * (2 ** (req.retries - 1))
                req.outcome = "queued"
                # the fresh attempt's deadline window opens when the backoff
                # expires — clocking it from the requeue instant would let a
                # backoff longer than the deadline evict the request forever
                req.t_enqueue = req.not_before
                self.queue.append(req)
                log.warning("req %d missed deadline; requeued (retry %d/%d, "
                            "backoff %.3fs)", req.rid, req.retries,
                            req.max_retries, req.not_before - now)
        # queue-side enforcement: a request past its attempt deadline while
        # *still queued* is evicted here — before it burns prefill ticks on
        # an attempt that cannot meet its deadline anyway
        still: List[Request] = []
        for req in self.queue:
            if req.deadline_s is None or now - req.t_enqueue <= req.deadline_s:
                still.append(req)
                continue
            self.queue_evictions += 1
            req.retries += 1
            if req.retries > req.max_retries:
                req.done = True
                req.outcome = "failed"
                req.t_done = now
                log.error("req %d failed: deadline %.3fs expired in queue "
                          "(%d attempts)", req.rid, req.deadline_s,
                          req.retries)
            else:
                req.not_before = now + 0.05 * (2 ** (req.retries - 1))
                req.t_enqueue = req.not_before  # window opens post-backoff
                still.append(req)
                log.warning("req %d deadline expired while queued; attempt "
                            "window reset (retry %d/%d)", req.rid,
                            req.retries, req.max_retries)
        self.queue = still

    # -- main loop -----------------------------------------------------------

    def run(self, requests: List[Request], greedy: bool = True):
        """Closed-loop serving: every request is offered at once (the
        pre-traffic semantics — what the chaos smoke and the resilience
        tests drive)."""
        now = self.clock.time()
        return self._serve([(now, r) for r in requests])

    def run_traffic(self, requests: List[Request],
                    arrivals: Sequence[float]):
        """Open-loop serving: ``requests[i]`` becomes visible at absolute
        clock time ``arrivals[i]`` (see ``runtime.traffic``).  The engine
        never sees a request before its arrival, and the arrival process
        never waits for the engine — offered load is fixed, which is what
        makes shed rate and tail latency honest under overload."""
        if len(requests) != len(arrivals):
            raise ValueError(
                f"{len(requests)} requests but {len(arrivals)} arrival "
                f"times — the traffic trace must cover every request")
        pending = sorted(zip((float(t) for t in arrivals), requests),
                         key=lambda p: p[0])
        return self._serve(pending)

    def _serve(self, pending: List[Tuple[float, Request]]):
        self._requests = [r for _, r in pending]
        self._pending = list(pending)
        self.queue = []
        for r in self._requests:
            r.outcome = "queued"
        t0 = self.clock.time()
        self.tick = 0
        self.prefill_ticks = 0
        self.queue_evictions = 0
        self.slot_evictions = 0
        self.telemetry = []
        self._tick_ema = None
        self.ckpts.clear()
        self._checkpoint()
        watchdog = StepWatchdog()
        while (self._pending or self.queue
               or any(r is not None for r in self.active)):
            try:
                t_tick = self.clock.time()
                now = t_tick
                self._admit_arrivals(now)
                for s in range(self.slots):
                    if self.active[s] is not None or not self.queue:
                        continue
                    i = self._edf_pick(now)
                    if i is None:
                        break  # every queued request is backing off
                    self._prefill_into_slot(s, self.queue.pop(i))
                if not any(r is not None for r in self.active):
                    if self.queue:
                        self.clock.sleep(0.005)  # wait out shortest backoff
                        self._enforce_deadlines()  # backoff may outlive one
                    elif self._pending:
                        nxt = min(t for t, _ in self._pending)
                        self.clock.sleep(max(nxt - now, 1e-9))
                    continue
                nxt = self._step()
                if self.monitor is not None:
                    breaches = self.monitor.on_tick(
                        self.tick, sat=self._last_sat, rows=self.slots)
                    if breaches:
                        # commits since the breached layer was last verified
                        # may be corrupt — rewind there and replay demoted.
                        # Drift is different: committed tokens were produced
                        # inside the calibrated range (the counters fired on
                        # *this* tick's activations), so it indicts only the
                        # current, not-yet-committed tick.
                        lv = [int(self.monitor.last_verified[e["layer"]])
                              for e in breaches
                              if e["layer"] is not None
                              and e["kind"] != "drift"]
                        lv += [int(self.monitor.head_last_verified)
                               for e in breaches if e["kind"] == "head"]
                        lv += [self.tick for e in breaches
                               if e["kind"] == "drift"]
                        raise _Degraded(max(min(lv), 0), breaches)
                self._commit_tokens(nxt)
                self._enforce_deadlines()
                dt = self.clock.time() - t_tick
                watchdog.observe(self.tick, dt)
                self._tick_ema = (dt if self._tick_ema is None
                                  else 0.9 * self._tick_ema + 0.1 * dt)
                occupied = sum(r is not None for r in self.active)
                entry = {
                    "tick": self.tick,
                    "t": self.clock.time(),
                    "queue_depth": len(self.queue),
                    "pending": len(self._pending),
                    "active_slots": occupied,
                    "occupancy": occupied / self.slots,
                    "queue_evictions": self.queue_evictions,
                    "slot_evictions": self.slot_evictions,
                    "tick_s": dt,
                }
                if self.sentinel and self.monitor is not None:
                    entry["saturation"] = self.monitor.saturation_summary()
                self.telemetry.append(entry)
                self.tick += 1
                self._checkpoint()
            except _Degraded as d:
                self.rollbacks += 1
                log.warning("rolling back to tick <= %d after %d breach(es)",
                            d.target_tick, len(d.events))
                self._restore(d.target_tick)
                if self.monitor is not None and self.monitor.drift_pending:
                    # online recalibration between ticks: rebuild the drifted
                    # layer's tables at the observed range and repromote (or
                    # record the typed sticky event), then replay
                    self.monitor.recalibrate_pending(self.tick)
            except Exception as e:  # noqa: BLE001 — any tick fault
                self.restarts += 1
                log.error("decode tick %d failed (%s); restart %d/%d",
                          self.tick, e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                self._restore(self.tick)
        dt = self.clock.time() - t0
        # outcome accounting from final request state — replays through the
        # checkpoint ring can never double-count
        outcomes = {r.rid: r.outcome for r in self._requests}
        offered = len(self._requests)
        rejected = sum(o == "rejected" for o in outcomes.values())
        stats = {
            "decode_ticks": self.tick,
            "prefill_ticks": self.prefill_ticks,
            "wall_s": dt,
            "offered": offered,
            "served": sum(o == "served" for o in outcomes.values()),
            "degraded": sum(o == "degraded" for o in outcomes.values()),
            "failed": sum(o == "failed" for o in outcomes.values()),
            "rejected": rejected,
            "shed_rate": rejected / offered if offered else 0.0,
            "retried": sum(r.retries > 0 for r in self._requests),
            "restarts": self.restarts,
            "rollbacks": self.rollbacks,
            "queue_evictions": self.queue_evictions,
            "slot_evictions": self.slot_evictions,
            "straggler_ticks": list(watchdog.flagged),
            "outcomes": outcomes,
            "telemetry": list(self.telemetry),
            "table_bytes": (self.pdecode.table_bytes()
                            if self.pdecode is not None else 0),
        }
        if self.monitor is not None:
            stats["health_events"] = list(self.monitor.events)
            if self.sentinel:
                stats["saturation"] = self.monitor.saturation_summary()
                stats["recalibrations"] = int(
                    self.monitor.recalibrations.sum())
        return stats


def token_latencies(requests: Sequence[Request]) -> List[float]:
    """Per-token latency (seconds/token, arrival to completion) of every
    *completed* request — the tail the overload contract bounds."""
    out = []
    for r in requests:
        if r.outcome in ("served", "degraded") and r.out:
            out.append((r.t_done - r.t_arrive) / len(r.out))
    return out


def verify_accounting(requests: Sequence[Request], stats: Dict) -> None:
    """The overload-accounting invariant: every request ends in exactly one
    typed outcome and the outcome counts partition the offered set — no
    admitted request is ever silently dropped.  Raises ``SystemExit`` on
    violation (the CI traffic smoke's non-zero exit)."""
    bad = [r.rid for r in requests if r.outcome not in OUTCOMES]
    if bad:
        raise SystemExit(
            f"accounting violated: requests {bad} ended without a terminal "
            f"outcome (allowed: {OUTCOMES})")
    total = sum(stats[k] for k in OUTCOMES)
    if total != stats["offered"] or stats["offered"] != len(requests):
        raise SystemExit(
            f"accounting violated: served+degraded+failed+rejected = {total} "
            f"!= offered = {stats['offered']} (requests: {len(requests)})")
    undone = [r.rid for r in requests if not r.done]
    if undone:
        raise SystemExit(
            f"accounting violated: requests {undone} have a terminal outcome "
            f"but done=False")


def _chaos_plan(eng: Engine, injector):
    """The fault schedule the ``--chaos`` smoke drives: one action per fault
    class, each exercising its detection + response end to end."""
    from repro.kernels import autotune as atn

    def garble_autotune(e):
        cache = atn.get_cache()
        # make sure there are bytes to garble, then corrupt them in place;
        # the reload must warn + quarantine, never crash or silently reset
        cache.record("chaos_probe|B=1,dtype=float32|backend=cpu",
                     atn.TileConfig(Bb=8, Gb=1, Ob=128), None, 0)
        injector.garble_file(cache.path, "garbage")
        atn.reset_cache(cache.path)

    def poison_state(e):
        layers = e.cache["layers"]
        e.cache = dict(e.cache, layers=dict(
            layers, ssd=injector.poison(layers["ssd"], "nan", n=4)))

    def corrupt_proj(e):
        tabs = e.pdecode.pcilt["proj"]["tables"]
        tabs["wx"] = injector.corrupt_table(tabs["wx"], n_flips=2)
        e.pdecode.rehoist()  # jit closed over the old arrays

    def flip_head(e):
        head = e.pdecode.pcilt["head"]
        head["seg_idx"] = injector.flip_seg_idx(
            head["seg_idx"], n_pool=head["pool"].shape[0])
        e.pdecode.rehoist()

    # keyed on the monotone step counter (prefill + decode steps) so every
    # entry fires even when requests finish during neighbors' prefill ticks
    return {
        4: [garble_autotune],
        7: [lambda e: injector.maybe_fail(7)],
        11: [poison_state],
        15: [corrupt_proj],
        19: [flip_head],
    }


#: the drift smoke's injection site: one layer's mixer norm gain, amplified
#: hard enough that the very first monitored tick classifies "saturated"
DRIFT_LAYER = 1
DRIFT_GAMMA = 64.0
DRIFT_STEP = 10


def _chaos_drift_plan(eng: Engine, injector):
    """The ``--chaos-drift`` schedule: amplify one layer's mixer norm gain
    so its ``wo`` activations walk out of the calibrated range.  No table
    byte changes — checksums pass, the dense oracle agrees — only the
    in-kernel saturation counters can catch it."""

    def drift_norm(e):
        blocks = dict(e.params["blocks"])
        mixer = dict(blocks["mixer"])
        norm = dict(mixer["norm"])
        norm["scale"] = injector.drift_scale(norm["scale"], DRIFT_GAMMA,
                                             rows=[DRIFT_LAYER])
        mixer["norm"] = norm
        blocks["mixer"] = mixer
        # params are a step *argument* (not closed over like tables), so no
        # rehoist — and they are deliberately outside the checkpoint ring:
        # a rollback must NOT undo the drift, the workload really moved
        e.params = dict(e.params, blocks=blocks)

    return {DRIFT_STEP: [drift_norm]}


def _make_requests(cfg, n: int, max_new: int, deadline: Optional[float],
                   seed: int) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(2, cfg.vocab, size=rng.integers(4, 12)),
                    max_new, deadline_s=deadline) for i in range(n)]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--full", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--pcilt", action="store_true",
                   help="serve the converted PCILT decode path (Mamba archs) "
                        "under the health monitor")
    p.add_argument("--chaos", action="store_true",
                   help="drive the fault-injection schedule and verify the "
                        "resilience contract (implies a reference run)")
    p.add_argument("--chaos-drift", action="store_true",
                   help="inject calibration drift (no corrupted bytes) and "
                        "verify the sentinel contract: detect -> demote -> "
                        "recalibrate -> repromote (requires --pcilt)")
    p.add_argument("--no-sentinel", action="store_true",
                   help="serve unmonitored (no in-kernel saturation "
                        "counters) — the zero-overhead opt-out")
    p.add_argument("--deadline", type=float, default=None,
                   help="per-request deadline in seconds")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--traffic", choices=("poisson", "burst", "ramp"),
                   default=None,
                   help="open-loop arrival profile on a virtual clock (the "
                        "overload-control smoke); verifies the outcome-"
                        "accounting invariant and exits non-zero on a break")
    p.add_argument("--load", type=float, default=1.0,
                   help="offered load as a multiple of analytic capacity "
                        "(--traffic only; 2.0 = overload)")
    p.add_argument("--rate", type=float, default=None,
                   help="explicit arrival rate in requests/s (overrides "
                        "--load)")
    p.add_argument("--queue-limit", type=int, default=None,
                   help="bounded admission queue depth (default: 2*slots "
                        "under --traffic, unbounded otherwise)")
    p.add_argument("--step-cost", type=float, default=1e-3,
                   help="simulated seconds per engine step on the virtual "
                        "clock (--traffic only)")
    args = p.parse_args(argv)

    logging.basicConfig(level=logging.WARNING)
    if args.chaos_drift and args.chaos:
        raise SystemExit("--chaos-drift and --chaos are separate smokes — "
                         "run them as two invocations")
    if args.chaos_drift and not args.pcilt:
        raise SystemExit("--chaos-drift exercises the PCILT drift sentinel; "
                         "add --pcilt")
    if args.chaos_drift and args.no_sentinel:
        raise SystemExit("--chaos-drift needs the sentinel; drop "
                         "--no-sentinel")
    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.n_img_tokens or cfg.encoder_layers:
        raise SystemExit("serve demo targets text decoder archs")
    if args.pcilt:
        import dataclasses as dc
        import os
        import tempfile

        from repro.configs.base import PCILTConfig

        if cfg.ssm is None:
            raise SystemExit("--pcilt serves the converted Mamba decode "
                             "path; pick an [ssm] arch (e.g. mamba2-130m)")
        cfg = dc.replace(cfg, pcilt=PCILTConfig(act_bits=4, group=2),
                         dtype=jnp.float32)
        if args.chaos and "REPRO_PCILT_TUNE_CACHE" not in os.environ:
            # the chaos plan garbles the autotune cache file — never the
            # user's real one
            from repro.kernels import autotune as atn

            atn.reset_cache(os.path.join(tempfile.mkdtemp(), "tiles.json"))

    reqs = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                          args.seed)

    engine_kw = {}
    arrivals = None
    if args.traffic:
        from repro.runtime import VirtualClock, make_arrivals

        engine_kw = dict(clock=VirtualClock(), step_cost_s=args.step_cost,
                         queue_limit=args.queue_limit
                         if args.queue_limit is not None else 2 * args.slots)
        # analytic capacity on the virtual clock: prefill ticks serialize
        # (one slot replays its prompt at a time) while decode ticks are
        # shared by every active slot, so one request costs about
        # (mean prompt + max_new/slots) steps of step_cost seconds each
        steps_per_req = 7.5 + args.max_new / args.slots  # prompts are 4..11
        capacity = 1.0 / (steps_per_req * args.step_cost)
        rate = args.rate if args.rate is not None else args.load * capacity
        arrivals = make_arrivals(args.traffic, args.requests, rate,
                                 seed=args.seed)
        print(f"traffic: {args.traffic} arrivals at {rate:.1f} req/s "
              f"({args.load:.2f}x capacity {capacity:.1f} req/s), "
              f"queue_limit={engine_kw['queue_limit']}")
    elif args.queue_limit is not None:
        engine_kw = dict(queue_limit=args.queue_limit)

    injector = None
    eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt,
                 sentinel=not args.no_sentinel, **engine_kw)
    if args.chaos:
        from repro.runtime.faults import FaultInjector

        injector = FaultInjector(fail_at=(7,), seed=args.seed)
        if eng.pdecode is not None:
            eng.chaos = _chaos_plan(eng, injector)
        else:
            eng.chaos = {4: [lambda e: injector.maybe_fail(7)]}
    elif args.chaos_drift:
        from repro.runtime.faults import FaultInjector

        injector = FaultInjector(seed=args.seed)
        eng.chaos = _chaos_drift_plan(eng, injector)

    if arrivals is not None:
        stats = eng.run_traffic(reqs, arrivals)
    else:
        stats = eng.run(reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}... "
              f"[{r.outcome}]")
    n_completed = sum(r.outcome in ("served", "degraded") for r in reqs)
    print(f"served {n_completed} requests in {stats['wall_s']:.2f}s "
          f"({stats['decode_ticks']} decode ticks)")
    if stats["degraded"] or stats["restarts"] or stats["rollbacks"]:
        print(f"resilience: degraded={stats['degraded']} "
              f"retried={stats['retried']} failed={stats['failed']} "
              f"restarts={stats['restarts']} rollbacks={stats['rollbacks']}")

    if arrivals is not None:
        verify_accounting(reqs, stats)
        lats = token_latencies(reqs)
        p50 = float(np.percentile(lats, 50)) if lats else float("nan")
        p99 = float(np.percentile(lats, 99)) if lats else float("nan")
        print(f"overload: rejected={stats['rejected']} "
              f"(shed {100 * stats['shed_rate']:.1f}%) "
              f"queue_evictions={stats['queue_evictions']} "
              f"slot_evictions={stats['slot_evictions']} "
              f"p50/p99 token latency {p50:.4f}/{p99:.4f}s")
        print("accounting invariant verified: "
              f"{stats['served']}+{stats['degraded']}+{stats['failed']}"
              f"+{stats['rejected']} == {stats['offered']} offered")

    if args.chaos:
        if arrivals is not None:
            _verify_chaos_traffic_contract(cfg, args, eng, reqs, stats,
                                           injector, arrivals, engine_kw)
        else:
            _verify_chaos_contract(cfg, args, eng, reqs, stats, injector)
    elif args.chaos_drift:
        _verify_chaos_drift_contract(cfg, args, eng, reqs, stats, injector)


def _verify_chaos_contract(cfg, args, eng, reqs, stats, injector):
    """The CI gate: no request lost, fault-free-identical tokens, and the
    demoted path equal to the dense fake-quant oracle.  Exits non-zero on
    any violation."""
    lost = [r.rid for r in reqs if r.outcome not in ("served", "degraded")]
    if lost:
        raise SystemExit(f"chaos contract violated: requests lost: {lost}")
    if not injector.events:
        raise SystemExit("chaos smoke injected no faults — schedule never "
                         "fired (engine finished too fast?)")
    if eng.chaos:
        raise SystemExit(f"chaos smoke left faults unfired at step keys "
                         f"{sorted(eng.chaos)} (engine ran only "
                         f"{eng.steps} steps)")

    # fault-free reference run: same params (PRNGKey(0)), same request stream.
    # Undegraded requests must be token-identical; degraded requests ran
    # (partly) through the dense-oracle path, which is allclose-but-not-
    # bitwise to PCILT — their correctness is covered by the oracle-
    # equivalence check below, not token identity.
    ref_eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt)
    ref = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                         args.seed)
    ref_eng.run(ref)
    mismatched = [r.rid for r, q in zip(reqs, ref)
                  if r.outcome == "served" and r.out != q.out]
    if mismatched:
        raise SystemExit(
            f"chaos contract violated: undegraded tokens diverge from the "
            f"fault-free run for requests {mismatched}")
    n_exact = sum(r.outcome == "served" for r in reqs)

    if eng.pdecode is not None:
        # demoted decode == dense fake-quant oracle (one explicit step)
        pc_fq = dict(eng.pdecode.pcilt)
        proj = pc_fq.get("proj")
        B = args.slots
        cspecs = eng.model.cache_specs(B, 256)
        cache = materialize(cspecs, jax.random.PRNGKey(5))
        cache = dict(cache, pos=jnp.asarray(1, jnp.int32))
        tok = np.full((B, 1), 3, np.int32)
        la = jnp.zeros((cfg.n_layers,), bool)
        got, _ = eng.pdecode.step(eng.params, cache, jnp.asarray(tok),
                                  layer_ok=la, head_ok=jnp.asarray(False))
        if proj is not None:
            pc_fq["proj"] = dict(proj, path="dense_fq")
        ref_step = jax.jit(lambda p, c, t: eng.model.decode_step(
            p, c, t, make_ctx(None, None, decode=True), pcilt=pc_fq,
            head_ok=jnp.asarray(False)))
        want, _ = ref_step(eng.params, cache, jnp.asarray(tok))
        if not np.allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                           atol=1e-4):
            raise SystemExit("chaos contract violated: demoted decode "
                             "diverges from the dense fake-quant oracle")
    print(f"chaos contract verified: {len(reqs)} requests completed "
          f"({n_exact} token-identical to fault-free run, "
          f"{len(injector.events)} faults injected, "
          f"{stats['restarts']} restarts, {stats['rollbacks']} rollbacks, "
          f"{stats['degraded']} degraded)")


def _verify_chaos_drift_contract(cfg, args, eng, reqs, stats, injector):
    """The drift-sentinel CI gate: injected calibration drift (no corrupted
    bytes — checksums pass, the oracle agrees) must be caught by the
    saturation counters, the drifting layer demoted, its tables
    recalibrated online at the observed range and repromoted, with no
    request lost; requests that finished undegraded must be token-identical
    to a fault-free reference run, and the hot-swapped tables bit-equal to
    a fresh conversion-arithmetic build at the recorded new scale.  Exits
    non-zero on any violation."""
    from repro.core.pcilt import (build_grouped_tables,
                                  build_paired_stacked_tables)

    lost = [r.rid for r in reqs if r.outcome not in ("served", "degraded")]
    if lost:
        raise SystemExit(f"drift contract violated: requests lost: {lost}")
    drifts = [e for e in injector.events if e["kind"] == "calibration_drift"]
    if not drifts:
        raise SystemExit("drift smoke never injected — schedule never fired "
                         f"(engine ran only {eng.steps} steps)")
    events = stats["health_events"]
    demotions = [e for e in events if e["kind"] == "drift"]
    recals = [e for e in events if e["kind"] == "recalibrate"]
    if not demotions:
        raise SystemExit("drift contract violated: sentinel never fired "
                         f"(saturation: {stats.get('saturation')})")
    if any(e["layer"] != DRIFT_LAYER for e in demotions):
        raise SystemExit(f"drift contract violated: demotions fired off the "
                         f"drifted layer {DRIFT_LAYER}: {demotions}")
    if not recals:
        raise SystemExit("drift contract violated: no online recalibration "
                         f"(events: {[e['kind'] for e in events]})")
    mon = eng.monitor
    bad = [l for l in range(mon.n_layers) if not mon.layer_ok[l]]
    if bad:
        raise SystemExit(f"drift contract violated: layers {bad} not "
                         "repromoted after recalibration")

    # hot-swapped tables == fresh conversion-arithmetic build at the
    # recorded post-drift scale, bitwise
    proj = eng.pdecode.pcilt["proj"]
    spec, group = proj["spec"], proj["group"]
    paired = bool(proj.get("paired"))
    for ev in recals:
        l = ev["layer"]
        for name, new_scale in ev["scales"].items():
            if float(np.asarray(proj["scales"][name][l])) != new_scale:
                continue  # a later recalibration superseded this one
            wf = jnp.asarray(
                eng.params["blocks"]["mixer"][name]["kernel"][l],
                jnp.float32)
            t = np.asarray(proj["tables"][name])
            if paired:
                ref = build_paired_stacked_tables(
                    wf[None], spec, jnp.full((1,), new_scale, jnp.float32),
                    group)[:, 0]
                got = t[:, l]
            else:
                pad = (-wf.shape[0]) % group
                if pad:
                    wf = jnp.concatenate(
                        [wf, jnp.zeros((pad, wf.shape[1]), wf.dtype)], 0)
                ref = build_grouped_tables(wf, spec, new_scale, group)
                got = t[l]
            if not np.array_equal(got, np.asarray(ref).astype(got.dtype)):
                raise SystemExit(
                    f"drift contract violated: recalibrated table "
                    f"{name}[{l}] != fresh build at scale {new_scale}")

    # undrifted tokens: a fault-free reference run of the same stream —
    # requests that finished undegraded (before the drift / the
    # recalibration taint) must be token-identical
    ref_eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt)
    ref = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                         args.seed)
    ref_eng.run(ref)
    mismatched = [r.rid for r, q in zip(reqs, ref)
                  if r.outcome == "served" and r.out != q.out]
    if mismatched:
        raise SystemExit(
            f"drift contract violated: undrifted tokens diverge from the "
            f"fault-free run for requests {mismatched}")
    print(f"drift contract verified: {len(reqs)} requests completed, "
          f"sentinel fired {len(demotions)}x on layer {DRIFT_LAYER}, "
          f"{len(recals)} recalibration(s), {stats['rollbacks']} "
          f"rollback(s), {stats['degraded']} degraded; recalibrated tables "
          f"bit-equal to fresh build at the new scale")


def _verify_chaos_traffic_contract(cfg, args, eng, reqs, stats, injector,
                                   arrivals, engine_kw):
    """Chaos under traffic: the overload contract and the resilience
    contract must hold *at once* — every outcome typed and accounted, no
    admitted request silently dropped, and every request served undegraded
    in both the chaos run and a fault-free reference run of the same
    arrival trace must be token-identical."""
    from repro.runtime import VirtualClock

    verify_accounting(reqs, stats)  # raises SystemExit on violation
    if not injector.events:
        raise SystemExit("chaos-under-traffic smoke injected no faults — "
                         "schedule never fired")
    ref_kw = dict(engine_kw, clock=VirtualClock())
    ref_eng = Engine(cfg, max_len=256, slots=args.slots, pcilt=args.pcilt,
                     **ref_kw)
    ref = _make_requests(cfg, args.requests, args.max_new, args.deadline,
                         args.seed)
    ref_stats = ref_eng.run_traffic(ref, arrivals)
    verify_accounting(ref, ref_stats)
    mismatched = [r.rid for r, q in zip(reqs, ref)
                  if r.outcome == "served" and q.outcome == "served"
                  and r.out != q.out]
    if mismatched:
        raise SystemExit(
            f"chaos-under-traffic contract violated: undegraded tokens "
            f"diverge from the fault-free run for requests {mismatched}")
    print(f"chaos-under-traffic contract verified: {stats['offered']} "
          f"offered -> {stats['served']} served / {stats['degraded']} "
          f"degraded / {stats['failed']} failed / {stats['rejected']} "
          f"rejected; {len(injector.events)} faults injected, "
          f"{stats['restarts']} restarts, {stats['rollbacks']} rollbacks")


if __name__ == "__main__":
    main()
