import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod, 2×16×16 multi-pod),
  2. lowers the right step function against ShapeDtypeStruct inputs
     (nothing is allocated — a 400B-param train step lowers on a CPU host),
  3. compiles, records ``memory_analysis()`` / ``cost_analysis()``,
  4. parses collective bytes out of the compiled HLO,
  5. caches everything to ``experiments/dryrun/<cell>.json``.

``python -m repro.launch.dryrun --all`` runs the whole grid; failures are
recorded (and are bugs).  The roofline report (benchmarks/roofline.py) reads
these JSONs.
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (
    make_train_step, make_prefill_step, make_decode_step, active_matmul_params,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.optim import AdamWConfig, cosine_schedule
from repro.models import build_model

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_bytes(line: str) -> int:
    """Largest typed buffer on an HLO line — a robust per-device byte proxy
    for AR (out=in), AG (out largest), RS (in largest), A2A (equal)."""
    best = 0
    for dt, dims in _SHAPE_RE.findall(line):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, int]]:
    out = {k: {"count": 0, "bytes": 0} for k in _COLL}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("%") and not ls.startswith("ROOT"):
            continue
        for k in _COLL:
            if re.search(rf"= [^=]*\b{k}(?:-start|-done)?\(", ls):
                if f"{k}-done" in ls:  # paired with -start; count once
                    continue
                out[k]["count"] += 1
                out[k]["bytes"] += _line_bytes(ls)
                break
    return out


#: §Perf hillclimbing variants — baseline cells carry no variant suffix.
VARIANTS = {
    "base": {},
    # H-A1/H-A2 (llama4 train, collective-bound): bf16 gradient reductions +
    # half the microbatch re-gathers
    "bf16grads": {"bf16_grads": True},
    "llama4opt": {"bf16_grads": True, "grad_accum": 2},
    # H-C1 (dense train): ZeRO-1 — params model-sharded only, moments shard
    # over data, one param all-gather per step instead of per-layer FSDP
    "zero1": {"bf16_grads": True, "zero1": True,
              "rule_overrides": {"embed": None,
                                  "opt_embed": ("data", "pod")}},
    # H-B1 (decode): KV-cache time axis shards over the model axis;
    # q-heads replicate at decode (tiny) so attention contracts sharded T
    "kvshard": {"rule_overrides": {"cache_seq": "model", "heads": None}},
    # H-B2: time-sharded cache only — projections stay TP; the partitioner
    # gathers the tiny q instead of the huge KV
    "kvshard2": {"rule_overrides": {"cache_seq": "model"}},
    # H-C1b: ZeRO-1 with gradients *pinned* to the data-sharded moment
    # layout (reduce-scatter, not all-reduce)
    "zero1b": {"bf16_grads": True, "zero1": True, "pin_grads": True,
               "rule_overrides": {"embed": None,
                                   "opt_embed": ("data", "pod")}},
    # H-C2/H-A3: explicit bf16 psum_scatter row-parallel matmuls (o_proj +
    # down_proj) instead of partitioner-chosen fp32 all-reduces
    "rowrs": {"explicit_rs": True},
    # combined best-known for llama4 train
    "llama4opt2": {"explicit_rs": True, "grad_accum": 2},
    # no microbatching: minimum weight re-gathers (memory traded away —
    # the multi-pod mesh is the feasible home for 400B training state)
    "llama4opt3": {"explicit_rs": True, "grad_accum": 1},
}


def _skip_reason(cfg, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 500k-token decode KV is the quadratic "
                "regime the assignment skips (DESIGN.md §7)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cfg=None, variant: str = "base") -> Dict:
    import dataclasses

    v = VARIANTS[variant]
    cfg = cfg or get_config(arch)
    if "grad_accum" in v:
        cfg = dataclasses.replace(cfg, grad_accum=v["grad_accum"])
    rule_overrides = v.get("rule_overrides")
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "variant": variant,
            "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    reason = _skip_reason(cfg, shape_name)
    if reason:
        cell.update(status="skipped", reason=reason)
        return cell
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        t0 = time.time()
        specs = input_specs(arch, shape_name, mesh, cfg=cfg,
                            rule_overrides=rule_overrides,
                            zero1=v.get("zero1", False))
        if sh.kind == "train":
            ocfg = AdamWConfig(lr=cosine_schedule(3e-4, 100, 10000),
                               quantize_moments=cfg.name.startswith("llama4"))
            grad_sh = None
            if v.get("pin_grads"):
                from repro.nn.module import ParamSpec, shardings as _mk_sh
                from repro.launch.specs import data_spec as _ds
                pspecs = build_model(cfg).param_specs()
                remap = jax.tree.map(
                    lambda sp: ParamSpec(
                        sp.shape,
                        tuple("opt_embed" if a == "embed" else a
                              for a in sp.axes),
                        sp.dtype, sp.init, sp.scale),
                    pspecs, is_leaf=lambda x: isinstance(x, ParamSpec))
                grad_sh = _mk_sh(remap, mesh, _ds(mesh, rule_overrides))
            step = make_train_step(cfg, mesh, ocfg,
                                   bf16_grads=v.get("bf16_grads", False),
                                   rule_overrides=rule_overrides,
                                   grad_shardings=grad_sh,
                                   explicit_rs=v.get("explicit_rs", False))
            args = (specs["params"], specs["opt_state"], specs["batch"])
            out_sh = (
                jax.tree.map(lambda s: s.sharding, specs["params"]),
                jax.tree.map(lambda s: s.sharding, specs["opt_state"]),
                None,
            )
            jitted = jax.jit(step, donate_argnums=(0, 1), out_shardings=out_sh)
        elif sh.kind == "prefill":
            step = make_prefill_step(cfg, mesh, rule_overrides=rule_overrides)
            args = (specs["params"], specs["batch"])
            jitted = jax.jit(step)
        else:
            step = make_decode_step(cfg, mesh, rule_overrides=rule_overrides)
            args = (specs["params"], specs["cache"], specs["tokens"])
            out_sh = (None, jax.tree.map(lambda s: s.sharding, specs["cache"]))
            jitted = jax.jit(step, donate_argnums=(1,), out_shardings=out_sh)

        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        hlo = compiled.as_text()
        # trip-count-weighted per-device analysis (cost_analysis counts while
        # bodies once — see launch/hlo_analysis.py)
        hw = analyze_hlo(hlo)

        n_active = active_matmul_params(cfg)
        tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
        factor = 6 if sh.kind == "train" else 2
        model_flops = factor * n_active * tokens

        cell.update(
            status="ok",
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "total_nonalias_bytes": mem.argument_size_in_bytes
                + mem.temp_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            cost_raw={  # while-bodies-once (XLA native numbers, for reference)
                "flops_per_device": ca.get("flops", 0.0),
                "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
            },
            cost={  # trip-count weighted, per device
                "flops_per_device": hw["flops"],
                "bytes_traffic_est_per_device": hw["bytes_traffic_est"],
            },
            collectives=hw["coll"],
            collective_bytes_per_device=hw["collective_bytes"],
            top_collectives=hw["top_collectives"],
            top_buffers=hw["top_buffers"],
            model_flops_global=model_flops,
            n_active_params=n_active,
        )
    except Exception as e:  # noqa: BLE001 — recorded, it's a bug
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])
    return cell


def cell_path(arch: str, shape_name: str, mesh_name: str,
              variant: str = "base") -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    safe = arch.replace("/", "_").replace(".", "_")
    suffix = "" if variant == "base" else f"__{variant}"
    return os.path.join(OUT_DIR,
                        f"{safe}__{shape_name}__{mesh_name}{suffix}.json")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--force", action="store_true", help="ignore cache")
    p.add_argument("--variant", default="base", choices=sorted(VARIANTS))
    args = p.parse_args()

    archs = ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if (args.both_meshes or args.all) else (args.multi_pod,)

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = cell_path(arch, shape_name, mesh_name, args.variant)
                if os.path.exists(path) and not args.force:
                    cell = json.load(open(path))
                    if cell.get("status") == "ok" or cell.get("status") == "skipped":
                        print(f"[cached] {arch} {shape_name} {mesh_name}: "
                              f"{cell['status']}")
                        n_ok += cell["status"] == "ok"
                        n_skip += cell["status"] == "skipped"
                        continue
                print(f"[run]    {arch} {shape_name} {mesh_name} ...",
                      flush=True)
                cell = run_cell(arch, shape_name, mp, variant=args.variant)
                json.dump(cell, open(path, "w"), indent=1)
                if cell["status"] == "ok":
                    n_ok += 1
                    print(f"         ok: compile {cell['compile_s']}s, "
                          f"mem/dev {cell['memory']['total_nonalias_bytes']/2**30:.2f} GiB, "
                          f"coll/dev {cell['collective_bytes_per_device']/2**20:.1f} MiB")
                elif cell["status"] == "skipped":
                    n_skip += 1
                    print(f"         skipped: {cell['reason'][:80]}")
                else:
                    n_err += 1
                    print(f"         ERROR: {cell['error']}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
