"""Step-function factories: train / prefill / decode for any (arch, mesh).

These close over the model + sharding context and are what both the real
launchers (train.py / serve.py) and the dry-run lower.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.nn.layers import Ctx
from repro.nn.module import ShardingRules
from repro.optim import AdamWConfig, adamw_update

__all__ = ["make_ctx", "make_train_step", "make_prefill_step",
           "make_decode_step", "active_matmul_params"]


def make_ctx(mesh, rule_overrides=None, decode=False,
             explicit_rs=False) -> Ctx:
    if mesh is None:
        return Ctx(decode=decode)
    from repro.nn.module import DEFAULT_RULES

    rules = dict(DEFAULT_RULES)
    if rule_overrides:
        rules.update(rule_overrides)
    return Ctx(mesh=mesh, rules=ShardingRules.for_mesh(mesh, rules),
               decode=decode, explicit_rs=explicit_rs)


def _cast_tree_bf16(p):
    return jax.tree.map(
        lambda a: a.astype(jnp.bfloat16)
        if a.dtype == jnp.float32 and a.ndim >= 2 else a, p)


def make_train_step(cfg, mesh, ocfg: AdamWConfig, bf16_grads: bool = False,
                    rule_overrides=None, grad_shardings=None,
                    explicit_rs: bool = False):
    """bf16_grads: differentiate w.r.t. the bf16-cast tree so the gradient
    cross-replica reduction moves bf16 on the wire (half the bytes); the
    fp32 master update applies the bf16 grads (§Perf H-A1).

    grad_shardings: explicit shardings pinned onto the gradient tree before
    the optimizer — ZeRO-1 uses this to force a reduce-*scatter* over the
    data axis (matching the data-sharded moments) instead of letting the
    partitioner all-reduce full gradients (§Perf H-C1b)."""
    model = build_model(cfg)
    ctx = make_ctx(mesh, rule_overrides, explicit_rs=explicit_rs)

    def loss_fn(p, b):
        # cast fp32 master -> bf16 *before* use: FSDP all-gathers then
        # move bf16, halving param-collective bytes and gathered temp.
        return model.loss(_cast_tree_bf16(p), b, ctx)

    def loss_fn_bf16(pc, b):
        return model.loss(pc, b, ctx)

    def grad_of(params, b):
        if bf16_grads:
            pc = _cast_tree_bf16(params)
            (l, m), g = jax.value_and_grad(loss_fn_bf16, has_aux=True)(pc, b)
            # leaves that were never cast keep their grads; shapes match tree
            return (l, m), g
        return jax.value_and_grad(loss_fn, has_aux=True)(params, b)

    def train_step(params, opt_state, batch):
        n = max(cfg.grad_accum, 1)
        if n == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches; activations and
            # backward transients divide by n (weight gathers repeat ×n —
            # the memory/collective trade recorded in §Perf).
            micro = jax.tree.map(
                lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

            def one(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_of(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, l_acc + l), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(one, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, ocfg)
        return new_params, new_opt, dict(metrics, loss=loss, **om)

    return train_step


def make_prefill_step(cfg, mesh, rule_overrides=None):
    model = build_model(cfg)
    ctx = make_ctx(mesh, rule_overrides)

    def prefill_step(params, batch):
        return model.prefill(params, batch, ctx)

    return prefill_step


def make_decode_step(cfg, mesh, rule_overrides=None):
    model = build_model(cfg)
    ctx = make_ctx(mesh, rule_overrides, decode=True)

    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens, ctx)
        if cfg.padded_vocab > cfg.vocab:  # padded ids never sampled
            neg = jnp.full((cfg.padded_vocab - cfg.vocab,), -1e30, logits.dtype)
            logits = logits.at[..., cfg.vocab:].set(neg)
        return logits, new_cache

    return serve_step


def active_matmul_params(cfg) -> int:
    """N for MODEL_FLOPS = 6·N·D: per-token matmul-touched parameters.

    Embedding gathers don't matmul (excluded); the logits projection does
    (counted once, tied or not); MoE expert tensors count at top_k experts
    per token; dead padding experts are never routed (excluded exactly by
    scaling the padded tensor count by k/E_pad)."""
    import math
    from repro.nn.module import ParamSpec, flatten_with_path

    model = build_model(cfg)
    specs = model.param_specs()
    flat, _ = flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0.0
    for path, spec in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        n = math.prod(spec.shape)
        if "embed/embedding" in name:
            continue  # gather, not matmul (tied logits handled below)
        if "/moe/" in name and name.split("/")[-1] in ("w_gate", "w_up", "w_down"):
            n *= cfg.moe.top_k / cfg.moe.padded_experts
        total += n
    if cfg.tie_embeddings:
        total += cfg.d_model * cfg.padded_vocab  # tied logits matmul
    return int(total)
