"""Data pipeline (seeded synthetic LM corpus + modality stubs)."""
from .pipeline import SyntheticLM
