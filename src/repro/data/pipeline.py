"""Deterministic synthetic data pipeline.

Host-side, seeded, shard-aware: batch contents are a pure function of
(seed, step, shard) so restarts and elastic re-sharding reproduce the same
global batch — the property the fault-tolerance tests assert.

``packed`` mode simulates a real LM corpus: documents of random length packed
into the sequence with EOS boundaries and a loss mask that ignores padding —
so the loss path exercises masking exactly as a production pipeline would.
Modality frontends are stubbed per the assignment: ``memory`` (whisper frame
embeddings) and ``img_embeds`` (llava patch embeddings) come out of the same
seeded generator.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["SyntheticLM"]


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    packed: bool = True
    eos_id: int = 1
    n_shards: int = 1
    shard: int = 0
    # modality stubs
    memory_len: int = 0      # whisper encoder frames
    img_tokens: int = 0      # llava patch embeddings
    d_model: int = 0

    @property
    def local_batch(self) -> int:
        if self.global_batch % self.n_shards:
            raise ValueError(
                f"global_batch {self.global_batch} is not divisible by "
                f"n_shards {self.n_shards}")
        return self.global_batch // self.n_shards

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S = self.local_batch, self.seq_len
        toks = rng.integers(2, self.vocab, size=(B, S + 1), dtype=np.int32)
        mask = np.ones((B, S), np.float32)
        if self.packed:
            # documents ~ Zipf-ish lengths; EOS at boundaries; tail padding
            for b in range(B):
                pos = 0
                while pos < S:
                    doc = int(rng.integers(16, max(S // 2, 17)))
                    end = min(pos + doc, S)
                    toks[b, end - 1] = self.eos_id
                    pos = end
                pad_from = int(rng.integers(S - 8, S + 1))
                toks[b, pad_from:] = 0
                mask[b, pad_from:] = 0.0
        out = {
            "tokens": toks[:, :S],
            "labels": toks[:, 1 : S + 1],
            "loss_mask": mask,
        }
        if self.memory_len:
            out["memory"] = rng.standard_normal(
                (B, self.memory_len, self.d_model)).astype(np.float32)
        if self.img_tokens:
            out["img_embeds"] = rng.standard_normal(
                (B, self.img_tokens, self.d_model)).astype(np.float32)
        return out
