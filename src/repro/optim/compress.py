"""Compressed cross-replica gradient reduction.

Two schemes, both expressed as explicit collectives inside ``shard_map`` so
the byte reduction is visible in the compiled HLO (and in the roofline
collective term):

* ``bf16``  — all-reduce in bf16: 2× fewer wire bytes than fp32.
* ``int8``  — two-phase compressed all-reduce: per-chunk int8 quantize →
  ``all_to_all`` (each replica owns one chunk) → local fp32 reduce → requant
  → ``all_gather``.  Wire bytes ≈ 2·N·1B vs 2·N·4B for a ring fp32
  all-reduce — a 4× cut.  Per-chunk fp32 scales travel alongside (negligible).

Error feedback: each scheme returns the *local* quantization residual
(``g_local − Q(g_local)``); the trainer folds it into the next step's local
gradient (EF-SGD), keeping the compressed reduction unbiased over time at
zero extra collective cost.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

__all__ = ["compressed_pmean", "compress_grads_tree"]


def _int8_pmean(x: jax.Array, axis: str) -> Tuple[jax.Array, jax.Array]:
    """Mean over ``axis`` via int8 two-phase reduce.  Returns (mean, residual)."""
    n_shards = axis_size(axis)
    n = x.size
    pad = (-n) % n_shards
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(n_shards, -1)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    residual = (flat - q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(x.shape)
    # phase 1: every replica receives the chunk it owns from all peers (int8)
    q_t = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=True)
    s_t = jax.lax.all_to_all(
        jnp.broadcast_to(scale, (n_shards, 1)), axis, split_axis=0,
        concat_axis=0, tiled=True)
    part = jnp.sum(q_t.astype(jnp.float32).reshape(n_shards, -1)
                   * s_t.reshape(n_shards, 1), axis=0) / n_shards
    # phase 2: requantize the reduced chunk, all-gather int8 + scales
    s2 = jnp.max(jnp.abs(part)) / 127.0 + 1e-12
    q2 = jnp.clip(jnp.round(part / s2), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q2, axis, axis=0, tiled=False)   # [S, chunk] int8
    sg = jax.lax.all_gather(s2, axis, axis=0, tiled=False)   # [S]
    full = (qg.astype(jnp.float32) * sg[:, None]).reshape(-1)
    return full[:n].reshape(x.shape), residual


def compressed_pmean(x: jax.Array, axis: str, scheme: str = "int8"):
    """Returns (reduced, local_residual)."""
    x = x.astype(jnp.float32)
    if scheme == "int8":
        return _int8_pmean(x, axis)
    if scheme == "bf16":
        xq = x.astype(jnp.bfloat16)
        reduced = jax.lax.pmean(xq, axis).astype(jnp.float32)
        return reduced, x - xq.astype(jnp.float32)
    if scheme == "none":
        return jax.lax.pmean(x, axis), jnp.zeros_like(x)
    raise ValueError(f"unknown compression scheme {scheme!r}")


def compress_grads_tree(grads, axis: str, scheme: str = "int8"):
    """pmean every leaf with compression; returns (reduced, residuals)."""
    pairs = jax.tree.map(lambda g: compressed_pmean(g, axis, scheme), grads)
    reduced = jax.tree.map(lambda p: p[0], pairs,
                           is_leaf=lambda p: isinstance(p, tuple))
    residual = jax.tree.map(lambda p: p[1], pairs,
                            is_leaf=lambda p: isinstance(p, tuple))
    return reduced, residual
