"""Optimizer substrate: AdamW (+int8 moment quantization), schedules,
clipping, compressed gradient collectives."""

from .adamw import (
    AdamWConfig, adamw_init, adamw_init_specs, adamw_update, cosine_schedule,
    global_norm, clip_by_global_norm,
)
from .compress import compressed_pmean, compress_grads_tree
