"""AdamW with optional block-quantized (int8) moment storage.

Pure-JAX functional optimizer (no optax dependency).  The int8 moment option
stores ``m``/``v`` as int8 codes + per-block fp32 scales — the paper's
low-cardinality thesis applied to optimizer state.  It is what lets
llama4-400B train state fit a 256-chip v5e pod: fp32 m+v needs 8 bytes/param
(3.2 TB); int8+scales needs ~2.06 bytes/param (DESIGN.md §4, EXPERIMENTS.md
§Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_init_specs", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_moments: bool = False  # int8 + per-row scales


# ---- shape-preserving int8 codec -------------------------------------------
# Codes keep the parameter's shape (so they inherit its NamedSharding and
# checkpoint layout); scales are per-last-dim-row, shape [..., 1].


def _q8(x: jax.Array):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---- state -----------------------------------------------------------------


def _zeros_moment(p, quantized: bool):
    if not quantized:
        return jnp.zeros_like(p, jnp.float32)
    return {"q": jnp.zeros(p.shape, jnp.int8),
            "scale": jnp.zeros((*p.shape[:-1], 1), jnp.float32)}


def adamw_init(params, cfg: AdamWConfig):
    return {
        "count": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _zeros_moment(p, cfg.quantize_moments), params),
        "v": jax.tree.map(lambda p: _zeros_moment(p, cfg.quantize_moments), params),
    }


def adamw_init_specs(param_specs, cfg: AdamWConfig, remap_axes=None):
    """ParamSpec tree for the optimizer state (dry-run / sharding food).

    remap_axes: logical-axis rename for the moments only — ZeRO-1 keeps
    params data-replicated ("embed" -> None rule) while the moments shard
    over data ("embed" -> "opt_embed" here, with an "opt_embed" rule)."""
    from repro.nn.module import ParamSpec  # local import to avoid a cycle

    def _axes(axes):
        if not remap_axes:
            return axes
        return tuple(remap_axes.get(a, a) for a in axes)

    def moment(s: ParamSpec):
        if not cfg.quantize_moments:
            return ParamSpec(s.shape, _axes(s.axes), jnp.float32, "zeros")
        return {
            "q": ParamSpec(s.shape, _axes(s.axes), jnp.int8, "zeros"),
            "scale": ParamSpec((*s.shape[:-1], 1), (*_axes(s.axes[:-1]), None),
                               jnp.float32, "zeros"),
        }

    is_spec = lambda x: isinstance(x, ParamSpec)
    return {
        "count": ParamSpec((), (), jnp.int32, "zeros"),
        "m": jax.tree.map(moment, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(moment, param_specs, is_leaf=is_spec),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    lr = cfg.lr(count) if callable(cfg.lr) else jnp.asarray(cfg.lr)
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, v):
        if cfg.quantize_moments:
            m_f = _dq8(m["q"], m["scale"])
            v_f = _dq8(v["q"], v["scale"])
        else:
            m_f, v_f = m, v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        update = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases/scalars
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if cfg.quantize_moments:
            mq, ms = _q8(m_f)
            vq, vs = _q8(v_f)
            return p_new, {"q": mq, "scale": ms}, {"q": vq, "scale": vs}
        return p_new, m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_m = lambda x: isinstance(x, dict) and "q" in x
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_m)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_m)
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"count": count, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
