"""Version-tolerance shims for the jax APIs this repo uses.

The codebase targets the modern public spellings (``jax.shard_map``,
``jax.tree.flatten_with_path``); older jax releases only ship them under
``jax.experimental`` / ``jax.tree_util``.  Everything funnels through here so
the rest of the code can stay on one spelling.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "tree_flatten_with_path", "axis_size", "is_tracer"]


_TRACER_TYPES: tuple = ()


def _tracer_types() -> tuple:
    global _TRACER_TYPES
    if not _TRACER_TYPES:
        types = []
        try:  # newer jax: the supported public home
            from jax.extend import core as _xcore

            t = getattr(_xcore, "Tracer", None)
            if t is not None:
                types.append(t)
        except ImportError:
            pass
        t = getattr(getattr(jax, "core", None), "Tracer", None)
        if t is not None and t not in types:
            types.append(t)
        _TRACER_TYPES = tuple(types)
    return _TRACER_TYPES


def is_tracer(x) -> bool:
    """``isinstance(x, Tracer)`` across jax versions.

    ``jax.core.Tracer`` is deprecated/being removed; newer jax exposes the
    class under ``jax.extend.core``.  Falls back to an MRO name probe when
    neither module offers it, so eager-vs-traced dispatch (e.g. the autotune
    "never time under a jit trace" rule) keeps working across versions.
    """
    ts = _tracer_types()
    if ts:
        return isinstance(x, ts)
    return any(c.__name__ == "Tracer" for c in type(x).__mro__)


def shard_map(f=None, /, **kwargs):
    """``jax.shard_map`` with a fallback to the experimental location.

    The old API names the replication-check kwarg ``check_rep`` instead of
    ``check_vma``; translate when falling back.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn

        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:
        return lambda g: fn(g, **kwargs)
    return fn(f, **kwargs)


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` with a ``jax.tree_util`` fallback."""
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` only exists in newer jax; ``psum(1)`` is the
    portable spelling of "how many shards am I across this axis"."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
