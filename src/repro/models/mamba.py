"""Mamba2 language model (attention-free) — the [ssm] architecture.

Scanned Mamba2 blocks with pre-norm residuals.  Decode carries constant-size
(conv, ssd) states — no KV cache — so the ``long_500k`` cell costs the same
memory as ``decode`` at any context length.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec
from repro.nn.layers import Ctx, dense, embed_spec, rmsnorm_spec, rmsnorm
from repro.nn.ssm import mamba_spec, mamba_block, mamba_decode, ssm_cache_specs
from .transformer import stack_specs, chunked_ce_loss

__all__ = ["MambaLM"]


@dataclasses.dataclass
class MambaLM:
    cfg: Any

    def param_specs(self):
        cfg = self.cfg
        block = {"ln": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
                 "mixer": mamba_spec(cfg, cfg.param_dtype)}
        p = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
            "blocks": stack_specs(block, cfg.n_layers),
            "ln_f": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = {
                "kernel": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                                    cfg.param_dtype, "fan_in")
            }
        return p

    def cache_specs(self, batch: int, max_len: int):
        return {"layers": ssm_cache_specs(self.cfg, batch, self.cfg.n_layers),
                "pos": ParamSpec((), (), jnp.int32, "zeros")}

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return x @ params["embed"]["embedding"].astype(cfg.dtype).T
        return dense(params["lm_head"], x, cfg.dtype)

    def _embed(self, params, ctx, tokens):
        cfg = self.cfg
        x = params["embed"]["embedding"].astype(cfg.dtype)[tokens]
        return ctx.constrain(x, "batch", "seq_sp", None)

    def _policy(self):
        return {
            "none": None,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "full": jax.checkpoint_policies.nothing_saveable,
        }[self.cfg.remat_policy]

    def loss(self, params, batch, ctx: Ctx):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, ctx, tokens)
        policy = self._policy()

        def blk(x, p):
            return x + mamba_block(p["mixer"], cfg, ctx,
                                   rmsnorm(p["ln"], x, cfg.norm_eps))

        if policy is not None:
            blk = jax.checkpoint(blk, policy=policy)

        x, _ = jax.lax.scan(lambda h, p: (blk(h, p), ()), x, params["blocks"])
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce, z = chunked_ce_loss(lambda xc: self._logits(params, xc), x, labels,
                                mask.astype(jnp.float32), cfg.loss_chunk)
        return ce + 1e-4 * z, {"ce": ce, "z": z}

    def prefill(self, params, batch, ctx: Ctx):
        """Full-sequence pass emitting final (conv, ssd) states per layer —
        the decode-ready cache (constant-size regardless of prompt length)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens)

        def body(h, p):
            y, st = mamba_block(p["mixer"], cfg, ctx,
                                rmsnorm(p["ln"], h, cfg.norm_eps),
                                return_state=True)
            return h + y, st

        x, states = jax.lax.scan(body, x, params["blocks"])
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"layers": states, "pos": jnp.asarray(S, jnp.int32)}

    def build_pcilt(self, params, scale):
        """Offline PCILT build for every layer's conv frontend (requires
        ``cfg.pcilt``): per-layer ``[C, V]`` tables stacked to ``[L, C, V]``
        so they ride the decode scan exactly like parameters.  ``scale`` is
        the calibrated per-tensor activation scale of the conv input."""
        from repro.core import QuantSpec
        from repro.core.lut_layers import build_dwconv_tables

        cfg = self.cfg
        assert cfg.pcilt is not None, "cfg.pcilt must be set to build PCILTs"
        # the conv input (xBC) is a pre-activation stream — signed, so the
        # grid must straddle zero (symmetric), unlike post-ReLU CNN codes
        spec = QuantSpec(bits=cfg.pcilt.act_bits, symmetric=True)
        tables = jax.vmap(
            lambda w: build_dwconv_tables(w, spec, scale)
        )(params["blocks"]["mixer"]["conv_w"])  # [L, C, V]
        return {"tables": tables, "scale": scale, "spec": spec}

    def decode_step(self, params, cache, tokens, ctx: Ctx, pcilt=None):
        """One decode step.  ``pcilt`` (from :meth:`build_pcilt`) routes every
        layer's conv frontend through the fused PCILT fetch."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed(params, ctx, tokens)

        def body(h, inp):
            p, st = inp[0], inp[1]
            pc = None if pcilt is None else {
                "tables": inp[2], "scale": pcilt["scale"],
                "spec": pcilt["spec"]}
            y, st2 = mamba_decode(p["mixer"], cfg, ctx,
                                  rmsnorm(p["ln"], h, cfg.norm_eps), st,
                                  pcilt=pc)
            return h + y, st2

        xs = (params["blocks"], cache["layers"])
        if pcilt is not None:
            xs = xs + (pcilt["tables"],)
        x, new_states = jax.lax.scan(body, x, xs)
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = self._logits(params, x)[:, -1]
        return logits, dict(cache, layers=new_states, pos=pos + 1)
