"""Mamba2 language model (attention-free) — the [ssm] architecture.

Scanned Mamba2 blocks with pre-norm residuals.  Decode carries constant-size
(conv, ssd) states — no KV cache — so the ``long_500k`` cell costs the same
memory as ``decode`` at any context length.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec
from repro.nn.layers import Ctx, dense, embed_spec, rmsnorm_spec, rmsnorm
from repro.nn.ssm import mamba_spec, mamba_block, mamba_decode, ssm_cache_specs
from .transformer import stack_specs, chunked_ce_loss

__all__ = ["MambaLM"]


@dataclasses.dataclass
class MambaLM:
    cfg: Any

    def param_specs(self):
        cfg = self.cfg
        block = {"ln": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
                 "mixer": mamba_spec(cfg, cfg.param_dtype)}
        p = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
            "blocks": stack_specs(block, cfg.n_layers),
            "ln_f": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = {
                "kernel": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                                    cfg.param_dtype, "fan_in")
            }
        return p

    def cache_specs(self, batch: int, max_len: int):
        return {"layers": ssm_cache_specs(self.cfg, batch, self.cfg.n_layers),
                "pos": ParamSpec((), (), jnp.int32, "zeros")}

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return x @ params["embed"]["embedding"].astype(cfg.dtype).T
        return dense(params["lm_head"], x, cfg.dtype)

    def _embed(self, params, ctx, tokens):
        cfg = self.cfg
        x = params["embed"]["embedding"].astype(cfg.dtype)[tokens]
        return ctx.constrain(x, "batch", "seq_sp", None)

    def _policy(self):
        return {
            "none": None,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "full": jax.checkpoint_policies.nothing_saveable,
        }[self.cfg.remat_policy]

    def loss(self, params, batch, ctx: Ctx):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, ctx, tokens)
        policy = self._policy()

        def blk(x, p):
            return x + mamba_block(p["mixer"], cfg, ctx,
                                   rmsnorm(p["ln"], x, cfg.norm_eps))

        if policy is not None:
            blk = jax.checkpoint(blk, policy=policy)

        x, _ = jax.lax.scan(lambda h, p: (blk(h, p), ()), x, params["blocks"])
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce, z = chunked_ce_loss(lambda xc: self._logits(params, xc), x, labels,
                                mask.astype(jnp.float32), cfg.loss_chunk)
        return ce + 1e-4 * z, {"ce": ce, "z": z}

    def prefill(self, params, batch, ctx: Ctx):
        """Full-sequence pass emitting final (conv, ssd) states per layer —
        the decode-ready cache (constant-size regardless of prompt length)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens)

        def body(h, p):
            y, st = mamba_block(p["mixer"], cfg, ctx,
                                rmsnorm(p["ln"], h, cfg.norm_eps),
                                return_state=True)
            return h + y, st

        x, states = jax.lax.scan(body, x, params["blocks"])
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"layers": states, "pos": jnp.asarray(S, jnp.int32)}

    def build_pcilt(self, params, scale, proj_scales=None, proj_path="fused",
                    projections=None, mesh=None, mesh_axis="model",
                    table_dtype=jnp.float32, head_scale=None,
                    head_weight_bits=4, paired=False):
        """Offline PCILT build for the decode hot loop (requires
        ``cfg.pcilt``).

        Conv frontend: per-layer ``[C, V]`` tables stacked to ``[L, C, V]``
        so they ride the decode scan exactly like parameters; ``scale`` is
        the calibrated per-tensor activation scale of the conv input.

        Projections (full-PCILT decode): pass ``proj_scales`` — per-layer
        calibrated absmax-derived scales ``{"in": [L], "out": [L]}`` (see
        :meth:`calibrate_pcilt` / ``core.serving.convert_mamba_decode``) —
        and every projection in ``projections`` (default: all six,
        ``nn.ssm.PROJ_NAMES``) gains a layer-stacked ``[L, G, V, O]``
        grouped-table array.  The stack is **closure-resident** in
        :meth:`decode_step` (never sliced by the scan — the stacked kernel's
        scalar-prefetch staging reads it in place); with ``mesh=`` it is
        placed with the segment axis sharded over ``mesh_axis`` (the
        ``"table_seg"`` rule, ``seg_axis=1``) so each device holds
        ``[L, G/D, V, O]`` and every projection costs one psum per step.
        ``proj_path`` selects the execution route (``"fused"`` stacked
        kernel; ``"kernel"``/``"gather"``/``"onehot"`` host-packed
        references; ``"dense_fq"`` fake-quant dense oracle).

        With ``paired=True`` the projection stacks are built in the
        TL1-style multi-scalar layout instead: **segment-major**
        ``[G2, L, V2, O]`` paired tables
        (``core.pcilt.build_paired_stacked_tables`` — each fetch covers two
        adjacent segments, halving fetch count and adder-tree depth) and
        decode dispatches the paired row-gather kernels.  Under a mesh the
        *pair* axis shards (``seg_axis=0``).  The conv frontend and logits
        head are unchanged.

        Logits head: pass ``head_scale`` (calibrated absmax-derived scale of
        the ``ln_f`` output — ``calibrate_pcilt``'s ``head_in``) and the
        tied-embedding / ``lm_head`` kernel is fake-quantized to
        ``head_weight_bits`` and converted to a **shared-pool** (ext.-3)
        PCILT (``pool [X, V, O]`` + ``seg_idx [G]``), executed by
        :meth:`_head_logits` on the ``"shared"`` dispatch path.

        The returned bundle carries an ``"integrity"`` record — per-layer
        CRC-32 checksums of every table array
        (``core.serving.pcilt_integrity``) — verified at executor load and
        on demand by the serving health monitor.
        """
        from repro.core import QuantSpec
        from repro.core.lut_layers import build_dwconv_tables

        cfg = self.cfg
        if cfg.pcilt is None:
            raise ValueError(
                "MambaLM.build_pcilt requires cfg.pcilt (a configs.base."
                "PCILTConfig supplying act_bits/group for the table build); "
                "got None — set cfg = dataclasses.replace(cfg, "
                "pcilt=PCILTConfig(...)) before converting, or decode dense "
                "with pcilt=None")
        # the conv input (xBC) is a pre-activation stream — signed, so the
        # grid must straddle zero (symmetric), unlike post-ReLU CNN codes
        spec = QuantSpec(bits=cfg.pcilt.act_bits, symmetric=True)
        tables = jax.vmap(
            lambda w: build_dwconv_tables(w, spec, scale)
        )(params["blocks"]["mixer"]["conv_w"])  # [L, C, V]
        out = {"tables": tables, "scale": scale, "spec": spec}
        if proj_scales is not None:
            out["proj"] = self._build_proj_pcilt(
                params, spec, proj_scales, proj_path, projections, mesh,
                mesh_axis, table_dtype, paired)
        if head_scale is not None:
            out["head"] = self._build_head_pcilt(
                params, head_scale, head_weight_bits)
        from repro.core.serving import pcilt_integrity

        out["integrity"] = pcilt_integrity(out)
        return out

    def _build_head_pcilt(self, params, head_scale, head_weight_bits):
        """Shared-pool (ext.-3) PCILT over the weight-quantized logits head.

        Weight fake-quantization to ``head_weight_bits`` gives the kernel a
        low segment cardinality, so the ``[G, V, O]`` grouped tables dedupe
        into a ``pool [X, V, O]`` + ``seg_idx [G]`` pointer vector; the
        quantized kernel itself rides along as the exact dense oracle the
        demoted path evaluates (``fetch(x) == fake_quant(x) @ kernel_q`` on
        the activation grid — zero-padded alignment rows contribute 0).
        """
        from repro.core import (QuantSpec, build_shared_grouped_tables,
                                fake_quant, scale_from_amax)

        cfg = self.cfg
        group = cfg.pcilt.group
        if cfg.tie_embeddings:
            k = params["embed"]["embedding"].astype(jnp.float32).T  # [d, Vp]
        else:
            k = params["lm_head"]["kernel"].astype(jnp.float32)
        wspec = QuantSpec(bits=head_weight_bits, symmetric=True)
        w_scale = scale_from_amax(jnp.max(jnp.abs(k)), wspec)
        kq = fake_quant(k, wspec, w_scale)
        n = kq.shape[0]
        pad = (-n) % group
        kp = jnp.concatenate(
            [kq, jnp.zeros((pad, kq.shape[1]), kq.dtype)], 0) if pad else kq
        spec = QuantSpec(bits=cfg.pcilt.act_bits, symmetric=True)
        shared = build_shared_grouped_tables(
            kp, spec, head_scale, group)
        return {"pool": shared.pool, "seg_idx": shared.seg_idx,
                "group": group, "spec": spec,
                "scale": jnp.asarray(head_scale, jnp.float32),
                "kernel_q": kq, "n": n + pad}

    def _head_logits(self, head, x, ok=None):
        """Last-position logits through the shared-pool PCILT head.

        ``x [B, d]`` -> ``[B, padded_vocab]``.  ``ok`` (traced bool) demotes
        the fetch to the exact fake-quant dense oracle under ``lax.cond`` —
        the response to a corrupted pool entry or re-aimed ``seg_idx``
        pointer."""
        from repro.core import fake_quant, pcilt_linear
        from repro.core.pcilt import SharedGroupedTables

        cfg = self.cfg

        def _fetch(xx):
            pad = head["n"] - xx.shape[-1]
            if pad:  # group-alignment slots (zero weights -> zero tables)
                xx = jnp.concatenate(
                    [xx, jnp.zeros((*xx.shape[:-1], pad), xx.dtype)], -1)
            shared = SharedGroupedTables(pool=head["pool"],
                                         seg_idx=head["seg_idx"],
                                         group=head["group"])
            return pcilt_linear(
                xx.astype(jnp.float32), shared, head["spec"], head["scale"],
                head["group"], path="shared").astype(cfg.dtype)

        def _oracle(xx):
            xq = fake_quant(xx.astype(jnp.float32), head["spec"],
                            head["scale"])
            return (xq @ head["kernel_q"]).astype(cfg.dtype)

        if ok is None:
            return _fetch(x)
        return jax.lax.cond(jnp.asarray(ok, bool), _fetch, _oracle, x)

    def _build_proj_pcilt(self, params, spec, proj_scales, proj_path,
                          projections, mesh, mesh_axis, table_dtype,
                          paired=False):
        """Stacked grouped tables per decode projection: dense
        ``[L, G, V, O]`` or, with ``paired``, seg-major ``[G2, L, V2, O]``
        paired stacks (``build_paired_stacked_tables``)."""
        from repro.core import build_grouped_tables
        from repro.core.pcilt import build_paired_stacked_tables
        from repro.core.lut_layers import mesh_shard_count
        from repro.nn.ssm import PROJ_NAMES

        cfg = self.cfg
        group = cfg.pcilt.group
        tabs, scales = {}, {}
        for name in (projections or PROJ_NAMES):
            ks = params["blocks"]["mixer"][name]["kernel"]  # [L, n, O]
            s_l = jnp.asarray(
                proj_scales["out" if name == "wo" else "in"], jnp.float32)
            _, n, O = ks.shape
            pad_n = (-n) % group

            if paired:
                # build_paired_stacked_tables pads n to the pair width
                # itself (alignment + phantom slots from zero weights) and
                # returns the seg-major [G2, L, V2, O] layout; building in
                # f32 and casting once keeps bf16 tables rounding-safe.
                t = build_paired_stacked_tables(
                    ks.astype(jnp.float32), spec, s_l, group
                ).astype(table_dtype)
                seg_count, seg_axis = t.shape[0], 0
            else:
                def build(w, s):
                    wf = w.astype(jnp.float32)
                    if pad_n:  # group-alignment slots from zero weights
                        wf = jnp.concatenate(
                            [wf, jnp.zeros((pad_n, wf.shape[-1]), wf.dtype)],
                            0)
                    return build_grouped_tables(wf, spec, s, group)

                t = jax.vmap(build)(ks, s_l).astype(table_dtype)
                seg_count, seg_axis = t.shape[1], 1
            if mesh is not None and mesh_shard_count(
                    mesh, mesh_axis, seg_count) > 1:
                from repro.nn.module import pcilt_table_sharding

                t = jax.device_put(t, pcilt_table_sharding(
                    mesh, seg_count, ndim=4, mesh_axis=mesh_axis,
                    seg_axis=seg_axis))
            tabs[name] = t
            scales[name] = s_l
        return {"tables": tabs, "scales": scales, "spec": spec,
                "group": group, "path": proj_path, "mesh": mesh,
                "mesh_axis": mesh_axis, "paired": paired}

    def calibrate_pcilt(self, params, batch, ctx: Ctx):
        """Calibration prefill: one full-sequence pass over a calibration
        batch capturing the per-layer absmax of every activation the PCILT
        decode quantizes — the in-projection input (the post-``ln`` block
        input feeding ``wz``/``wx``/``wB``/``wC``/``wdt``), the ``wo``
        input (post-norm gated ``y``), and the conv input (pre-activation
        ``xBC``).  Returns ``{"in": [L], "out": [L], "conv_in": []}``
        absmax arrays; ``core.serving.convert_mamba_decode`` turns them
        into quantization scales."""
        cfg = self.cfg
        x = self._embed(params, ctx, batch["tokens"])

        def body(h, p):
            xn = rmsnorm(p["ln"], h, cfg.norm_eps)
            y, calib = mamba_block(p["mixer"], cfg, ctx, xn,
                                   return_calib=True)
            stats = {"in": jnp.max(jnp.abs(xn)).astype(jnp.float32),
                     "out": calib["wo_in"], "conv_in": calib["conv_in"]}
            return h + y, stats

        h, stats = jax.lax.scan(body, x, params["blocks"])
        head_in = jnp.max(
            jnp.abs(rmsnorm(params["ln_f"], h, cfg.norm_eps))
        ).astype(jnp.float32)
        return {"in": stats["in"], "out": stats["out"],
                "conv_in": jnp.max(stats["conv_in"]), "head_in": head_in}

    def decode_step(self, params, cache, tokens, ctx: Ctx, pcilt=None,
                    layer_ok=None, head_ok=None, with_stats: bool = False):
        """One decode step.  ``pcilt`` (from :meth:`build_pcilt`) routes every
        layer's conv frontend through the fused PCILT fetch; with a
        ``pcilt["proj"]`` bundle the projections execute as layer-stacked
        table fetches too — the stacked ``[L, G, V, O]`` tables stay
        closure-resident while only the integer layer index and that layer's
        calibration scales ride the scan.

        Resilience masks: ``layer_ok`` (``[L]`` bool) and ``head_ok`` (bool)
        demote individual layers' fetches (conv + projections) or the PCILT
        logits head to their exact dense fake-quant oracles under
        ``lax.cond``.  They are runtime *arguments* — flipping a bit never
        retraces — and an all-True mask executes the identical fetch
        computation, so healthy serving is bitwise-unchanged.

        Drift sentinel: ``with_stats=True`` returns a third value — the
        per-layer saturation statistics of every distinct quantizer,
        ``{"in"|"conv"|"out": {"count" [L] i32, "ratio" [L] f32}}``
        (see :func:`repro.nn.ssm.mamba_decode`), stacked by the layer scan.
        Logits and the cache are bit-identical either way; the counters ride
        the fetch kernels' own grids, so the monitored step adds no second
        pass over any activation."""
        cfg = self.cfg
        if pcilt is None and (layer_ok is not None or head_ok is not None):
            raise ValueError(
                "layer_ok/head_ok demote PCILT fetches to their dense "
                "oracles — they require a pcilt bundle (got pcilt=None)")
        if with_stats and pcilt is None:
            raise ValueError(
                "with_stats reports the PCILT quantizers' saturation — it "
                "requires a pcilt bundle (got pcilt=None)")
        pos = cache["pos"]
        x = self._embed(params, ctx, tokens)
        proj = None if pcilt is None else pcilt.get("proj")

        def body(h, inp):
            p, st = inp[0], inp[1]
            per = inp[3] if len(inp) > 3 else {}
            pc = None
            if pcilt is not None:
                pc = {"tables": inp[2], "scale": pcilt["scale"],
                      "spec": pcilt["spec"]}
                if "ok" in per:
                    pc["ok"] = per["ok"]
                if proj is not None:
                    pc["proj"] = {
                        "tables": proj["tables"],  # full stack, not scanned
                        "spec": proj["spec"], "group": proj["group"],
                        "path": proj["path"], "mesh": proj["mesh"],
                        "mesh_axis": proj["mesh_axis"],
                        "layer": per["layer"], "scale": per["scale"],
                        "paired": proj.get("paired", False),
                        "ok": per.get("ok")}
            res = mamba_decode(p["mixer"], cfg, ctx,
                               rmsnorm(p["ln"], h, cfg.norm_eps), st,
                               pcilt=pc, with_stats=with_stats)
            if with_stats:
                y, st2, sat = res
                return h + y, (st2, sat)
            y, st2 = res
            return h + y, st2

        xs = (params["blocks"], cache["layers"])
        if pcilt is not None:
            xs = xs + (pcilt["tables"],)
            per = {}
            if proj is not None:
                per["layer"] = jnp.arange(cfg.n_layers, dtype=jnp.int32)
                per["scale"] = proj["scales"]
            if layer_ok is not None:
                per["ok"] = jnp.asarray(layer_ok, bool)
            if per:
                xs = xs + (per,)
        x, ys = jax.lax.scan(body, x, xs)
        new_states, sat = ys if with_stats else (ys, None)
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        head = None if pcilt is None else pcilt.get("head")
        if head is None:
            logits = self._logits(params, x)[:, -1]
        else:
            logits = self._head_logits(head, x[:, -1], head_ok)
        new_cache = dict(cache, layers=new_states, pos=pos + 1)
        if with_stats:
            return logits, new_cache, sat
        return logits, new_cache
