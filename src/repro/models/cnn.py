"""The paper's own example network: a 5-layer CNN (50-80-120-200-350).

"In a modest-sized CNN — 5 convolutional layers, 50x80x120x200x350 neurons —
using internally 8-bit activations and 5x5 filters with 8-bit values, PCILTs
would need about 1.65 GB" (§Basic Version).  This model is the faithful
reproduction target: it runs with the classic direct-multiplication (DM)
algorithm or any PCILT path, and ``benchmarks/paper_claims.py`` reproduces
the paper's memory/op-count arithmetic from its exact dimensions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    QuantSpec, calibrate, quantize, dequantize, build_grouped_tables,
    pcilt_conv2d,
)
from repro.nn.module import ParamSpec
from repro.nn.layers import Ctx

__all__ = ["PaperCNN", "PAPER_CHANNELS", "PAPER_FILTER"]

PAPER_CHANNELS = (50, 80, 120, 200, 350)
PAPER_FILTER = 5


@dataclasses.dataclass
class PaperCNN:
    """5 conv layers + ReLU + global-avg-pool classifier head."""

    in_channels: int = 1
    n_classes: int = 10
    channels: tuple = PAPER_CHANNELS
    k: int = PAPER_FILTER
    act_spec: QuantSpec = QuantSpec(bits=8, symmetric=False)
    group: int = 1

    def param_specs(self):
        p = {}
        cin = self.in_channels
        for i, cout in enumerate(self.channels):
            p[f"conv{i}"] = ParamSpec((self.k, self.k, cin, cout),
                                      (None, None, None, None), jnp.float32,
                                      "fan_in")
            cin = cout
        p["head"] = ParamSpec((cin, self.n_classes), (None, None), jnp.float32,
                              "fan_in")
        return p

    def forward(self, params, x, mode: str = "dm",
                scales: Optional[Dict] = None, tables: Optional[Dict] = None):
        """x [B,H,W,Cin].  mode: "dm" (direct multiplication baseline) or a
        PCILT path ("gather" | "onehot" | "kernel").

        In PCILT modes activations are quantized to ``act_spec`` before every
        conv (the paper's low-cardinality precondition); the DM oracle for
        comparisons quantizes identically, so both paths see the same inputs
        and PCILT is *exact* — "there is no result precision loss".
        """
        scales = scales or {}
        for i in range(len(self.channels)):
            w = params[f"conv{i}"]
            s = scales.get(f"conv{i}") or calibrate(x, self.act_spec)
            if mode == "dm":
                xq = dequantize(quantize(x, self.act_spec, s), self.act_spec, s)
                x = jax.lax.conv_general_dilated(
                    xq, w, (1, 1), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            else:
                x = pcilt_conv2d(
                    x, w, self.act_spec, s, group=self.group, path=mode,
                    tables=None if tables is None else tables[f"conv{i}"])
            x = jax.nn.relu(x)
        x = x.mean(axis=(1, 2))  # [B, C]
        return x @ params["head"]

    def build_tables(self, params, scales: Dict):
        """Offline table build (once per network lifetime, paper §Basic)."""
        out = {}
        for i in range(len(self.channels)):
            w = params[f"conv{i}"]
            kh, kw, cin, cout = w.shape
            n = kh * kw * cin
            pad = (-n) % self.group
            wflat = w.reshape(n, cout)
            if pad:
                wflat = jnp.concatenate(
                    [wflat, jnp.zeros((pad, cout), wflat.dtype)], 0)
            out[f"conv{i}"] = build_grouped_tables(
                wflat, self.act_spec, scales[f"conv{i}"], self.group)
        return out
