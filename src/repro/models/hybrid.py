"""Zamba2-style hybrid: Mamba2 backbone + *shared* attention blocks.

Structure (Zamba2-7B, arXiv:2411.15242): a stack of Mamba2 blocks; every
``shared_attn_period`` blocks a shared transformer block runs on
``concat(hidden, original_embedding)`` (2·d wide), with
``n_shared_attn_blocks`` parameter sets used round-robin across applications.
Weight sharing keeps parameters low while giving periodic global mixing.

Implementation: segments of ``period`` Mamba blocks are scanned; shared
attention applications sit between segments (a python loop over ~14 segments
keeps the HLO small while letting each application address its own KV cache
slot).  Decode carries: per-layer SSM states + per-application KV caches —
the attention caches dominate ``long_500k`` and shard over the data axis
(batch=1 ⇒ the cache_seq rule engages, DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec
from repro.nn.layers import Ctx, dense, dense_spec, embed_spec, rmsnorm_spec, rmsnorm
from repro.nn.attention import attention_spec, attention, init_cache_specs
from repro.nn.ssm import mamba_spec, mamba_block, mamba_decode, ssm_cache_specs
from .transformer import stack_specs, chunked_ce_loss, mlp_spec, mlp

__all__ = ["HybridLM"]


@dataclasses.dataclass
class HybridLM:
    cfg: Any

    # -- structure ---------------------------------------------------------

    def _segments(self):
        """[(start, length), ...] covering n_layers in period-sized chunks."""
        cfg = self.cfg
        period = cfg.shared_attn_period
        segs, i = [], 0
        while i < cfg.n_layers:
            segs.append((i, min(period, cfg.n_layers - i)))
            i += period
        return segs

    def n_attn_applications(self) -> int:
        return len(self._segments())

    def _shared_block_spec(self):
        cfg = self.cfg
        return {
            "ln": rmsnorm_spec(2 * cfg.d_model, cfg.param_dtype),
            "attn": attention_spec(cfg, d_in=2 * cfg.d_model,
                                   dtype=cfg.param_dtype),
            "ln_mlp": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
            "mlp": mlp_spec(cfg, cfg.param_dtype),
        }

    def param_specs(self):
        cfg = self.cfg
        block = {"ln": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
                 "mixer": mamba_spec(cfg, cfg.param_dtype)}
        return {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
            "blocks": stack_specs(block, cfg.n_layers),
            "shared": stack_specs(self._shared_block_spec(),
                                  cfg.n_shared_attn_blocks),
            "ln_f": rmsnorm_spec(cfg.d_model, cfg.param_dtype),
            "lm_head": {
                "kernel": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                                    cfg.param_dtype, "fan_in")
            },
        }

    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        napp = self.n_attn_applications()
        kv = init_cache_specs(cfg, batch, max_len, napp, layer_axis=True)
        return {
            "ssm": {"layers": ssm_cache_specs(cfg, batch, cfg.n_layers)},
            "attn": kv,
            "pos": ParamSpec((), (), jnp.int32, "zeros"),
        }

    # -- shared attention application ---------------------------------------

    def _shared_attn(self, params_i, ctx, x, x0, positions, cache=None):
        """One shared-block application on concat(x, x0)."""
        cfg = self.cfg
        xin = jnp.concatenate([x, x0], axis=-1)
        h, new_cache = attention(
            params_i["attn"], cfg, ctx,
            rmsnorm(params_i["ln"], xin, cfg.norm_eps),
            positions, causal=True, cache=cache,
        )
        x = x + h
        x = x + mlp(params_i["mlp"], cfg, ctx,
                    rmsnorm(params_i["ln_mlp"], x, cfg.norm_eps))
        return x, new_cache

    def _select_shared(self, params, app_idx: int):
        i = app_idx % self.cfg.n_shared_attn_blocks
        return jax.tree.map(lambda a: a[i], params["shared"])

    # -- helpers -------------------------------------------------------------

    def _embed(self, params, ctx, tokens):
        x = params["embed"]["embedding"].astype(self.cfg.dtype)[tokens]
        return ctx.constrain(x, "batch", "seq_sp", None)

    def _policy(self):
        return {
            "none": None,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "full": jax.checkpoint_policies.nothing_saveable,
        }[self.cfg.remat_policy]

    def _mamba_segment(self, params, ctx, x, start, length):
        cfg = self.cfg
        seg = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length),
                           params["blocks"])
        policy = self._policy()

        def blk(h, p):
            return h + mamba_block(p["mixer"], cfg, ctx,
                                   rmsnorm(p["ln"], h, cfg.norm_eps))

        if policy is not None:
            blk = jax.checkpoint(blk, policy=policy)
        x, _ = jax.lax.scan(lambda h, p: (blk(h, p), ()), x, seg)
        return x

    # -- modes ---------------------------------------------------------------

    def loss(self, params, batch, ctx: Ctx):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens)
        x0 = x
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        policy = self._policy()
        for app, (start, length) in enumerate(self._segments()):
            shared_p = self._select_shared(params, app)

            def shared_fn(p, x, x0):
                return self._shared_attn(p, ctx, x, x0, positions)[0]

            if policy is not None:  # shared blocks sit outside the layer
                shared_fn = jax.checkpoint(shared_fn, policy=policy)
            x = shared_fn(shared_p, x, x0)
            x = self._mamba_segment(params, ctx, x, start, length)
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce, z = chunked_ce_loss(lambda xc: dense(params["lm_head"], xc, cfg.dtype),
                                x, labels, mask.astype(jnp.float32),
                                cfg.loss_chunk)
        return ce + 1e-4 * z, {"ce": ce, "z": z}

    def prefill(self, params, batch, ctx: Ctx):
        """Full-sequence pass emitting per-application KV caches + per-layer
        SSM states (the decode-ready hybrid cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens)
        x0 = x
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        attn_k, attn_v, seg_states = [], [], []
        for app, (start, length) in enumerate(self._segments()):
            x, kv = self._shared_attn(self._select_shared(params, app), ctx,
                                      x, x0, positions)
            attn_k.append(kv["k"])
            attn_v.append(kv["v"])
            seg = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, start, start + length),
                params["blocks"])

            def body(h, p):
                y, st = mamba_block(p["mixer"], cfg, ctx,
                                    rmsnorm(p["ln"], h, cfg.norm_eps),
                                    return_state=True)
                return h + y, st

            x, states = jax.lax.scan(body, x, seg)
            seg_states.append(states)
        ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *seg_states)
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = dense(params["lm_head"], x[:, -1:], cfg.dtype)[:, 0]
        cache = {
            "ssm": {"layers": ssm},
            "attn": {"k": jnp.stack(attn_k), "v": jnp.stack(attn_v)},
            "pos": jnp.asarray(S, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens, ctx: Ctx):
        cfg = self.cfg
        pos = cache["pos"]
        B = tokens.shape[0]
        x = self._embed(params, ctx, tokens)
        x0 = x
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        new_attn_k, new_attn_v = [], []
        ssm_states = cache["ssm"]["layers"]
        new_ssm = jax.tree.map(lambda a: a, ssm_states)

        for app, (start, length) in enumerate(self._segments()):
            kv = {"k": cache["attn"]["k"][app], "v": cache["attn"]["v"][app],
                  "pos": pos}
            x, nc = self._shared_attn(self._select_shared(params, app), ctx,
                                      x, x0, positions, cache=kv)
            new_attn_k.append(nc["k"])
            new_attn_v.append(nc["v"])
            seg_params = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, start, start + length),
                params["blocks"])
            seg_states = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, start, start + length),
                ssm_states)

            def body(h, inp):
                p, st = inp
                y, st2 = mamba_decode(p["mixer"], cfg, ctx,
                                      rmsnorm(p["ln"], h, cfg.norm_eps), st)
                return h + y, st2

            x, seg_new = jax.lax.scan(body, x, (seg_params, seg_states))
            new_ssm = jax.tree.map(
                lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), start, axis=0),
                new_ssm, seg_new)

        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = dense(params["lm_head"], x, cfg.dtype)[:, -1]
        new_cache = dict(
            cache,
            ssm={"layers": new_ssm},
            attn={"k": jnp.stack(new_attn_k), "v": jnp.stack(new_attn_v)},
            pos=pos + 1,
        )
        return logits, new_cache
