"""Transformer model zoo: decoder-only (dense + MoE), encoder-decoder
(whisper), and VLM glue (llava) — one scanned-block implementation.

Structure: layers are scanned in *units* of ``cfg.moe.interleave`` blocks
(llama4 alternates dense/MoE every other layer; granite is MoE every layer;
dense models are unit size 1).  Units are stacked on a leading "layers" axis
and driven by ``jax.lax.scan`` with a configurable remat policy — this keeps
the HLO O(one unit) for 62-layer models and is what makes 400B-parameter
lowering tractable.

Modes: ``loss`` (training, chunked-vocab CE), ``prefill`` (build KV cache),
``decode`` (single token step against the cache).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import ParamSpec
from repro.nn.layers import (
    Ctx, dense_spec, dense, embed_spec, rmsnorm_spec, rmsnorm,
    layernorm_spec, layernorm, sinusoidal_positions,
)
from repro.nn.attention import attention_spec, attention, init_cache_specs
from repro.nn.moe import moe_spec, moe_apply

__all__ = ["TransformerLM", "stack_specs", "chunked_ce_loss"]


def stack_specs(tree, n: int):
    """Prepend a scanned "layers" dim to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), s.dtype, s.init,
                            s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _use_ln(cfg) -> bool:
    return cfg.family == "audio"  # whisper uses LayerNorm + GELU


def mlp_spec(cfg, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    if _use_ln(cfg):
        return {
            "wi": dense_spec(d, f, ("embed", "mlp"), bias=True, dtype=dtype),
            "wo": dense_spec(f, d, ("mlp", "embed"), bias=True, dtype=dtype),
        }
    return {
        "wg": dense_spec(d, f, ("embed", "mlp"), dtype=dtype),
        "wu": dense_spec(d, f, ("embed", "mlp"), dtype=dtype),
        "wd": dense_spec(f, d, ("mlp", "embed"), dtype=dtype),
    }


def mlp(params, cfg, ctx: Ctx, x):
    if "wi" in params:
        h = jax.nn.gelu(dense(params["wi"], x, cfg.dtype))
        h = ctx.constrain(h, "batch", None, "mlp")
        return ctx.constrain(dense(params["wo"], h, cfg.dtype),
                             "batch", "seq_sp", None)
    g = dense(params["wg"], x, cfg.dtype)
    u = dense(params["wu"], x, cfg.dtype)
    h = ctx.constrain(jax.nn.silu(g) * u, "batch", None, "mlp")
    from repro.nn.layers import row_parallel

    y = row_parallel(ctx, h, params["wd"]["kernel"], "bsf,fd->bsd")
    if y is not None:
        return y
    return ctx.constrain(dense(params["wd"], h, cfg.dtype),
                         "batch", "seq_sp", None)


def block_spec(cfg, use_moe: bool, cross: bool = False, dtype=jnp.float32):
    norm = layernorm_spec if _use_ln(cfg) else rmsnorm_spec
    p = {
        "ln_attn": norm(cfg.d_model, dtype),
        "attn": attention_spec(cfg, dtype=dtype),
        "ln_mlp": norm(cfg.d_model, dtype),
    }
    if cross:
        p["ln_cross"] = norm(cfg.d_model, dtype)
        p["cross"] = attention_spec(cfg, dtype=dtype)
    p["moe" if use_moe else "mlp"] = (
        moe_spec(cfg, dtype) if use_moe else mlp_spec(cfg, dtype)
    )
    if use_moe and cfg.moe.shared_expert:
        p["shared_mlp"] = mlp_spec(cfg, dtype)
    return p


def _norm(params, cfg, x):
    return (layernorm if _use_ln(cfg) else rmsnorm)(params, x, cfg.norm_eps)


def block_apply(
    params, cfg, ctx: Ctx, x, positions, causal=True,
    cache=None, cross_kv=None,
) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """One transformer block.  Returns (x, new_cache, aux)."""
    aux = {}
    h, new_cache = attention(
        params["attn"], cfg, ctx, _norm(params["ln_attn"], cfg, x),
        positions, causal=causal, cache=cache,
    )
    x = x + h
    if cross_kv is not None:
        h, _ = attention(
            params["cross"], cfg, ctx, _norm(params["ln_cross"], cfg, x),
            positions, causal=False, cross_kv=cross_kv,
        )
        x = x + h
    xn = _norm(params["ln_mlp"], cfg, x)
    if "moe" in params:
        h, aux = moe_apply(params["moe"], cfg, ctx, xn)
        if "shared_mlp" in params:
            h = h + mlp(params["shared_mlp"], cfg, ctx, xn)
    else:
        h = mlp(params["mlp"], cfg, ctx, xn)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------


def chunked_ce_loss(logits_fn, x, labels, mask, chunk: int):
    """Cross-entropy + z-loss over S-chunks via scan (bounds logits memory).

    logits_fn: [B, c, d] -> [B, c, V] (the lm head); x [B,S,d]; labels [B,S].
    """
    B, S, d = x.shape
    c = min(chunk, S) if chunk else S
    while S % c:
        c -= 1
    n = S // c

    def chunk_loss(xc, lc, mc):
        logits = logits_fn(xc).astype(jnp.float32)  # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mc
        z = jnp.square(lse) * mc
        return ce.sum(), z.sum()

    if n == 1:
        ce, z = chunk_loss(x, labels, mask)
    else:
        xs = (
            jnp.moveaxis(x.reshape(B, n, c, d), 1, 0),
            jnp.moveaxis(labels.reshape(B, n, c), 1, 0),
            jnp.moveaxis(mask.reshape(B, n, c), 1, 0),
        )

        def body(acc, inp):
            ce, z = jax.checkpoint(chunk_loss)(*inp)
            return (acc[0] + ce, acc[1] + z), ()

        (ce, z), _ = jax.lax.scan(body, (0.0, 0.0), xs)
    denom = jnp.maximum(mask.sum(), 1.0)
    return ce / denom, z / denom


@dataclasses.dataclass
class TransformerLM:
    """Facade: param specs + loss / prefill / decode for one config."""

    cfg: Any

    # ---------------- specs ----------------

    def _unit_size(self) -> int:
        return self.cfg.moe.interleave if self.cfg.moe else 1

    def _n_units(self) -> int:
        u = self._unit_size()
        if self.cfg.n_layers % u:
            raise ValueError(
                f"n_layers {self.cfg.n_layers} is not a multiple of the MoE "
                f"interleave unit size {u}")
        return self.cfg.n_layers // u

    def _unit_spec(self, cross=False):
        cfg, u = self.cfg, self._unit_size()
        return {
            f"sub{i}": block_spec(
                cfg, use_moe=(cfg.moe is not None and i == u - 1), cross=cross,
                dtype=cfg.param_dtype,
            )
            for i in range(u)
        }

    def param_specs(self):
        cfg = self.cfg
        norm = layernorm_spec if _use_ln(cfg) else rmsnorm_spec
        p = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
            "blocks": stack_specs(self._unit_spec(cross=cfg.encoder_layers > 0),
                                  self._n_units()),
            "ln_f": norm(cfg.d_model, cfg.param_dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = {
                "kernel": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                                    cfg.param_dtype, "fan_in")
            }
        if cfg.encoder_layers:
            p["encoder"] = {
                "blocks": stack_specs(
                    {"sub0": block_spec(cfg, use_moe=False,
                                        dtype=cfg.param_dtype)},
                    cfg.encoder_layers,
                ),
                "ln_f": norm(cfg.d_model, cfg.param_dtype),
            }
        if cfg.n_img_tokens:
            p["projector"] = {
                "w1": dense_spec(cfg.d_model, cfg.d_model, ("embed", "mlp"),
                                 bias=True, dtype=cfg.param_dtype),
                "w2": dense_spec(cfg.d_model, cfg.d_model, ("mlp", "embed"),
                                 bias=True, dtype=cfg.param_dtype),
            }
        return p

    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        per_unit = {
            f"sub{i}": init_cache_specs(cfg, batch, max_len, 1, layer_axis=False)
            for i in range(self._unit_size())
        }
        c = {"layers": stack_specs(per_unit, self._n_units()),
             "pos": ParamSpec((), (), jnp.int32, "zeros")}
        if cfg.encoder_layers:  # whisper: precomputed cross K/V per dec layer
            Hk, Dh = cfg.padded_kv_heads, cfg.resolved_head_dim
            c["cross_kv"] = {
                "k": ParamSpec((self._n_units(), batch, cfg.encoder_len, Hk, Dh),
                               ("layers", "batch", None, "kv_heads", None),
                               jnp.bfloat16, "zeros"),
                "v": ParamSpec((self._n_units(), batch, cfg.encoder_len, Hk, Dh),
                               ("layers", "batch", None, "kv_heads", None),
                               jnp.bfloat16, "zeros"),
            }
        return c

    # ---------------- shared machinery ----------------

    def _remat_policy(self):
        return {
            "none": None,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "full": jax.checkpoint_policies.nothing_saveable,
        }[self.cfg.remat_policy]

    def _embed(self, params, ctx, tokens, img_embeds=None):
        cfg = self.cfg
        e = params["embed"]["embedding"].astype(cfg.dtype)
        x = e[tokens]  # [B, S, d]
        if cfg.n_img_tokens and img_embeds is not None:
            h = jax.nn.gelu(dense(params["projector"]["w1"], img_embeds, cfg.dtype))
            img = dense(params["projector"]["w2"], h, cfg.dtype)
            x = jnp.concatenate([img, x], axis=1)  # early fusion: image first
        return ctx.constrain(x, "batch", "seq_sp", None)

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            k = params["embed"]["embedding"].astype(cfg.dtype).T
            return x @ k
        return dense(params["lm_head"], x, cfg.dtype)

    def _run_encoder(self, params, ctx, memory):
        """Whisper encoder over stub frame embeddings [B, F, d]."""
        cfg = self.cfg
        pos = sinusoidal_positions(memory.shape[1], cfg.d_model).astype(cfg.dtype)
        x = ctx.constrain(memory.astype(cfg.dtype) + pos[None], "batch", None, None)
        policy = self._remat_policy()

        def body(h, p):
            def blk(h, p):
                y, _, _ = block_apply(p["sub0"], cfg, ctx, h, None, causal=False)
                return y
            if policy is not None:
                blk = jax.checkpoint(blk, policy=policy)
            return blk(h, p), ()

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return _norm(params["encoder"]["ln_f"], cfg, x)

    def _cross_kv_from_memory(self, params, ctx, enc_out):
        """Precompute per-decoder-layer cross K/V (once per request)."""
        cfg = self.cfg

        def body(_, p):
            k = dense(p["sub0"]["cross"]["wk"], enc_out, cfg.dtype)
            v = dense(p["sub0"]["cross"]["wv"], enc_out, cfg.dtype)
            return (), (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

        _, (ks, vs) = jax.lax.scan(body, (), params["blocks"])
        return {"k": ks, "v": vs}

    def _run_blocks(self, params, ctx, x, positions, caches=None,
                    cache_pos=None, cross_kv=None, collect_cache=False):
        """Scan over layer units.  Returns (x, stacked caches or None, aux).

        caches: stacked per-unit KV dicts (decode).  collect_cache: emit the
        K/V computed during a full-sequence pass (prefill).  cross_kv: stacked
        whisper cross K/V.
        """
        cfg, u = self.cfg, self._unit_size()
        policy = self._remat_policy()
        aux0 = {"load_balance": jnp.zeros((), jnp.float32),
                "router_z": jnp.zeros((), jnp.float32)}

        def unit(x, p, cache_u, xkv):
            new_cache, aux_sum = {}, dict(aux0)
            for i in range(u):
                sub = f"sub{i}"
                cache_in = None
                if cache_u is not None:
                    cache_in = dict(cache_u[sub], pos=cache_pos)
                x, nc, aux = block_apply(
                    p[sub], cfg, ctx, x, positions, cache=cache_in,
                    cross_kv=None if xkv is None else (xkv["k"], xkv["v"]),
                )
                new_cache[sub] = nc
                for n in aux:
                    aux_sum[n] = aux_sum[n] + aux[n]
            return x, new_cache, aux_sum

        emit_cache = collect_cache or caches is not None

        def body(carry, inp):
            x, acc = carry
            p = inp[0]
            cache_u = inp[1] if caches is not None else None
            xkv = inp[-1] if cross_kv is not None else None

            def blk(x, p, cache_u, xkv):
                return unit(x, p, cache_u, xkv)

            if policy is not None and not emit_cache:
                blk = jax.checkpoint(blk, policy=policy)
            x, nc, aux = blk(x, p, cache_u, xkv)
            acc = {n: acc[n] + aux[n] for n in acc}
            return (x, acc), (nc if emit_cache else ())

        xs = [params["blocks"]]
        if caches is not None:
            xs.append(caches)
        if cross_kv is not None:
            xs.append(cross_kv)
        (x, aux), ys = jax.lax.scan(body, (x, aux0), tuple(xs))
        return x, (ys if emit_cache else None), aux

    # ---------------- public modes ----------------

    def loss(self, params, batch, ctx: Ctx):
        """batch: tokens [B,S], labels [B,S] (+ memory / img_embeds)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens, batch.get("img_embeds"))
        S_full = x.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S_full, dtype=jnp.int32)[None], (B, S_full)
        )
        if cfg.pos_embed == "sinusoidal":
            x = x + sinusoidal_positions(S_full, cfg.d_model).astype(cfg.dtype)[None]
        cross = None
        if cfg.encoder_layers:
            enc = self._run_encoder(params, ctx, batch["memory"])
            cross = self._cross_kv_from_memory(params, ctx, enc)
        x, _, aux = self._run_blocks(params, ctx, x, positions, cross_kv=cross)
        x = _norm(params["ln_f"], cfg, x)
        if cfg.n_img_tokens:  # image positions carry no next-token loss
            x = x[:, -S:]
        labels = batch["labels"]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce, z = chunked_ce_loss(
            lambda xc: self._logits(params, xc), x, labels,
            mask.astype(jnp.float32), cfg.loss_chunk,
        )
        loss = ce + 1e-4 * z
        if cfg.moe:
            loss = loss + 1e-2 * aux["load_balance"] / self._n_units() \
                 + 1e-3 * aux["router_z"] / self._n_units()
        metrics = {"ce": ce, "z": z, **aux}
        return loss, metrics

    def prefill(self, params, batch, ctx: Ctx):
        """Full-sequence forward emitting the KV cache + last-token logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, ctx, tokens, batch.get("img_embeds"))
        S_full = x.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S_full, dtype=jnp.int32)[None], (B, S_full)
        )
        if cfg.pos_embed == "sinusoidal":
            x = x + sinusoidal_positions(S_full, cfg.d_model).astype(cfg.dtype)[None]
        cross = None
        if cfg.encoder_layers:
            enc = self._run_encoder(params, ctx, batch["memory"])
            cross = self._cross_kv_from_memory(params, ctx, enc)
        x, layer_caches, _ = self._run_blocks(
            params, ctx, x, positions, cross_kv=cross, collect_cache=True,
        )
        x = _norm(params["ln_f"], cfg, x)
        logits = self._logits(params, x[:, -1:])[:, 0]
        cache = {"layers": layer_caches,
                 "pos": jnp.asarray(S_full, jnp.int32)}
        if cross is not None:
            cache["cross_kv"] = cross
        return logits, cache

    def decode_step(self, params, cache, tokens, ctx: Ctx):
        """tokens [B,1]; cache: {"layers": stacked KV, "pos": int32 scalar,
        optional "cross_kv"}.  Returns (logits [B,V], new cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = self._embed(params, ctx, tokens)
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        if cfg.pos_embed == "sinusoidal":
            x = x + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(cfg.dtype)[None]
        x, new_layers, _ = self._run_blocks(
            params, ctx, x, positions, caches=cache["layers"], cache_pos=pos,
            cross_kv=cache.get("cross_kv"),
        )
        x = _norm(params["ln_f"], cfg, x)
        logits = self._logits(params, x)[:, -1]
        new_cache = dict(cache, layers=new_layers, pos=pos + 1)
        return logits, new_cache
