"""Model zoo dispatch."""

from .transformer import TransformerLM
from .mamba import MambaLM
from .hybrid import HybridLM
from .cnn import PaperCNN

__all__ = ["build_model", "TransformerLM", "MambaLM", "HybridLM", "PaperCNN"]


def build_model(cfg):
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    return TransformerLM(cfg)  # dense | moe | audio | vlm
