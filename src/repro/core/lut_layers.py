"""PCILT inference layers.

Each layer executes the paper's fetch-instead-of-multiply semantics through
one of three interchangeable paths that produce bit-identical arithmetic:

* ``path="gather"`` — the literal algorithm: offsets address table rows
  (paper Fig. 2/6).  Reference semantics; also the right shape for CPU.
* ``path="onehot"`` — ``T[off] == onehot(off) @ T``: re-expresses every fetch
  as an MXU matmul.  This is the TPU-idiomatic lookup (DESIGN.md §2) and the
  path the distributed dry-run lowers, since it partitions like any einsum.
* ``path="kernel"`` — the Pallas TPU kernel (``repro.kernels``): tables tiled
  into VMEM via BlockSpec, offsets packed on the host and re-read by the
  kernel.
* ``path="fused"`` — the fused Pallas pipeline (``repro.kernels.pcilt_fused``):
  quantize → offset-pack → fetch → adder-tree entirely in VMEM from the raw
  float activations, so the int32 offset tensor never touches HBM.  Fastest
  deployment path; requires a per-tensor scale and the default contiguous
  segment plan.
* ``path="shared"`` — the shared-pool fused pipeline
  (``repro.kernels.pcilt_shared``) for extension-3 segment-deduped tables:
  ``tables`` is a ``SharedGroupedTables`` (pool + pointers) and the pointer
  indirection is resolved inside the kernel, so weight-deduped layers run at
  fused speed without ever materializing the dense ``[G, V, O]`` tables.
  A ``SharedGroupedTables`` also executes on ``path="gather"`` (its
  pointer-gather reference semantics) for parity checking.

Both kernel paths dispatch tile shapes through the persistent autotune lookup
table (``repro.kernels.autotune``) — recorded winners are used on a cache
hit, the VMEM heuristic otherwise.

The convolution layers reduce to the linear case by im2col — a PCILT is
indexed by (segment, offset) regardless of whether the segment came from a
flattened conv receptive field or a projection row.  (``path="fused"`` does
the im2col on quantized codes inside the kernel instead.)

Mesh execution (tensor-parallel decode)
---------------------------------------

Every path also runs sharded: pass ``mesh=`` (and optionally
``mesh_axis=``, default ``"model"``) and the segment axis ``G`` is split
across the mesh axis under ``shard_map`` — each device holds a ``[G/D, V, O]``
table shard (or a local ext.-3 pool, see ``pcilt.ShardedSharedPool``) plus
the matching slice of the activation's reduction dim, fetches and sums its
local segments with the *same* single-device kernels it would use unsharded,
and a single ``psum`` over the mesh axis combines the partial adder-tree
sums (the paper's segment sum is associative).  The fused/shared **conv**
paths stay VMEM-resident under the mesh too: the image is replicated, each
shard's conv kernel rebuilds the patch in VMEM and slices exactly the
columns its table shard covers (the kernels' ``seg_offset`` parameter) —
there is no host-im2col detour at any device count.  When the mesh axis
does not divide ``G`` the call falls back to replicated single-device
execution — the same divisibility fallback ``repro.nn.module.ShardingRules``
applies to parameters.  Because the kernels see *local* shapes, the autotune
lookup table is keyed on the local shard shape automatically.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .quantization import QuantSpec, quantize, quantize_with_stats
from .offsets import SegmentPlan, pack_offsets
from .pcilt import (SharedTables, SharedGroupedTables, ShardedSharedPool,
                    build_grouped_tables, shard_shared_grouped_tables)

__all__ = [
    "lut_lookup",
    "pcilt_linear",
    "pcilt_conv2d",
    "pcilt_depthwise_conv1d",
    "build_dwconv_tables",
    "im2col",
    "conv_same_pads",
    "mesh_shard_count",
]


@functools.lru_cache(maxsize=4096)
def conv_same_pads(h: int, w: int, kh: int, kw: int, stride: int = 1):
    """XLA-conformant "SAME" pads for NHWC (single source of truth — the
    fused/shared kernel wrappers in ``repro.kernels.ops`` import this).

    Matches ``lax.conv_general_dilated``: output extent ``ceil(size/stride)``
    and ``pad_total = (out-1)*stride + k - size`` split low-first as
    ``pad_total // 2`` — which differs from the naive stride-agnostic
    ``(k-1)//2`` whenever ``stride > 1`` and the size isn't congruent
    (e.g. stride 2 on an even extent: the naive split pads one extra low and
    every window samples shifted positions).

    Memoized (pure int arithmetic, hashable args): eager serving calls this
    on every conv step, and ``serving.PCILTConv2d`` additionally caches the
    whole padded-shape plan per input shape.
    """
    def axis(size: int, k: int):
        out = -(-size // stride)
        total = max((out - 1) * stride + k - size, 0)
        return (total // 2, total - total // 2)

    return ((0, 0), axis(h, kh), axis(w, kw), (0, 0))


def lut_lookup(tables: jax.Array, offsets: jax.Array, path: str = "gather") -> jax.Array:
    """Fetch-and-sum: ``sum_s T[s, off[..., s], :]``.

    tables: ``[G, V, O]`` grouped PCILTs.  offsets: integer ``[..., G]``.
    Returns ``[..., O]``.
    """
    G, V, O = tables.shape
    if path == "gather":
        # Literal table addressing.  [..., G, O] partials, then the adder tree.
        partial = jnp.take_along_axis(
            tables[(None,) * (offsets.ndim - 1)],
            offsets[..., None, None].astype(jnp.int32),
            axis=-2,
        )[..., 0, :]
        return jnp.sum(partial, axis=-2)
    if path == "onehot":
        oh = jax.nn.one_hot(offsets, V, dtype=tables.dtype)  # [..., G, V]
        return jnp.einsum("...gv,gvo->...o", oh, tables)
    if path == "kernel":
        from repro.kernels import ops  # local import: kernels are optional

        flat = offsets.reshape(-1, G)
        out = ops.pcilt_gemv(flat.astype(jnp.int32), tables)
        return out.reshape(*offsets.shape[:-1], O)
    raise ValueError(f"unknown path {path!r}")


def mesh_shard_count(mesh, mesh_axis: str, n_segments: int) -> int:
    """How many G-shards a mesh yields; 1 means replicate (fallback).

    Falls back to replication when there is no mesh, the axis is absent, or
    the axis size does not divide the segment count — the same divisibility
    fallback ``repro.nn.module.ShardingRules`` applies to parameter dims.
    """
    if mesh is None or mesh_axis not in mesh.axis_names:
        return 1
    d = int(mesh.shape[mesh_axis])
    if d <= 1 or n_segments % d:
        return 1
    return d


def _check_contiguous_segments(path: str, plan, n: int, n_segments: int,
                               group: int) -> None:
    """Typed boundary validation for the in-kernel-packing paths.

    ``path="shared"`` packs contiguous segments inside the kernel, so a
    generalized ``SegmentPlan`` (non-adjacent / skipped / reused positions)
    cannot execute there — reject it here, at the dispatch boundary, instead
    of letting a bare shape error surface from deep inside the kernel
    wrapper.  ``path="fused"`` *does* run generalized plans (the plan-gather
    kernel resolves the index in VMEM), so this helper only sees
    ``plan=None`` on the fused route — the residual check catches tables
    *built* with a generalized plan but dispatched without passing it
    (their segment count no longer satisfies ``G * group == n``).
    """
    if plan is not None:
        raise ValueError(
            f"path={path!r} packs contiguous segments in-kernel and cannot "
            f"follow a generalized SegmentPlan; drop plan= (contiguous "
            f"default), use path='fused' (which gathers the plan index in "
            f"VMEM), or use the host-packed paths ('gather'/'onehot'/"
            f"'kernel'), which honor plan.pack()")
    if n != n_segments * group:
        raise ValueError(
            f"path={path!r} requires contiguous segments covering the "
            f"reduction dim: got x trailing dim {n} but G*group = "
            f"{n_segments}*{group} = {n_segments * group}. Tables built from "
            f"a generalized SegmentPlan (skipped/reused positions) need that "
            f"plan passed as plan= (path='fused' runs it via the in-VMEM "
            f"plan gather; 'gather'/'onehot'/'kernel' via plan.pack())")


def _pad_paired_phantom(x: jax.Array, n_pairs: int, group: int) -> jax.Array:
    """Zero-pad ``x`` over the phantom segment of an odd-``G`` pairing.

    Paired tables cover ``n_pairs`` two-segment fetches; when the unpaired
    segment count was odd the builder padded a phantom segment whose table
    column is exactly zero (``build_paired_tables``), so the matching
    activation slots are zero here — any code they quantize to fetches 0.
    """
    want = n_pairs * 2 * group
    n = x.shape[-1]
    if n == want:
        return x
    if n == want - group:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, group)]
        return jnp.pad(x, widths)
    raise ValueError(
        f"x trailing dim {n} matches neither G2*2*group = {want} nor the "
        f"odd-G phantom layout {want - group} for paired tables with "
        f"G2={n_pairs}, group={group}")


def _shard_pool_for(tables: SharedGroupedTables,
                    n_shards: int) -> ShardedSharedPool:
    from repro import compat

    if compat.is_tracer(tables.seg_idx):
        raise ValueError(
            "sharding a SharedGroupedTables pool is an offline build step "
            "(np.unique on concrete pointers) and cannot run under jit; "
            "pre-shard with pcilt.shard_shared_grouped_tables(...) — or "
            "convert_kernel(..., shared=True, mesh=...) — and pass the "
            "ShardedSharedPool instead")
    return shard_shared_grouped_tables(tables, n_shards)


def _pcilt_linear_sharded(x, tables, spec, scale, group, path, mesh,
                          mesh_axis, paired: bool = False) -> jax.Array:
    """Run one fetch-and-sum layer under ``shard_map`` over local G-shards.

    Each device executes the unsharded layer on its table shard and the
    matching slice of the reduction dim, then contributes its partial sum to
    the ``psum`` over ``mesh_axis`` — the one collective of the whole layer.
    ``check_vma=False``: Pallas calls carry no replication rule.
    """
    from repro import compat

    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])

    if isinstance(tables, ShardedSharedPool):
        def shard_fn(xl, pool_l, idx_l):
            local = SharedGroupedTables(pool=pool_l[0], seg_idx=idx_l[0],
                                        group=group)
            part = pcilt_linear(xl, local, spec, scale, group, path=path)
            return jax.lax.psum(part, mesh_axis)

        out = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(None, mesh_axis), P(mesh_axis), P(mesh_axis)),
            out_specs=P(), check_vma=False,
        )(flat, tables.pools, tables.seg_idx)
    else:
        def shard_fn(xl, tab_l):
            part = pcilt_linear(xl, tab_l, spec, scale, group, path=path,
                                paired=paired)
            return jax.lax.psum(part, mesh_axis)

        out = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(None, mesh_axis), P(mesh_axis, None, None)),
            out_specs=P(), check_vma=False,
        )(flat, tables)
    return out.reshape(*lead, out.shape[-1])


def _pcilt_linear_stacked_sharded(x, tables, layer, spec, scale, group,
                                  mesh, mesh_axis) -> jax.Array:
    """Layer-stacked fused GEMV under ``shard_map``: the ``[L, G, V, O]``
    stack shards on its *segment* axis (the same ``"table_seg"`` rule dense
    tables use, one position to the right), each device runs the stacked
    kernel over its resident ``[L, G/D, V, O]`` shard at the scan-carried
    layer index, and one ``psum`` per step combines the partial adder-tree
    sums — the stacked kernel's scalar-prefetch table staging survives the
    mesh unchanged because every shard's stack stays put in its own HBM.
    """
    from repro import compat
    from repro.kernels import ops  # local import: kernels are optional

    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    l1 = jnp.asarray(layer, jnp.int32).reshape(1)

    def shard_fn(xl, tab_l, lyr):
        part = ops.pcilt_fused_gemv_stacked(xl, tab_l, lyr[0], spec, scale,
                                            group)
        return jax.lax.psum(part, mesh_axis)

    out = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None, mesh_axis), P(None, mesh_axis, None, None), P()),
        out_specs=P(), check_vma=False,
    )(flat, tables, l1)
    return out.reshape(*lead, out.shape[-1])


def _pcilt_linear_paired_stacked_sharded(x, tables, layer, spec, scale,
                                         group, mesh, mesh_axis) -> jax.Array:
    """Seg-major paired stack ``[G2, L, V2, O]`` under ``shard_map``: shards
    split the *pair* axis (axis 0 — the ``"table_seg"`` position for
    ``ndim=4, seg_axis=0`` in ``pcilt_table_sharding``), each device runs
    the paired stacked kernel over its resident ``[G2/D, L, V2, O]`` shard,
    and one ``psum`` per step combines the partial adder-tree sums."""
    from repro import compat
    from repro.kernels import ops  # local import: kernels are optional

    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    l1 = jnp.asarray(layer, jnp.int32).reshape(1)

    def shard_fn(xl, tab_l, lyr):
        part = ops.pcilt_fused_gemv_paired_stacked(xl, tab_l, lyr[0], spec,
                                                   scale, group)
        return jax.lax.psum(part, mesh_axis)

    out = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(None, mesh_axis), P(mesh_axis, None, None, None), P()),
        out_specs=P(), check_vma=False,
    )(flat, tables, l1)
    return out.reshape(*lead, out.shape[-1])


def _pcilt_linear_paired(x, tables, spec, scale, group, path, mesh,
                         mesh_axis, stacked, return_stats=False):
    """The paired (TL1-style multi-scalar) routes of :func:`pcilt_linear`.

    ``tables`` is a paired ``[G2, V2, out]`` array
    (``build_paired_tables``) or, with ``stacked=``, the **segment-major**
    ``[G2, L, V2, out]`` stack (``build_paired_stacked_tables``).  ``x`` is
    zero-padded over the odd-``G`` phantom segment here, once, before any
    shard split — so mesh divisibility is judged on the padded layout.
    The host-packed reference paths fall out for free: a paired table *is*
    a grouped table at width ``2*group``, so ``gather``/``onehot``/
    ``kernel`` recurse into the dense layer with the doubled group.
    """
    pair = 2 * group
    if stacked is not None:
        if tables.ndim != 4:
            raise ValueError(
                f"paired stacked= expects seg-major [G2, L, V2, O] tables "
                f"(build_paired_stacked_tables), got shape {tables.shape}")
        G2, L, V2, O = tables.shape
        x = _pad_paired_phantom(x, G2, group)
        if path == "fused":
            if mesh_shard_count(mesh, mesh_axis, G2) > 1:
                out = _pcilt_linear_paired_stacked_sharded(
                    x, tables, stacked, spec, scale, group, mesh, mesh_axis)
                if return_stats:
                    _, count, ratio = quantize_with_stats(x, spec, scale)
                    return out, count, ratio
                return out
            from repro.kernels import ops  # local import: kernels optional

            flat = x.reshape(-1, x.shape[-1])
            if return_stats:
                out, count, ratio = ops.pcilt_fused_gemv_paired_stacked(
                    flat, tables, stacked, spec, scale, group,
                    with_stats=True)
                return out.reshape(*x.shape[:-1], O), count, ratio
            out = ops.pcilt_fused_gemv_paired_stacked(
                flat, tables, stacked, spec, scale, group)
            return out.reshape(*x.shape[:-1], O)
        # Reference / host-packed baseline: slice the layer out of the
        # seg-major stack (axis 1) and run it as a dense grouped table at
        # the doubled group width.
        tab_l = jax.lax.dynamic_index_in_dim(
            tables, jnp.asarray(stacked, jnp.int32), 1, keepdims=False)
        return pcilt_linear(x, tab_l, spec, scale, pair, path=path,
                            return_stats=return_stats)
    if tables.ndim != 3:
        raise ValueError(
            f"paired tables are [G2, V2, O] (build_paired_tables), got "
            f"shape {tables.shape}")
    G2, V2, O = tables.shape
    x = _pad_paired_phantom(x, G2, group)
    if path == "fused":
        if mesh_shard_count(mesh, mesh_axis, G2) > 1:
            out = _pcilt_linear_sharded(x, tables, spec, scale, group,
                                        path, mesh, mesh_axis, paired=True)
            if return_stats:
                _, count, ratio = quantize_with_stats(x, spec, scale)
                return out, count, ratio
            return out
        from repro.kernels import ops  # local import: kernels are optional

        flat = x.reshape(-1, x.shape[-1])
        if return_stats:
            out, count, ratio = ops.pcilt_fused_gemv_paired(
                flat, tables, spec, scale, group, with_stats=True)
            return out.reshape(*x.shape[:-1], O), count, ratio
        out = ops.pcilt_fused_gemv_paired(flat, tables, spec, scale, group)
        return out.reshape(*x.shape[:-1], O)
    # gather/onehot/kernel reference (and their sharded forms): a paired
    # table is exactly a grouped table of width 2*group.
    return pcilt_linear(x, tables, spec, scale, pair, path=path, mesh=mesh,
                        mesh_axis=mesh_axis, return_stats=return_stats)


def pcilt_linear(
    x: jax.Array,
    tables,
    spec: QuantSpec,
    scale,
    group: int,
    plan: Optional[SegmentPlan] = None,
    path: str = "gather",
    mesh=None,
    mesh_axis: str = "model",
    stacked=None,
    paired: bool = False,
    return_stats: bool = False,
):
    """Quantize -> pack offsets -> fetch -> sum.   ``x: [..., n] -> [..., out]``.

    ``tables`` is the dense grouped ``[G, V, out]`` array, a
    ``SharedGroupedTables`` pool (required for ``path="shared"``; also
    accepted on ``path="gather"`` for the pointer-gather reference), or a
    pre-sharded ``ShardedSharedPool`` (mesh execution only).

    With ``stacked=`` (a possibly-traced integer layer index), ``tables``
    is a layer-stacked dense ``[L, G, V, out]`` array holding every layer's
    tables of a scanned network, and the call executes layer ``stacked``:
    ``path="fused"`` runs the scalar-prefetch stacked kernel
    (``repro.kernels.pcilt_fused_gemv_stacked``) so the resident stack is
    tiled directly — the ``lax.scan`` carrying the index never copies a
    ``[G, V, out]`` slice through HBM — while the host-packed reference
    paths (``gather``/``onehot``/``kernel``) slice the layer explicitly
    (paying exactly that copy; they exist for parity and as the baseline
    the stacked kernel is benchmarked against).

    With ``paired=True``, ``tables`` is the TL1-style multi-scalar layout:
    ``[G2, V2, out]`` from ``build_paired_tables`` (or, stacked, the
    segment-major ``[G2, L, V2, out]`` from ``build_paired_stacked_tables``)
    where each fetch covers **two** adjacent ``group``-wide segments.  ``x``
    keeps the *unpaired* layout — the layer zero-pads the odd-``G`` phantom
    segment itself — and ``group`` stays the unpaired width.
    ``path="fused"`` runs the row-gather paired kernels (halved fetch count
    and adder-tree depth); the host-packed paths execute the paired table
    as a dense grouped table of width ``2*group``.  Mesh execution shards
    the pair axis (``pcilt_table_sharding(..., ndim=4, seg_axis=0)`` for
    the seg-major stack).

    A generalized ``SegmentPlan`` with ``path="fused"`` executes via the
    plan-gather kernel (``pcilt_fused_gemv_plan``): the plan index is
    gathered in VMEM before the standard quantize→pack→fetch, so plan-built
    tables no longer fall back to the host-packed paths.

    A scalar-level :class:`~repro.core.pcilt.SharedTables` (per-unique-value
    pool) is accepted on ``path="shared"``/``"gather"``: it is re-expressed
    as its 1-wide segment pool (``SharedTables.as_grouped_pool``) and runs
    the fused shared kernel / pointer-gather — ``materialize()`` is never
    called.

    With ``mesh=``, the segment axis is sharded over ``mesh_axis`` and the
    partial sums are ``psum``-combined (see the module docstring); without a
    mesh — or when the axis does not divide ``G`` — execution is the
    single-device reference.  A generalized ``SegmentPlan`` cannot shard
    (its positions are arbitrary): combining ``plan=`` with a mesh that
    would shard raises rather than silently replicating.

    With ``return_stats=True`` the call returns ``(out, count, ratio)``:
    the saturation statistics of the quantizer feeding the fetch —
    ``count`` (int32) elements whose pre-clip code left ``[0, K)`` and
    ``ratio`` (f32) ``max(|x|)/scale`` — exactly
    :func:`~repro.core.quantization.quantize_with_stats`'s definition.
    ``out`` is bit-identical to the ``return_stats=False`` result.  On the
    unsharded fused stacked/paired routes the counters are reduced inside
    the fetch kernel's grid (no second pass over ``x``); every other route
    derives the same stats host-side.  Zero-padding (group alignment,
    paired phantom segments) never perturbs the stats: padded slots
    quantize to ``zero_point``, which is in range.
    """
    if isinstance(tables, SharedTables):
        if paired:
            raise ValueError(
                "paired tables are dense [G2, V2, O] arrays; scalar-level "
                "SharedTables pools have no paired layout")
        # Scalar-level ext.-3: each weight position is a 1-wide segment over
        # the deduped pointer-row pool; group becomes the pool's (1).
        tables = tables.as_grouped_pool()
        group = tables.group
    if paired:
        if plan is not None:
            raise ValueError(
                "paired tables pack adjacent contiguous segment pairs; "
                "generalized SegmentPlans cannot pair — drop plan= or use "
                "the unpaired paths")
        if isinstance(tables, (SharedGroupedTables, ShardedSharedPool)):
            raise ValueError(
                "paired=True consumes dense paired [G2, V2, O] tables "
                "(build_paired_tables); shared pools have no paired layout")
        if path == "shared":
            raise ValueError(
                "path='shared' has no paired variant; paired tables run "
                "path='fused' (row-gather kernels) or the host-packed "
                "reference paths")
        return _pcilt_linear_paired(x, tables, spec, scale, group, path,
                                    mesh, mesh_axis, stacked,
                                    return_stats=return_stats)
    if stacked is not None:
        if isinstance(tables, (SharedGroupedTables, ShardedSharedPool)):
            raise ValueError(
                "stacked= executes layer-stacked dense [L, G, V, O] tables; "
                "shared pools have no stacked path — materialize() per layer "
                "or use the unstacked shared layer")
        if tables.ndim != 4:
            raise ValueError(
                f"stacked= expects [L, G, V, O] tables, got shape "
                f"{tables.shape}")
        if plan is not None:
            raise ValueError(
                "stacked= packs contiguous segments (the tables of every "
                "layer share one segment grid); generalized SegmentPlans "
                "cannot ride the layer stack — drop plan= or slice the "
                "layer's tables and use the unstacked paths")
        L, G, V, O = tables.shape
        if path == "fused":
            _check_contiguous_segments(path, None, x.shape[-1], G, group)
            if mesh_shard_count(mesh, mesh_axis, G) > 1:
                out = _pcilt_linear_stacked_sharded(
                    x, tables, stacked, spec, scale, group, mesh, mesh_axis)
                if return_stats:
                    _, count, ratio = quantize_with_stats(x, spec, scale)
                    return out, count, ratio
                return out
            from repro.kernels import ops  # local import: kernels optional

            flat = x.reshape(-1, x.shape[-1])
            if return_stats:
                out, count, ratio = ops.pcilt_fused_gemv_stacked(
                    flat, tables, stacked, spec, scale, group,
                    with_stats=True)
                return out.reshape(*x.shape[:-1], O), count, ratio
            out = ops.pcilt_fused_gemv_stacked(flat, tables, stacked, spec,
                                               scale, group)
            return out.reshape(*x.shape[:-1], O)
        # Reference / host-packed baseline: slice the layer (the HBM copy
        # the stacked fused kernel exists to avoid) and fall through.
        tables = jax.lax.dynamic_index_in_dim(
            tables, jnp.asarray(stacked, jnp.int32), 0, keepdims=False)
    if return_stats:
        # Counter-less routes (host-packed references, shared pools, plans,
        # unstacked fused, sharded fallbacks): identical stats, computed
        # host-side from the same pre-clip codes (XLA drops the duplicate
        # quantize against the fetch's own).
        _, count, ratio = quantize_with_stats(x, spec, scale)
        out = pcilt_linear(x, tables, spec, scale, group, plan=plan,
                           path=path, mesh=mesh, mesh_axis=mesh_axis)
        return out, count, ratio
    shared = tables if isinstance(tables, SharedGroupedTables) else None
    if isinstance(tables, ShardedSharedPool):
        if path not in ("shared", "gather"):
            raise ValueError(
                f"a ShardedSharedPool executes path='shared' or 'gather', "
                f"not {path!r}")
        if plan is not None:
            raise ValueError(
                "a ShardedSharedPool was built over contiguous segment "
                "blocks; generalized SegmentPlans cannot execute on sharded "
                "pools — use the unsharded SharedGroupedTables with a "
                "host-packed path instead")
        if x.shape[-1] != tables.n_segments * group:
            raise ValueError(
                f"x trailing dim {x.shape[-1]} != G*group = "
                f"{tables.n_segments}*{group} = {tables.n_segments * group} "
                f"for this ShardedSharedPool")
        if mesh is None or mesh_axis not in mesh.axis_names:
            raise ValueError(
                "a ShardedSharedPool is a mesh operand; pass mesh= (and the "
                "mesh_axis its pools were sharded for), or execute the "
                "unsharded SharedGroupedTables instead")
        if int(mesh.shape[mesh_axis]) != tables.n_shards:
            raise ValueError(
                f"ShardedSharedPool was built for {tables.n_shards} shards "
                f"but mesh axis {mesh_axis!r} has size "
                f"{int(mesh.shape[mesh_axis])}; rebuild with "
                f"shard_shared_grouped_tables(st, {int(mesh.shape[mesh_axis])})")
        return _pcilt_linear_sharded(x, tables, spec, scale, group, path,
                                     mesh, mesh_axis)

    n_segments = shared.n_segments if shared is not None else (
        tables.shape[0] if path in ("fused", "shared") else None)
    if path == "shared" and shared is None:
        raise ValueError(
            "path='shared' executes a SharedGroupedTables pool; build one "
            "with build_shared_grouped_tables (got dense tables)")
    if path == "fused" and shared is not None:
        raise ValueError(
            "path='fused' consumes dense [G, V, O] tables; use "
            "path='shared' for a SharedGroupedTables pool (or "
            "materialize() it explicitly)")
    if path == "fused" and plan is not None:
        # Generalized plans run fused via the in-VMEM plan gather — only
        # validate that the plan and tables agree on the segment grid.
        if plan.n_segments != n_segments or plan.group != group:
            raise ValueError(
                f"plan grid [{plan.n_segments}, {plan.group}] does not match "
                f"tables' [{n_segments}, {group}] — tables must be built "
                f"from plan.gather_weights(...)")
    elif path in ("fused", "shared"):
        _check_contiguous_segments(path, plan, x.shape[-1], n_segments, group)

    D = mesh_shard_count(mesh, mesh_axis,
                         shared.n_segments if shared is not None
                         else tables.shape[0])
    if D > 1 and plan is not None:
        # Refuse rather than silently replicate: a generalized plan maps
        # positions arbitrarily, so it cannot shard along contiguous
        # G-blocks — and a silent fallback would keep full per-device table
        # residency exactly where the caller asked for sharding.
        raise ValueError(
            "mesh execution shards contiguous segment blocks; a generalized "
            "SegmentPlan cannot be sharded — pass mesh=None to execute the "
            "plan replicated")
    if D > 1:
        if shared is not None:
            if path not in ("shared", "gather"):
                raise ValueError(
                    f"SharedGroupedTables executes path='shared' or "
                    f"'gather', not {path!r}")
            tables = _shard_pool_for(shared, D)
        return _pcilt_linear_sharded(x, tables, spec, scale, group, path,
                                     mesh, mesh_axis)

    if path == "shared":
        from repro.kernels import ops  # local import: kernels are optional

        flat = x.reshape(-1, x.shape[-1])
        out = ops.pcilt_shared_gemv(flat, shared.pool, shared.seg_idx, spec,
                                    scale, shared.group)
        return out.reshape(*x.shape[:-1], shared.pool.shape[-1])
    if path == "fused":
        from repro.kernels import ops  # local import: kernels are optional

        G, _, O = tables.shape
        flat = x.reshape(-1, x.shape[-1])
        if plan is not None:
            out = ops.pcilt_fused_gemv_plan(
                flat, tables, jnp.asarray(plan.index, jnp.int32), spec,
                scale, group)
        else:
            out = ops.pcilt_fused_gemv(flat, tables, spec, scale, group)
        return out.reshape(*x.shape[:-1], O)
    codes = quantize(x, spec, scale)
    if plan is None:
        offsets = pack_offsets(codes, spec.bits, group)
    else:
        offsets = plan.pack(codes, spec.bits)
    if shared is not None:
        if path != "gather":
            raise ValueError(
                f"SharedGroupedTables executes path='shared' or 'gather', "
                f"not {path!r}")
        return shared.lookup(offsets)
    return lut_lookup(tables, offsets, path=path)


def im2col(
    x: jax.Array, kh: int, kw: int, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """NHWC ``[B,H,W,C] -> [B,Ho,Wo,kh*kw*C]`` patch extraction.

    "SAME" padding follows the XLA/``lax.conv_general_dilated`` convention:
    output extent ``ceil(size/stride)`` with ``pad_total`` split low-first as
    ``pad_total // 2`` — stride-aware, unlike the naive ``(k-1)//2``, which
    samples shifted windows at stride > 1 on non-congruent sizes.
    """
    pads = ((0, 0),) * 4
    if padding == "SAME":
        pads = conv_same_pads(x.shape[1], x.shape[2], kh, kw, stride)
    xp = jnp.pad(x, pads)
    B, H, W, C = xp.shape
    Ho = (H - kh) // stride + 1
    Wo = (W - kw) // stride + 1
    # Extract with a static double loop over the (small) kernel extent; XLA
    # fuses these slices.  Patch layout [kh, kw, C] flattened, matching the
    # filter flattening in pcilt_conv2d.
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                jax.lax.slice(
                    xp,
                    (0, i, j, 0),
                    (B, i + (Ho - 1) * stride + 1, j + (Wo - 1) * stride + 1, C),
                    (1, stride, stride, 1),
                )
            )
    return jnp.concatenate(cols, axis=-1).reshape(B, Ho, Wo, kh * kw * C)


def _pcilt_conv2d_sharded_kernel(x, tables, spec, scale, group, kh, kw,
                                 stride, padding, path, mesh, mesh_axis):
    """Fused/shared conv under a mesh: **in-VMEM im2col per shard**.

    Every device stages the full (replicated) activation image, its
    ``[G/D, V, O]`` table shard (or local ext.-3 pool), and the shard's
    global segment offset; the conv kernel rebuilds the patch in VMEM and
    slices exactly the columns its shard covers (``seg_offset`` /
    ``n_total`` on the kernel wrappers), so neither the float patch tensor
    nor any offset tensor is ever materialized in HBM — the host-im2col +
    sharded-GEMV detour this route replaces paid for both.  One ``psum``
    over ``mesh_axis`` combines the partial adder-tree sums, exactly like
    the sharded linear path.
    """
    from repro import compat
    from repro.kernels import ops  # local import: kernels are optional

    if isinstance(tables, ShardedSharedPool):
        n_seg, D = tables.n_segments, tables.n_shards
        if mesh is None or mesh_axis not in mesh.axis_names:
            raise ValueError(
                "a ShardedSharedPool is a mesh operand; pass mesh= (and the "
                "mesh_axis its pools were sharded for), or execute the "
                "unsharded SharedGroupedTables instead")
        if int(mesh.shape[mesh_axis]) != D:
            raise ValueError(
                f"ShardedSharedPool was built for {D} shards but mesh axis "
                f"{mesh_axis!r} has size {int(mesh.shape[mesh_axis])}; "
                f"rebuild with shard_shared_grouped_tables(st, "
                f"{int(mesh.shape[mesh_axis])})")
    else:
        n_seg = tables.shape[0]
        D = int(mesh.shape[mesh_axis])
    n_total = n_seg * group
    Gl = n_seg // D

    if path == "fused":
        def shard_fn(xl, tab_l):
            seg0 = jax.lax.axis_index(mesh_axis) * Gl
            part = ops.pcilt_fused_conv2d(
                xl, tab_l, spec, scale, group, kh, kw, stride=stride,
                padding=padding, seg_offset=seg0, n_total=n_total)
            return jax.lax.psum(part, mesh_axis)

        return compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(mesh_axis, None, None)),
            out_specs=P(), check_vma=False,
        )(x, tables)

    def shard_fn(xl, pool_l, idx_l):
        seg0 = jax.lax.axis_index(mesh_axis) * Gl
        part = ops.pcilt_shared_conv2d(
            xl, pool_l[0], idx_l[0], spec, scale, group, kh, kw,
            stride=stride, padding=padding, seg_offset=seg0, n_total=n_total)
        return jax.lax.psum(part, mesh_axis)

    return compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(mesh_axis), P(mesh_axis)),
        out_specs=P(), check_vma=False,
    )(x, tables.pools, tables.seg_idx)


def pcilt_conv2d(
    x: jax.Array,
    filters: jax.Array,
    spec: QuantSpec,
    scale,
    group: int,
    stride: int = 1,
    padding: str = "SAME",
    tables=None,
    path: str = "gather",
    mesh=None,
    mesh_axis: str = "model",
) -> jax.Array:
    """PCILT convolution, NHWC ``[B,H,W,Cin] -> [B,Ho,Wo,Cout]``.

    filters: ``[kh, kw, Cin, Cout]``.  Tables may be passed pre-built (the
    normal deployment: built once, reused for the network lifetime); when
    omitted they are built on the fly (tests / calibration) — as a
    segment-deduped ``SharedGroupedTables`` pool for ``path="shared"``,
    dense grouped tables otherwise.

    With ``mesh=`` the segment axis (the flattened ``kh*kw*Cin`` receptive
    field) is sharded over ``mesh_axis``.  The fused/shared paths keep
    their **in-VMEM im2col even under the mesh**: each device's conv kernel
    rebuilds the patch in VMEM and indexes its local table slice directly
    via the kernels' ``seg_offset`` parameter (one ``psum`` of partial
    sums).  Only the host-packed paths (``gather``/``onehot``/``kernel``),
    which consume explicit offset tensors, extract patches host-side
    (``im2col``) and route through the sharded linear layer.
    """
    kh, kw, cin, cout = filters.shape
    n = kh * kw * cin
    pad_n = (-n) % group
    wflat = filters.reshape(n, cout)
    if pad_n:
        wflat = jnp.concatenate([wflat, jnp.zeros((pad_n, cout), wflat.dtype)], 0)
    if tables is None:
        if path == "shared":
            from .pcilt import build_shared_grouped_tables

            tables = build_shared_grouped_tables(wflat, spec, scale, group)
        else:
            tables = build_grouped_tables(wflat, spec, scale, group)
    if isinstance(tables, (ShardedSharedPool, SharedGroupedTables)):
        n_seg = tables.n_segments
    else:
        n_seg = tables.shape[0]
    sharded = (isinstance(tables, ShardedSharedPool)
               or mesh_shard_count(mesh, mesh_axis, n_seg) > 1)
    if path == "shared" and not isinstance(
            tables, (SharedGroupedTables, ShardedSharedPool)):
        raise ValueError(
            "path='shared' executes a SharedGroupedTables pool; "
            "build one with build_shared_grouped_tables (got dense "
            "tables)")
    if path == "fused" and isinstance(
            tables, (SharedGroupedTables, ShardedSharedPool)):
        raise ValueError(
            "path='fused' consumes dense [G, V, O] tables; use "
            "path='shared' for a SharedGroupedTables pool (or "
            "materialize() it explicitly)")
    if path in ("fused", "shared"):
        _check_contiguous_segments(path, None, n + pad_n, n_seg, group)
        if sharded:
            if isinstance(tables, SharedGroupedTables):
                tables = _shard_pool_for(
                    tables, mesh_shard_count(mesh, mesh_axis, n_seg))
            return _pcilt_conv2d_sharded_kernel(
                x, tables, spec, scale, group, kh, kw, stride, padding,
                path, mesh, mesh_axis)
        # Single-device / fallback: the same conv-native kernels, unsharded.
        from repro.kernels import ops  # local import: kernels are optional

        if path == "shared":
            return ops.pcilt_shared_conv2d(
                x, tables.pool, tables.seg_idx, spec, scale, tables.group,
                kh, kw, stride=stride, padding=padding
            )
        return ops.pcilt_fused_conv2d(
            x, tables, spec, scale, group, kh, kw, stride=stride,
            padding=padding
        )
    patches = im2col(x, kh, kw, stride, padding)
    if pad_n:
        zeros = jnp.zeros((*patches.shape[:-1], pad_n), patches.dtype)
        patches = jnp.concatenate([patches, zeros], axis=-1)
    return pcilt_linear(patches, tables, spec, scale, group, path=path,
                        mesh=mesh, mesh_axis=mesh_axis)


def _dwconv_pads(k: int, padding: str):
    try:
        return {"CAUSAL": (k - 1, 0),
                "SAME": ((k - 1) // 2, k - 1 - (k - 1) // 2),
                "VALID": (0, 0)}[padding]
    except KeyError:
        raise ValueError(
            f"padding must be CAUSAL|SAME|VALID, got {padding!r}") from None


def build_dwconv_tables(filters: jax.Array, spec: QuantSpec, scale) -> jax.Array:
    """Per-channel depthwise-conv1d PCILTs: ``[k, C]`` filters -> ``[C, V]``.

    Segment slot ``j`` corresponds to tap ``j`` (slot ``j`` of the packed
    offset holds the code at time ``t-k+1+j`` ⇒ weight ``filters[j]``).
    Offline, once per network lifetime — serving callers
    (``serving.PCILTDwConv1d``) cache the result instead of rebuilding the
    ``V``-entry enumeration on every step.
    """
    from .offsets import offset_grid
    from .quantization import code_values

    k, _ = filters.shape
    vals = code_values(spec, scale)[offset_grid(spec.bits, k)]  # [V, k]
    return jnp.einsum("vk,kc->cv", vals, filters.astype(vals.dtype))


def pcilt_depthwise_conv1d(
    x: jax.Array,
    filters: jax.Array,
    spec: QuantSpec,
    scale,
    tables: Optional[jax.Array] = None,
    path: str = "gather",
    padding: str = "CAUSAL",
    return_stats: bool = False,
):
    """Depthwise conv1d where *one fetch produces one output element*.

    x: ``[B, T, C]``; filters: ``[k, C]`` (k taps per channel).  The k taps of
    a channel form exactly one PCILT segment, so the packed offset of the k
    input codes addresses a ``[C, K**k]`` table directly — the cleanest TPU
    incarnation of the paper's claim that small filters over large data are
    the technique's sweet spot (Mamba/Zamba frontends: k=4).

    ``padding``: ``"CAUSAL"`` (default — taps ``t-k+1..t``, the decode
    frontend), ``"SAME"`` (centered), or ``"VALID"`` (``T - k + 1``
    outputs).  ``path="fused"`` executes quantize + tap-stack + pack + fetch
    in one Pallas call (``repro.kernels.pcilt_fused_dwconv1d``) so the
    ``[B, T, C]`` offset tensor never exists in HBM; the host-packed paths
    (``gather``/``onehot``/``kernel``) build it explicitly.

    ``return_stats=True`` additionally returns the saturation ``(count,
    ratio)`` of the quantized signal (the :func:`quantize_with_stats`
    definition over the full ``[B, T, C]`` input — causal/SAME pad zeros
    quantize in range, so the count is the same for every padding mode).
    The fused path reduces the counters inside the kernel grid; the
    host-packed paths reuse the codes they quantize anyway.
    """
    k, C = filters.shape
    B, T, _ = x.shape
    if tables is None:
        tables = build_dwconv_tables(filters, spec, scale)
    if path == "fused":
        from repro.kernels import ops  # local import: kernels are optional

        if return_stats:
            return ops.pcilt_fused_dwconv1d(x, tables, spec, scale, k,
                                            padding=padding, with_stats=True)
        return ops.pcilt_fused_dwconv1d(x, tables, spec, scale, k,
                                        padding=padding)
    if return_stats:
        codes, count, ratio = quantize_with_stats(x, spec, scale)  # [B,T,C]
    else:
        codes = quantize(x, spec, scale)  # [B, T, C]
    lo, hi = _dwconv_pads(k, padding)
    padded = jnp.pad(codes, ((0, 0), (lo, hi), (0, 0)))
    To = padded.shape[1] - k + 1
    # Tap window: stack codes feeding output t  ->  [B, To, C, k]
    taps = jnp.stack([padded[:, i : i + To] for i in range(k)], axis=-1)
    shifts = jnp.arange(k, dtype=jnp.int32) * spec.bits
    offsets = jnp.sum(
        jnp.left_shift(taps.astype(jnp.int32), shifts[None, None, None]), axis=-1
    )  # [B, To, C]
    if path == "gather":
        out = jnp.take_along_axis(
            jnp.broadcast_to(tables, (B, To) + tables.shape),
            offsets[..., None],
            axis=-1,
        )[..., 0]
    elif path == "onehot":
        V = tables.shape[-1]
        oh = jax.nn.one_hot(offsets, V, dtype=tables.dtype)  # [B,To,C,V]
        out = jnp.einsum("btcv,cv->btc", oh, tables)
    elif path == "kernel":
        from repro.kernels import ops

        out = ops.pcilt_dwconv1d(offsets, tables)
    else:
        raise ValueError(f"unknown path {path!r}")
    if return_stats:
        return out, count, ratio
    return out
