"""PCILT serving-mode conversion for LM decode paths.

Implements the paper's deployment story for the framework's language models:
an *offline* table build ("done only once in the lifetime of a CNN") that
converts selected projection kernels into grouped PCILTs, plus the decode
helpers that execute them via the fetch paths.  Used by
``examples/serve_pcilt.py`` and the integration tests; the per-architecture
table-memory accounting (the paper's own feasibility analysis applied to the
10 assigned archs) is in ``benchmarks/paper_claims.py``.

Scoping (DESIGN.md §6): tables address the *decode GEMV* regime — batch-
starved, memory-bound — and the conv frontends.  Weight-side cardinality is
reduced by weight quantization first (paper: tables exist per distinct weight
value; shared-PCILT keeps memory feasible).
"""

from __future__ import annotations

import logging
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .quantization import (QuantSpec, calibrate, fake_quant, quantize,
                           dequantize, scale_from_amax)
from .pcilt import (SharedGroupedTables, ShardedSharedPool,
                    build_grouped_tables, build_shared_grouped_tables,
                    shard_shared_grouped_tables, stacked_checksums,
                    table_checksum)
from .lut_layers import (build_dwconv_tables, mesh_shard_count, pcilt_conv2d,
                         pcilt_depthwise_conv1d, pcilt_linear)

log = logging.getLogger("repro.serving")

__all__ = ["PCILTLinear", "PCILTConv2d", "PCILTDwConv1d", "convert_kernel",
           "convert_conv_kernel", "convert_dwconv", "convert_mamba_decode",
           "PCILTMambaDecode", "HealthMonitor", "pcilt_integrity",
           "pcilt_apply", "mlp_table_bytes"]


def pcilt_integrity(pcilt: Dict) -> Dict:
    """Conversion-time CRC-32 record of every table array in a Mamba PCILT
    bundle — per layer for the stacked arrays, so verification localizes a
    breach to the layer the health monitor must demote.  CRC-32 detects all
    error bursts of <= 32 bits: a single flipped table entry (f32/bf16
    value, int32 pointer) can never slip through."""
    integ: Dict[str, Any] = {"conv": stacked_checksums(pcilt["tables"])}
    proj = pcilt.get("proj")
    if proj is not None:
        # paired bundles stack seg-major ([G/2, L, V^2, O]) so the fused
        # kernel's table blocks are contiguous; the layer axis is axis 1
        axis = 1 if proj.get("paired") else 0
        integ["proj"] = {name: stacked_checksums(t, axis=axis)
                        for name, t in proj["tables"].items()}
    head = pcilt.get("head")
    if head is not None:
        integ["head"] = {"pool": table_checksum(head["pool"]),
                         "seg_idx": table_checksum(head["seg_idx"])}
    return integ


def _place_sharded_pool(sp: ShardedSharedPool, mesh,
                        mesh_axis: str) -> ShardedSharedPool:
    """Park each local pool + pointer block on its device (the whole point
    is that no device ever holds the global pool)."""
    from repro.nn.module import pcilt_table_sharding

    return ShardedSharedPool(
        pools=jax.device_put(
            sp.pools, pcilt_table_sharding(mesh, sp.n_shards, ndim=4,
                                           mesh_axis=mesh_axis)),
        seg_idx=jax.device_put(
            sp.seg_idx, pcilt_table_sharding(mesh, sp.n_shards, ndim=2,
                                             mesh_axis=mesh_axis)),
        group=sp.group, shard_cards=sp.shard_cards)


class PCILTLinear:
    """A converted projection: grouped tables + activation quantizer.

    ``path="fused"`` executes the whole quantize→pack→fetch pipeline in one
    Pallas call (``repro.kernels.pcilt_fused``); ``path="shared"`` does the
    same over an extension-3 segment-deduped pool (``repro.kernels.
    pcilt_shared``) — the configuration that keeps table memory feasible for
    real LM projections.  All kernel paths dispatch tile shapes through the
    persistent autotune lookup table.  Call :meth:`tune` once per decode
    shape at serving warmup to populate it — every later dispatch (this
    process or the next) is a pure cache hit.

    Exactly one table representation needs to exist: dense ``tables``
    (``[G, V, O]``) and/or a ``shared`` pool.  A shared-only instance (the
    memory-feasible deployment) executes ``path="gather"`` and
    ``path="shared"``; dense-only instances execute everything else.

    With ``mesh=``, the layer is tensor-parallel: dense tables are placed
    under ``PartitionSpec(mesh_axis, None, None)`` (each device holds the
    ``[G/D, V, O]`` shard), a shared pool is pre-sharded into a
    ``ShardedSharedPool`` (per-device memory scales with the *local* pool
    cardinality), every ``__call__`` runs the fetch under ``shard_map`` with
    one ``psum`` of the partial adder-tree sums, and :meth:`tune` keys the
    autotune cache on the **local shard shape** — the shape the kernels
    actually see per device.  When ``mesh_axis`` does not divide ``G`` the
    layer falls back to replicated execution (divisibility fallback).
    """

    def __init__(self, tables: Optional[jax.Array], spec: QuantSpec,
                 scale: jax.Array, group: int,
                 shared: Optional[SharedGroupedTables] = None,
                 mesh=None, mesh_axis: str = "model"):
        if tables is None and shared is None:
            raise ValueError("PCILTLinear needs dense tables, a shared pool, "
                             "or both")
        self.tables = tables
        self.spec = spec
        self.scale = scale
        self.group = group
        self.shared = shared
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        # conversion-time integrity record (pre-placement bytes; device_put
        # moves, never rewrites) — verified on demand by verify_integrity
        self.integrity: Dict[str, int] = {}
        if tables is not None:
            self.integrity["tables"] = table_checksum(tables)
        if shared is not None:
            self.integrity["pool"] = table_checksum(shared.pool)
            self.integrity["seg_idx"] = table_checksum(shared.seg_idx)
        self.shard_pools: Optional[ShardedSharedPool] = None
        if mesh is not None and self.shard_count > 1:
            if shared is not None:
                self.shard_pools = shard_shared_grouped_tables(
                    shared, self.shard_count)
                self._place_shard_pools()
            if tables is not None:
                # Park each [G/D, V, O] shard on its device now — the whole
                # point is that no device ever holds the global tables.
                from repro.nn.module import pcilt_table_sharding

                self.tables = jax.device_put(
                    tables, pcilt_table_sharding(mesh, tables.shape[0],
                                                 mesh_axis=mesh_axis))

    def _place_shard_pools(self) -> None:
        self.shard_pools = _place_sharded_pool(self.shard_pools, self.mesh,
                                               self.mesh_axis)

    @property
    def n_segments(self) -> int:
        if self.tables is not None:
            return self.tables.shape[0]
        return self.shared.n_segments

    @property
    def shard_count(self) -> int:
        """Effective G-shards on the layer's mesh (1 = replicated fallback)."""
        return mesh_shard_count(self.mesh, self.mesh_axis, self.n_segments)

    def table_bytes(self) -> int:
        """Bytes of the representation this layer would deploy (the shared
        pool when present — the paper's ext.-3 memory argument)."""
        if self.shared is not None:
            return self.shared.pool_bytes()
        return self.tables.size * self.tables.dtype.itemsize

    def per_device_table_bytes(self) -> int:
        """Table bytes each device holds under the layer's mesh.

        Dense tables shard exactly linearly (``G/D`` segments per device);
        shared layers stage the padded local pool.  Replicated layers (no
        mesh / fallback) hold everything everywhere.
        """
        if self.shard_pools is not None:
            return self.shard_pools.local_pool_bytes()
        return -(-self.table_bytes() // self.shard_count)

    def verify_integrity(self) -> Dict[str, bool]:
        """Recompute each held table's checksum against the conversion-time
        record; ``False`` marks a corrupted representation."""
        cur = {}
        if self.tables is not None:
            cur["tables"] = table_checksum(self.tables)
        if self.shared is not None:
            cur["pool"] = table_checksum(self.shared.pool)
            cur["seg_idx"] = table_checksum(self.shared.seg_idx)
        return {k: cur[k] == v for k, v in self.integrity.items()}

    def _pad_x(self, x: jax.Array) -> jax.Array:
        n = self.n_segments * self.group
        pad = n - x.shape[-1]
        if pad:
            x = jnp.concatenate([x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], -1)
        return x

    def _tables_for(self, path: str):
        if path == "shared" or (self.tables is None and path == "gather"):
            if self.shared is None:
                raise ValueError(
                    "no shared pool on this layer; convert with shared=True")
            return self.shard_pools if self.shard_pools is not None else self.shared
        if self.tables is None:
            raise ValueError(
                f"shared-only PCILTLinear executes path='shared' or 'gather', "
                f"not {path!r}")
        return self.tables

    def __call__(self, x: jax.Array, path: str = "gather") -> jax.Array:
        return pcilt_linear(self._pad_x(x), self._tables_for(path), self.spec,
                            self.scale, self.group, path=path,
                            mesh=self.mesh, mesh_axis=self.mesh_axis)

    def tune(self, x: jax.Array) -> jax.Array:
        """Eagerly autotune the fused kernel for this decode shape and record
        the winner in the persistent lookup table; returns the output.
        Shared-only layers tune the shared-pool kernel.

        Under a mesh, tuning runs on the **local shard shape** — one shard's
        ``[G/D, V, O]`` tables (or local pool) against the matching slice of
        the reduction dim — because that is the problem each device's kernel
        dispatches, and the shape key the sharded ``shard_map`` execution
        looks up at trace time.  Caches tuned at different device counts
        therefore occupy different keys and never collide.
        """
        from repro.kernels import ops  # local import: kernels are optional

        x = self._pad_x(x)
        flat = x.reshape(-1, x.shape[-1])
        D = self.shard_count
        if D > 1:
            Gl = self.n_segments // D
            xl = flat[:, : Gl * self.group]
            if self.tables is None:
                sp = self.shard_pools
                ops.pcilt_shared_gemv(xl, sp.pools[0], sp.seg_idx[0],
                                      self.spec, self.scale, self.group,
                                      autotune=True)
                return self(x, path="shared")
            ops.pcilt_fused_gemv(xl, self.tables[:Gl], self.spec, self.scale,
                                 self.group, autotune=True)
            return self(x, path="fused")
        if self.tables is None:
            out = ops.pcilt_shared_gemv(
                flat, self.shared.pool, self.shared.seg_idx, self.spec,
                self.scale, self.group, autotune=True)
        else:
            out = ops.pcilt_fused_gemv(flat, self.tables, self.spec,
                                       self.scale, self.group, autotune=True)
        return out.reshape(*x.shape[:-1], out.shape[-1])


def convert_kernel(kernel: jax.Array, act_spec: QuantSpec, act_scale,
                   group: int, weight_bits: Optional[int] = None,
                   shared: bool = False, mesh=None,
                   mesh_axis: str = "model") -> PCILTLinear:
    """Offline build for one [d_in, d_out] kernel.

    weight_bits: optionally quantize weights first (lowers table value
    diversity, the precondition for shared-PCILT dedup, ext. 3).
    shared: build the extension-3 segment-deduped pool *instead of* the dense
    tables — the layer then executes ``path="shared"`` (fused kernel) and
    ``path="gather"`` (pointer-gather reference), and its table memory scales
    with the weights' actual segment cardinality.  Usually combined with
    ``weight_bits`` (or otherwise weight-clustered kernels): dedup only bites
    when whole ``[group, d_out]`` segments repeat.
    mesh: build a tensor-parallel layer — tables are sharded on the segment
    axis over ``mesh_axis`` at conversion time (shared pools become per-shard
    local pools) and every call executes under ``shard_map`` with a psum of
    the partial sums.  Conversion is the offline step, so the sharding is
    too."""
    k = kernel.astype(jnp.float32)
    if kernel.ndim > 2:
        k = k.reshape(kernel.shape[0], -1)
    if weight_bits:
        wspec = QuantSpec(bits=weight_bits, symmetric=True)
        wscale = calibrate(k, wspec)
        k = dequantize(quantize(k, wspec, wscale), wspec, wscale)
    n, out = k.shape
    pad = (-n) % group
    if pad:
        k = jnp.concatenate([k, jnp.zeros((pad, out), k.dtype)], 0)
    if shared:
        pool = build_shared_grouped_tables(k, act_spec, act_scale, group)
        return PCILTLinear(None, act_spec, act_scale, group, shared=pool,
                           mesh=mesh, mesh_axis=mesh_axis)
    tables = build_grouped_tables(k, act_spec, act_scale, group)
    return PCILTLinear(tables, act_spec, act_scale, group, mesh=mesh,
                       mesh_axis=mesh_axis)


class PCILTConv2d:
    """A converted convolution: pre-built grouped tables + a per-path jitted
    executor cache.

    Eager (non-jit) serving used to pay the whole host-side pre-processing on
    *every* call — ``conv_same_pads`` arithmetic, the ``[kh*kw*Cin, Cout]``
    filter flatten/pad, and (worst) a full table rebuild when no tables were
    passed.  Conversion hoists all of it to the offline build (the paper's
    once-per-lifetime step), and ``__call__`` dispatches through one jitted
    closure per path — so repeated decode steps re-enter compiled code
    instead of re-tracing the quantize/pack/fetch pipeline each time.

    With ``mesh=``, calls execute the tensor-parallel conv route: the
    fused/shared kernels keep their in-VMEM im2col per shard via the
    kernels' ``seg_offset`` parameter (``core.lut_layers``), dense table
    shards are placed at conversion like :class:`PCILTLinear`.
    """

    def __init__(self, filters: jax.Array, spec: QuantSpec, scale, group: int,
                 stride: int = 1, padding: str = "SAME",
                 tables=None, shared: Optional[SharedGroupedTables] = None,
                 mesh=None, mesh_axis: str = "model"):
        if tables is None and shared is None:
            raise ValueError("PCILTConv2d needs dense tables, a shared pool, "
                             "or both")
        self.filters = filters
        self.spec = spec
        self.scale = scale
        self.group = group
        self.stride = stride
        self.padding = padding
        self.tables = tables
        self.shared = shared
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.shard_pools: Optional[ShardedSharedPool] = None
        if mesh is not None and self.shard_count > 1:
            # Shard and place at conversion (the offline step), exactly like
            # PCILTLinear: no device ever holds the global tables/pool, and
            # the np.unique pool-shard build never re-runs inside a trace.
            if shared is not None:
                self.shard_pools = _place_sharded_pool(
                    shard_shared_grouped_tables(shared, self.shard_count),
                    mesh, mesh_axis)
            if tables is not None:
                from repro.nn.module import pcilt_table_sharding

                self.tables = jax.device_put(
                    tables, pcilt_table_sharding(mesh, tables.shape[0],
                                                 mesh_axis=mesh_axis))
        self._exec: Dict[str, object] = {}

    @property
    def n_segments(self) -> int:
        if self.tables is not None:
            return self.tables.shape[0]
        return self.shared.n_segments

    @property
    def shard_count(self) -> int:
        """Effective G-shards on the layer's mesh (1 = replicated fallback)."""
        return mesh_shard_count(self.mesh, self.mesh_axis, self.n_segments)

    def _tables_for(self, path: str):
        if path == "shared" or (self.tables is None and path == "gather"):
            if self.shared is None:
                raise ValueError(
                    "no shared pool on this layer; convert with shared=True")
            return self.shard_pools if self.shard_pools is not None else self.shared
        if self.tables is None:
            raise ValueError(
                f"shared-only PCILTConv2d executes path='shared' or "
                f"'gather', not {path!r}")
        return self.tables

    def table_bytes(self) -> int:
        if self.shared is not None:
            return self.shared.pool_bytes()
        return self.tables.size * self.tables.dtype.itemsize

    def per_device_table_bytes(self) -> int:
        """Table bytes each device holds under the layer's mesh (the padded
        local pool for shared layers; linear ``G/D`` scaling for dense)."""
        if self.shard_pools is not None:
            return self.shard_pools.local_pool_bytes()
        return -(-self.table_bytes() // self.shard_count)

    def __call__(self, x: jax.Array, path: str = "fused") -> jax.Array:
        fn = self._exec.get(path)
        if fn is None:
            tables = self._tables_for(path)

            def run(xc):
                return pcilt_conv2d(
                    xc, self.filters, self.spec, self.scale, self.group,
                    stride=self.stride, padding=self.padding, tables=tables,
                    path=path, mesh=self.mesh, mesh_axis=self.mesh_axis)

            fn = self._exec[path] = jax.jit(run)
        return fn(x)

    def tune(self, x: jax.Array) -> jax.Array:
        """Eagerly autotune the conv kernel for this input shape and record
        the winner; shared-only layers tune the shared-pool kernel.  The
        jitted dispatch then hits the recorded entry at trace time.

        Under a mesh, tuning runs on the **local shard shape** — one shard's
        ``[G/D, V, O]`` tables (or local pool) with a concrete
        ``seg_offset`` — because that is the problem each device's kernel
        dispatches and the shape key the sharded ``shard_map`` trace looks
        up (same contract as :meth:`PCILTLinear.tune`)."""
        from repro.kernels import ops  # local import: kernels are optional

        kh, kw, _, _ = self.filters.shape
        conv_kw = dict(stride=self.stride, padding=self.padding,
                       autotune=True)
        D = self.shard_count
        if D > 1:
            G = self.n_segments
            n_total = G * self.group
            if self.tables is None:
                sp = self.shard_pools
                ops.pcilt_shared_conv2d(
                    x, sp.pools[0], sp.seg_idx[0], self.spec, self.scale,
                    self.group, kh, kw, seg_offset=0, n_total=n_total,
                    **conv_kw)
                return self(x, path="shared")
            ops.pcilt_fused_conv2d(
                x, self.tables[: G // D], self.spec, self.scale, self.group,
                kh, kw, seg_offset=0, n_total=n_total, **conv_kw)
            return self(x, path="fused")
        if self.tables is None:
            ops.pcilt_shared_conv2d(
                x, self.shared.pool, self.shared.seg_idx, self.spec,
                self.scale, self.group, kh, kw, **conv_kw)
            return self(x, path="shared")
        ops.pcilt_fused_conv2d(
            x, self.tables, self.spec, self.scale, self.group, kh, kw,
            **conv_kw)
        return self(x, path="fused")


def convert_conv_kernel(filters: jax.Array, act_spec: QuantSpec, act_scale,
                        group: int, stride: int = 1, padding: str = "SAME",
                        weight_bits: Optional[int] = None,
                        shared: bool = False, mesh=None,
                        mesh_axis: str = "model") -> PCILTConv2d:
    """Offline build for one ``[kh, kw, Cin, Cout]`` conv filter — the conv
    sibling of :func:`convert_kernel`.  Flattens/pads the receptive field to
    the segment grid once, builds dense grouped tables (or the ext.-3
    segment-deduped pool with ``shared=True``), and returns the serving
    layer with every per-call host cost hoisted out."""
    kh, kw, cin, cout = filters.shape
    f = filters.astype(jnp.float32)
    if weight_bits:
        wspec = QuantSpec(bits=weight_bits, symmetric=True)
        wscale = calibrate(f, wspec)
        f = dequantize(quantize(f, wspec, wscale), wspec, wscale)
    n = kh * kw * cin
    wflat = f.reshape(n, cout)
    pad = (-n) % group
    if pad:
        wflat = jnp.concatenate([wflat, jnp.zeros((pad, cout), wflat.dtype)], 0)
    if shared:
        pool = build_shared_grouped_tables(wflat, act_spec, act_scale, group)
        return PCILTConv2d(f, act_spec, act_scale, group, stride=stride,
                           padding=padding, shared=pool, mesh=mesh,
                           mesh_axis=mesh_axis)
    tables = build_grouped_tables(wflat, act_spec, act_scale, group)
    return PCILTConv2d(f, act_spec, act_scale, group, stride=stride,
                       padding=padding, tables=tables, mesh=mesh,
                       mesh_axis=mesh_axis)


class PCILTDwConv1d:
    """A converted depthwise-conv1d frontend (Mamba/Zamba conv, k=4): the
    ``[C, V]`` per-channel tables are built once at conversion and every call
    executes one fetch per output element.

    ``path="fused"`` runs quantize + causal tap-stack + pack + fetch in one
    Pallas call (``repro.kernels.pcilt_fused_dwconv1d``) — the decode
    frontend's offsets never exist in HBM; the host-packed paths remain for
    reference/parity.  :meth:`tune` records the ``(Tb, Cb)`` tiling under
    the ``fused_dwconv1d`` autotune key for this signal shape.
    """

    def __init__(self, filters: jax.Array, spec: QuantSpec, scale,
                 tables: Optional[jax.Array] = None):
        self.filters = filters
        self.spec = spec
        self.scale = scale
        self.k = int(filters.shape[0])
        self.tables = tables if tables is not None else build_dwconv_tables(
            filters, spec, scale)
        self._exec: Dict[tuple, object] = {}

    def table_bytes(self) -> int:
        return self.tables.size * self.tables.dtype.itemsize

    def __call__(self, x: jax.Array, path: str = "fused",
                 padding: str = "CAUSAL") -> jax.Array:
        fn = self._exec.get((path, padding))
        if fn is None:
            def run(xc):
                return pcilt_depthwise_conv1d(
                    xc, self.filters, self.spec, self.scale,
                    tables=self.tables, path=path, padding=padding)

            fn = self._exec[(path, padding)] = jax.jit(run)
        return fn(x)

    def tune(self, x: jax.Array, padding: str = "CAUSAL") -> jax.Array:
        from repro.kernels import ops  # local import: kernels are optional

        out = ops.pcilt_fused_dwconv1d(x, self.tables, self.spec, self.scale,
                                       self.k, padding=padding, autotune=True)
        return out


def convert_dwconv(filters: jax.Array, act_spec: QuantSpec,
                   act_scale) -> PCILTDwConv1d:
    """Offline build for one ``[k, C]`` depthwise-conv1d filter: per-channel
    ``[C, 2**(bits*k)]`` tables, built once (the per-call rebuild the eager
    path used to pay is exactly what this hoists)."""
    return PCILTDwConv1d(filters, act_spec, act_scale)


class PCILTMambaDecode:
    """A fully-converted Mamba decode path: the calibrated PCILT bundle
    (conv ``[L, C, V]`` tables + layer-stacked ``[L, G, V, O]`` projection
    tables) plus the **hoisted jitted step executor** — eager serving loops
    call one compiled function per token instead of re-tracing
    ``decode_step`` (and re-closing over the table stack) every step.

    Built by :func:`convert_mamba_decode`; ``step``/``__call__`` mirror
    ``MambaLM.decode_step(params, cache, tokens)``.  :meth:`tune` eagerly
    autotunes the stacked projection kernels for a decode batch shape and
    records the winners under ``fused_gemv_stacked`` keys (local-shard
    shapes under a mesh), so the jitted dispatch hits the lookup table at
    trace time.

    Integrity: the bundle carries a conversion-time CRC-32 record per table
    (per layer for the stacked arrays); it is verified at load
    (``verify=True``) and on demand (:meth:`verify_layer` /
    :meth:`verify_head` / :meth:`verify_integrity` — what the serving
    :class:`HealthMonitor` amortizes one layer per tick).  The step executor
    takes per-layer/head health masks as runtime *arguments* (defaulting to
    all-healthy), so demoting a layer to its dense oracle never retraces.
    """

    def __init__(self, model, pcilt: Dict, ctx=None, verify: bool = True):
        from repro.nn.layers import Ctx

        self.model = model
        self.pcilt = pcilt
        self.ctx = ctx if ctx is not None else Ctx()
        if "integrity" not in pcilt:
            pcilt["integrity"] = pcilt_integrity(pcilt)
        if verify:
            bad = self.verify_integrity()
            if bad:
                raise RuntimeError(
                    f"PCILT bundle failed integrity verification at load "
                    f"(corrupted tables): {bad}")
        self._hoist()

    def _hoist(self) -> None:
        # One jitted executor **per (decode batch, stats) pair**: the batch
        # dimension R is a first-class tuned axis of the stacked kernels
        # (``fused_gemv_stacked`` keys carry R), so an engine serving R=8
        # slots and a sibling serving R=32 dispatch distinct compiled steps
        # — each closing over the same resident table stack — instead of
        # sharing one retraced-on-shape-change function.  The stats flag is
        # a static trace property (counter outputs change the step's
        # result pytree), so monitored and unmonitored steps likewise hold
        # separate compiled executors.
        self._execs: Dict[Tuple[int, bool], object] = {}

    def executor(self, rows: int, stats: bool = False):
        """The hoisted jitted step for a decode batch of ``rows`` slots
        (built on first use, then cached — serving loops at a fixed slot
        count pay tracing exactly once).  ``stats=True`` builds the
        drift-monitored variant: the step additionally returns the
        per-layer saturation counters (``decode_step(with_stats=True)``)."""
        key = (rows, stats)
        f = self._execs.get(key)
        if f is None:
            f = jax.jit(
                lambda p, c, t, ok, hok: self.model.decode_step(
                    p, c, t, self.ctx, pcilt=self.pcilt, layer_ok=ok,
                    head_ok=hok, with_stats=stats))
            self._execs[key] = f
        return f

    def rehoist(self, verify: bool = False) -> None:
        """Rebuild the jitted executors after the bundle's table arrays were
        *replaced* (jit closes over the array values — swapping a dict entry
        has no effect on the compiled step until re-hoisted).  Drops every
        cached executor; each is rebuilt lazily on its next step.

        By default this does NOT re-verify integrity: detecting bad bytes at
        serving time is the health monitor's job, and the chaos suite
        exercises exactly that path.  ``verify=True`` opts in — the
        recalibration hot-swap path uses it so a rebuild whose re-recorded
        checksums don't match the freshly-swapped bytes fails loudly at the
        swap, not silently at some later amortized check."""
        if verify:
            bad = self.verify_integrity()
            if bad:
                raise RuntimeError(
                    f"PCILT bundle failed integrity verification at rehoist "
                    f"(corrupted tables): {bad}")
        self._hoist()

    def step(self, params, cache, tokens, layer_ok=None, head_ok=None,
             with_stats: bool = False):
        """One converted decode step: ``(logits, new_cache)`` — or, with
        ``with_stats=True``, ``(logits, new_cache, sat)`` where ``sat`` is
        the per-layer saturation-counter pytree of
        ``MambaLM.decode_step(with_stats=True)``.

        ``layer_ok`` (``[L]`` bool) / ``head_ok`` (bool) demote unhealthy
        layers' fetches (and the PCILT logits head) to their exact dense
        fake-quant oracles; both default to all-healthy."""
        if layer_ok is None:
            layer_ok = jnp.ones((self.model.cfg.n_layers,), bool)
        if head_ok is None:
            head_ok = jnp.asarray(True)
        fn = self.executor(int(tokens.shape[0]), stats=with_stats)
        return fn(params, cache, tokens, jnp.asarray(layer_ok, bool),
                  jnp.asarray(head_ok, bool))

    __call__ = step

    # -- integrity verification ----------------------------------------------

    def verify_layer(self, layer: int) -> List[Tuple]:
        """Checksum one layer's conv + projection table slices against the
        conversion-time record; returns the breached ``(name, layer)``
        sites (empty = clean)."""
        integ = self.pcilt["integrity"]
        bad: List[Tuple] = []
        if table_checksum(
                np.asarray(self.pcilt["tables"])[layer]) != integ["conv"][layer]:
            bad.append(("conv", int(layer)))
        proj = self.pcilt.get("proj")
        if proj is not None:
            for name, t in proj["tables"].items():
                sl = (np.asarray(t)[:, layer] if proj.get("paired")
                      else np.asarray(t)[layer])
                if table_checksum(sl) != integ["proj"][name][layer]:
                    bad.append((name, int(layer)))
        return bad

    def verify_head(self) -> List[Tuple]:
        """Checksum the shared-pool logits head (pool values + ``seg_idx``
        pointers); returns breached sites (empty = clean / no head)."""
        head = self.pcilt.get("head")
        if head is None:
            return []
        integ = self.pcilt["integrity"]["head"]
        bad: List[Tuple] = []
        if table_checksum(head["pool"]) != integ["pool"]:
            bad.append(("head.pool",))
        if table_checksum(head["seg_idx"]) != integ["seg_idx"]:
            bad.append(("head.seg_idx",))
        return bad

    def verify_integrity(self) -> List[Tuple]:
        """Full verification: every layer of every stacked table plus the
        head; returns all breached sites (what the monitor amortizes)."""
        L = self.pcilt["tables"].shape[0]
        bad: List[Tuple] = []
        for l in range(L):
            bad.extend(self.verify_layer(l))
        bad.extend(self.verify_head())
        return bad

    def table_bytes(self) -> int:
        """Total bytes of every table the converted decode deploys."""
        t = self.pcilt["tables"]
        total = t.size * t.dtype.itemsize
        proj = self.pcilt.get("proj")
        if proj is not None:
            total += sum(a.size * a.dtype.itemsize
                         for a in proj["tables"].values())
        return total

    def tune(self, batch=1) -> None:
        """Eagerly autotune each projection's stacked kernel at this decode
        batch size (layer 0 is representative: the per-layer staged slice is
        what the kernel tiles, and the shape key is layer-independent), plus
        the conv frontend's fused dwconv key on the assembled ``[B, k, C]``
        decode window.  Paired bundles tune the paired stacked kernel on the
        seg-major ``[G/2, L, V^2, O]`` stack.  Under a mesh, tuning runs on
        the local shard — the problem each device's kernel dispatches.

        ``batch`` may be an int or an iterable of ints — the stacked keys
        carry the decode batch ``R``, so an engine that serves several slot
        counts (8-64) tunes each R's row-tile sweep once up front:
        ``decode.tune(batch=(8, 32, 64))``.

        Each kernel is tuned in both the uncounted and the counter-carrying
        (``with_stats=True``) variant: monitored serving is the engine
        default, and the ``*_sat`` key families never share entries with
        the base ones, so skipping them would leave the sentinel's hot
        path on heuristic tiles."""
        from repro.core.lut_layers import mesh_shard_count
        from repro.kernels import ops  # local import: kernels are optional

        batches = (batch,) if isinstance(batch, int) else tuple(batch)
        conv_t = self.pcilt["tables"]  # [L, C, V]
        k = self.model.cfg.ssm.conv_kernel
        for b in batches:
            win = jnp.zeros((b, k, conv_t.shape[1]), jnp.float32)
            for stats in (False, True):
                ops.pcilt_fused_dwconv1d(win, conv_t[0], self.pcilt["spec"],
                                         self.pcilt["scale"], k,
                                         padding="VALID", autotune=True,
                                         with_stats=stats)
        proj = self.pcilt.get("proj")
        if proj is None or proj.get("path") != "fused":
            return
        group = proj["group"]
        paired = bool(proj.get("paired"))
        for name, t in proj["tables"].items():
            G = t.shape[0] if paired else t.shape[1]
            D = mesh_shard_count(proj.get("mesh"),
                                 proj.get("mesh_axis", "model"), G)
            Gl = G // D
            for b in batches:
                for stats in (False, True):
                    if paired:
                        x = jnp.zeros((b, Gl * 2 * group), jnp.float32)
                        ops.pcilt_fused_gemv_paired_stacked(
                            x, t[:Gl], 0, proj["spec"],
                            proj["scales"][name][0], group, autotune=True,
                            with_stats=stats)
                    else:
                        x = jnp.zeros((b, Gl * group), jnp.float32)
                        ops.pcilt_fused_gemv_stacked(
                            x, t[:, :Gl], 0, proj["spec"],
                            proj["scales"][name][0], group, autotune=True,
                            with_stats=stats)


class HealthMonitor:
    """Amortized health checking + graceful degradation for a converted
    Mamba decode path.

    The paper's exactness guarantee — a PCILT fetch is *bit-exact* against
    the dense matmul on the quantized activation grid — makes health
    checking uniquely cheap: any deviation at all is corruption, not noise.
    The monitor holds per-layer (and head) boolean health masks and, once
    per tick, spot-checks **one** still-healthy layer (round-robin), so the
    steady-state overhead is one layer's CRC per tick regardless of depth:

    * **checksum check** — :meth:`PCILTMambaDecode.verify_layer` CRCs the
      layer's conv + projection table slices against the conversion-time
      record (zero false negatives on single-entry flips);
    * **dense-oracle spot-check** (every ``oracle_every``-th clean check) —
      a fixed probe activation through the layer's table fetch vs the
      fake-quant dense matmul, catching corruption of anything the CRC
      record does not cover;
    * **output check** — :meth:`check_outputs` flags NaN/Inf in the decode
      logits (activation poisoning / numerical blowup), which the engine
      answers with checkpoint rollback rather than demotion.

    On breach the failing layer alone is demoted (its mask bit cleared), so
    subsequent steps run that layer's projections + conv on the exact dense
    oracle while every healthy layer keeps fetching — serving continues,
    degraded and logged, never wrong.  ``last_verified`` records the newest
    tick each layer passed at, bounding how far a rollback must rewind.

    Calibration-drift sentinel (PR 10): the CRC/oracle checks above cover
    *table* corruption, but PCILT is only correct while runtime activations
    stay inside the absmax range captured at calibration — ``quantize``
    silently clips anything outside, yielding wrong-but-finite outputs no
    checksum can see.  :meth:`observe_saturation` closes that hole from the
    in-kernel saturation counters of the monitored decode step
    (``step(with_stats=True)``): per (layer, quantizer-grid) saturation
    *rates* feed an EWMA, classified against two thresholds —
    ``sat_hard`` (instant ``"saturated"``: this step's outputs are already
    suspect) and ``sat_drift`` on the EWMA (``"drifting"``: sustained mild
    clipping).  Either breach demotes the drifting layer through the same
    typed ``layer_ok`` path as a CRC breach (event ``kind="drift"``) and
    queues it on :attr:`drift_pending`; the serving loop then calls
    :meth:`recalibrate_layer` between ticks — tables are cheap to rebuild
    (the paper's point), so the layer's grid is re-scaled to the observed
    peak ``|x|/scale`` ratio (× ``headroom``), its stacked tables are
    hot-swapped with checksums re-recorded, and the layer repromotes.
    ``max_recalibrations`` bounds thrash: a layer that keeps drifting past
    its budget stays demoted on the exact dense oracle (sticky).  The first
    recalibration sets :attr:`tainted` — outputs now come from a different
    (better-calibrated) grid than conversion time, so token streams are no
    longer comparable to a pre-drift reference.
    """

    #: the distinct quantizer grids a monitored decode step reports, in
    #: the order ``mamba_decode`` emits them
    SAT_GRIDS = ("in", "conv", "out")

    def __init__(self, decode: PCILTMambaDecode, params, *,
                 oracle_every: int = 4, oracle_batch: int = 1,
                 oracle_tol: float = 5e-3, seed: int = 0,
                 sat_hard: float = 0.25, sat_drift: float = 0.02,
                 sat_alpha: float = 0.2, headroom: float = 1.05,
                 max_recalibrations: int = 2):
        cfg = decode.model.cfg
        self.decode = decode
        self.params = params
        self.oracle_every = oracle_every
        self.oracle_tol = oracle_tol
        self.n_layers = int(cfg.n_layers)
        self.layer_ok = np.ones(self.n_layers, bool)
        self.head_ok = True
        #: newest tick each layer passed verification at (-1 = never)
        self.last_verified = np.full(self.n_layers, -1, np.int64)
        self.head_last_verified = -1
        self.checks = 0
        self.events: List[Dict] = []
        rng = np.random.default_rng(seed)
        d_inner = cfg.ssm.expand * cfg.d_model
        conv_ch = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
        self._probe = (0.3 * rng.normal(
            size=(oracle_batch, cfg.d_model))).astype(np.float32)
        # wo consumes the post-norm gated inner stream, not the block input
        # — the rotating oracle probe needs both widths.
        self._probe_out = (0.3 * rng.normal(
            size=(oracle_batch, d_inner))).astype(np.float32)
        self._oracle_rr = 0
        # -- drift sentinel state ------------------------------------------
        self.sat_hard = float(sat_hard)
        self.sat_drift = float(sat_drift)
        self.sat_alpha = float(sat_alpha)
        self.headroom = float(headroom)
        self.max_recalibrations = int(max_recalibrations)
        #: saturable elements per decode row per grid — the denominator
        #: turning the kernels' raw counts into rates
        self._sat_elems = {"in": int(cfg.d_model),
                           "conv": int(cfg.ssm.conv_kernel * conv_ch),
                           "out": int(d_inner)}
        self.sat_last = {g: np.zeros(self.n_layers) for g in self.SAT_GRIDS}
        self.sat_ewma = {g: np.zeros(self.n_layers) for g in self.SAT_GRIDS}
        #: running peak |x|/scale per (grid, layer) since last recalibration
        #: — the observed absmax the rebuild re-scales to
        self.sat_peak = {g: np.zeros(self.n_layers) for g in self.SAT_GRIDS}
        #: (layer, grid) pairs demoted for drift, awaiting recalibration
        self.drift_pending: List[Tuple[int, str]] = []
        self.recalibrations = np.zeros(self.n_layers, np.int64)
        #: True once any recalibration swapped tables: outputs thereafter
        #: come from a different quantization grid than conversion time
        self.tainted = False

    # -- masks / state -------------------------------------------------------

    def ok_masks(self) -> Tuple[jax.Array, jax.Array]:
        """The ``(layer_ok, head_ok)`` arguments for the next decode step."""
        return jnp.asarray(self.layer_ok), jnp.asarray(self.head_ok)

    @property
    def degraded(self) -> bool:
        return (not bool(self.layer_ok.all())) or not self.head_ok

    def demote(self, kind: str, layer: Optional[int], tick: int,
               reason: str) -> Dict:
        """Clear one health bit; the next step's cond takes the dense-oracle
        branch for that layer (or the head) — no retrace, no restart."""
        if kind == "head":
            self.head_ok = False
        else:
            self.layer_ok[int(layer)] = False
        ev = {"kind": kind, "layer": None if layer is None else int(layer),
              "tick": int(tick), "reason": reason}
        self.events.append(ev)
        log.warning("health breach at tick %d: %s layer=%s (%s) — demoted "
                    "to dense oracle", tick, kind, layer, reason)
        return ev

    # -- checks --------------------------------------------------------------

    def check_outputs(self, logits) -> bool:
        """NaN/Inf gate on the step's logits (True = healthy)."""
        return bool(jnp.all(jnp.isfinite(logits)))

    def _oracle_check(self, layer: int, name: str = "wx") -> bool:
        """Probe one layer's ``name`` table fetch against the fake-quant
        dense matmul — exact on the grid, so any mismatch beyond float-sum
        reassociation noise is corruption.  ``on_tick`` rotates ``name``
        across every converted projection (``nn.ssm.PROJ_NAMES``) so a
        corrupt ``wo`` or ``wdt`` is probed directly, not only via CRC."""
        proj = self.decode.pcilt.get("proj")
        if proj is None or name not in proj["tables"]:
            return True
        t = proj["tables"][name]  # [L, G, V, O] (paired: [G/2, L, V^2, O])
        spec, group = proj["spec"], proj["group"]
        paired = bool(proj.get("paired"))
        scale = proj["scales"][name][layer]
        x = self._probe_out if name == "wo" else self._probe
        n = t.shape[0] * 2 * group if paired else t.shape[1] * group
        pad = n - x.shape[-1]
        xx = np.concatenate(
            [x, np.zeros((x.shape[0], pad), x.dtype)], -1) if pad else x
        got = pcilt_linear(jnp.asarray(xx), t, spec, scale, group,
                           path="gather", stacked=int(layer), paired=paired)
        k = self.params["blocks"]["mixer"][name]["kernel"][layer]
        want = fake_quant(jnp.asarray(x), spec, scale) @ k.astype(jnp.float32)
        return bool(np.allclose(np.asarray(got), np.asarray(want),
                                rtol=self.oracle_tol, atol=self.oracle_tol))

    def _next_probe_name(self) -> str:
        """Round-robin over the converted projections for the dense-oracle
        spot-check (falls back to ``wx`` when no projections converted)."""
        from repro.nn.ssm import PROJ_NAMES

        proj = self.decode.pcilt.get("proj")
        names = tuple(n for n in PROJ_NAMES
                      if proj is not None and n in proj["tables"]) or ("wx",)
        name = names[self._oracle_rr % len(names)]
        self._oracle_rr += 1
        return name

    def on_tick(self, tick: int, sat=None, rows: int = 1) -> List[Dict]:
        """Amortized health pass for one decode tick; returns the breach
        events raised (empty = all checked slices clean).

        ``sat`` (optional) is the saturation-counter pytree of a monitored
        step (``PCILTMambaDecode.step(with_stats=True)``'s third result) and
        ``rows`` its decode batch; when given, the drift sentinel runs
        (:meth:`observe_saturation`) *before* the amortized CRC pass, so an
        instant ``"saturated"`` classification demotes on the very tick
        whose outputs it indicts."""
        tick = int(tick)
        breaches: List[Dict] = []
        if sat is not None:
            breaches.extend(self.observe_saturation(tick, sat, rows))
        candidates = [l for l in range(self.n_layers) if self.layer_ok[l]]
        if candidates:
            l = candidates[tick % len(candidates)]
            bad = self.decode.verify_layer(l)
            if bad:
                breaches.append(self.demote(
                    "layer", l, tick, f"checksum breach: {bad}"))
            else:
                self.checks += 1
                if self.oracle_every and \
                        self.checks % self.oracle_every == 0:
                    name = self._next_probe_name()
                    if not self._oracle_check(l, name):
                        breaches.append(self.demote(
                            "layer", l, tick,
                            f"dense-oracle divergence ({name})"))
            if self.layer_ok[l]:
                self.last_verified[l] = tick
        if self.head_ok and self.decode.pcilt.get("head") is not None and \
                tick % max(self.n_layers, 1) == 0:
            bad = self.decode.verify_head()
            if bad:
                breaches.append(self.demote(
                    "head", None, tick, f"checksum breach: {bad}"))
            else:
                self.head_last_verified = tick
        return breaches

    # -- calibration-drift sentinel ------------------------------------------

    def saturation_state(self, grid: str, layer: int) -> str:
        """Classify one (grid, layer) quantizer: ``"healthy"`` /
        ``"drifting"`` (EWMA past ``sat_drift``) / ``"saturated"`` (last
        observed rate past ``sat_hard``)."""
        if self.sat_last[grid][layer] >= self.sat_hard:
            return "saturated"
        if self.sat_ewma[grid][layer] >= self.sat_drift:
            return "drifting"
        return "healthy"

    def observe_saturation(self, tick: int, sat, rows: int) -> List[Dict]:
        """Feed one monitored step's saturation counters into the sentinel.

        ``sat`` is ``{"in"|"conv"|"out": {"count" [L], "ratio" [L]}}`` from
        ``decode_step(with_stats=True)``; counts normalize to per-element
        rates by ``rows ×`` the grid's element count.  A layer whose rate
        breaches ``sat_hard`` (instant) or whose EWMA breaches ``sat_drift``
        (sustained) is demoted — typed event ``kind="drift"`` carrying the
        grid, classification, and observed peak ``|x|/scale`` — and queued
        on :attr:`drift_pending` for :meth:`recalibrate_layer`.  Demoted
        layers keep contributing (the oracle branch computes the same stats
        host-side), so the recalibration re-scale always sees the freshest
        peak ratio."""
        tick = int(tick)
        breaches: List[Dict] = []
        # one batched device->host pull for the whole stats pytree (six
        # per-array np.asarray syncs add measurable per-tick latency).
        sat = jax.device_get(sat)
        for grid, st in sat.items():
            counts = np.asarray(st["count"], np.int64)
            ratios = np.asarray(st["ratio"], np.float64)
            rates = counts / float(max(int(rows), 1) * self._sat_elems[grid])
            a = self.sat_alpha
            self.sat_last[grid] = rates
            self.sat_ewma[grid] = (1.0 - a) * self.sat_ewma[grid] + a * rates
            self.sat_peak[grid] = np.maximum(self.sat_peak[grid], ratios)
            for l in range(self.n_layers):
                if not self.layer_ok[l]:
                    continue
                state = self.saturation_state(grid, l)
                if state == "healthy":
                    continue
                if state == "saturated":
                    reason = (f"saturation {grid} rate={rates[l]:.4f} >= "
                              f"sat_hard={self.sat_hard}")
                else:
                    reason = (f"saturation {grid} "
                              f"ewma={self.sat_ewma[grid][l]:.4f} >= "
                              f"sat_drift={self.sat_drift}")
                ev = self.demote("drift", l, tick, reason)
                ev.update(grid=grid, state=state, rate=float(rates[l]),
                          ewma=float(self.sat_ewma[grid][l]),
                          ratio=float(self.sat_peak[grid][l]))
                self.drift_pending.append((l, grid))
                breaches.append(ev)
        return breaches

    def recalibrate_layer(self, layer: int, grid: str, tick: int) -> Dict:
        """Online table rebuild for one drift-demoted layer, then repromote.

        The observed peak ``|x|/scale`` ratio pins the post-drift absmax
        (``ratio × old_scale``); ``headroom`` pads it so an activation just
        past the old edge doesn't immediately re-saturate.  The drifted
        grid's projections (``"in"``: the five block-input projections;
        ``"out"``: ``wo``) are rebuilt at the new scale with the *same*
        arithmetic as conversion, hot-swapped into the stacked arrays,
        their per-layer checksums re-recorded, and the executors re-hoisted
        with ``verify=True`` — so ``last_verified`` keeps meaning "checked
        against a record that matches the deployed bytes".  The ``"conv"``
        grid shares one global scale across all layers and stays demoted
        instead (sticky — rebuilding every layer's conv tables mid-serve is
        a full reconversion, not a hot-swap).  A layer past its
        ``max_recalibrations`` budget also stays demoted: the exact dense
        oracle is degraded-but-correct, and thrash means the workload, not
        the tables, moved."""
        l, tick = int(layer), int(tick)

        def _sticky(reason: str) -> Dict:
            ev = {"kind": "drift_sticky", "layer": l, "tick": tick,
                  "grid": grid, "reason": reason}
            self.events.append(ev)
            log.warning("drift at layer %d stays demoted: %s", l, reason)
            return ev

        proj = self.decode.pcilt.get("proj")
        if grid == "conv":
            return _sticky("conv grid shares one global scale across layers "
                           "— per-layer hot-swap impossible; demoted to the "
                           "dense oracle")
        if proj is None:
            return _sticky("no converted projections to rebuild")
        if self.recalibrations[l] >= self.max_recalibrations:
            return _sticky(
                f"recalibration budget exhausted "
                f"({int(self.recalibrations[l])}/{self.max_recalibrations})")
        spec, group = proj["spec"], proj["group"]
        paired = bool(proj.get("paired"))
        integ = self.decode.pcilt["integrity"]["proj"]
        names = ("wo",) if grid == "out" else tuple(
            n for n in proj["tables"] if n != "wo")
        new_amax = float(self.sat_peak[grid][l]) * self.headroom
        new_scales: Dict[str, float] = {}
        for name in names:
            old_scale = float(np.asarray(proj["scales"][name][l]))
            new_scale = scale_from_amax(
                jnp.asarray(new_amax * old_scale, jnp.float32), spec)
            wf = jnp.asarray(
                self.params["blocks"]["mixer"][name]["kernel"][l],
                jnp.float32)
            t = proj["tables"][name]
            if paired:
                # seg-major [G2, L, V2, O]: rebuild the one layer through
                # the same per-layer-vmapped builder as conversion
                from .pcilt import build_paired_stacked_tables

                t_new = build_paired_stacked_tables(
                    wf[None], spec, jnp.reshape(new_scale, (1,)),
                    group)[:, 0]
                t = t.at[:, l].set(t_new.astype(t.dtype))
                proj["tables"][name] = t
                integ[name][l] = table_checksum(np.asarray(t)[:, l])
            else:
                pad_n = (-wf.shape[0]) % group
                if pad_n:  # group-alignment slots, exactly as conversion
                    wf = jnp.concatenate(
                        [wf, jnp.zeros((pad_n, wf.shape[1]), wf.dtype)], 0)
                t_new = build_grouped_tables(wf, spec, new_scale, group)
                t = t.at[l].set(t_new.astype(t.dtype))
                proj["tables"][name] = t
                integ[name][l] = table_checksum(np.asarray(t)[l])
            proj["scales"][name] = proj["scales"][name].at[l].set(
                jnp.asarray(new_scale, jnp.float32))
            new_scales[name] = float(np.asarray(new_scale))
        # executors close over the swapped arrays — rebuild them, verifying
        # the re-recorded checksums against the deployed bytes (satellite:
        # rehoist(verify=True))
        self.decode.rehoist(verify=True)
        self.recalibrations[l] += 1
        self.tainted = True
        self.layer_ok[l] = True
        self.last_verified[l] = tick
        self.sat_ewma[grid][l] = 0.0
        self.sat_last[grid][l] = 0.0
        self.sat_peak[grid][l] = 0.0
        ev = {"kind": "recalibrate", "layer": l, "tick": tick, "grid": grid,
              "amax_ratio": new_amax, "scales": new_scales,
              "attempt": int(self.recalibrations[l])}
        self.events.append(ev)
        log.warning("recalibrated layer %d grid %r at tick %d: new scales "
                    "%s — repromoted", l, grid, tick, new_scales)
        return ev

    def recalibrate_pending(self, tick: int) -> List[Dict]:
        """Drain :attr:`drift_pending` (the between-ticks hook the serving
        loop calls): one :meth:`recalibrate_layer` per queued (layer, grid),
        deduplicated."""
        events: List[Dict] = []
        seen = set()
        pending, self.drift_pending = self.drift_pending, []
        for l, grid in pending:
            if (l, grid) in seen:
                continue
            seen.add((l, grid))
            events.append(self.recalibrate_layer(l, grid, tick))
        return events

    def saturation_summary(self) -> Dict:
        """Compact per-tick telemetry block: worst rate/EWMA per grid, total
        recalibrations, pending drift responses, taint flag."""
        return {
            "rate": {g: float(self.sat_last[g].max(initial=0.0))
                     for g in self.SAT_GRIDS},
            "ewma": {g: float(self.sat_ewma[g].max(initial=0.0))
                     for g in self.SAT_GRIDS},
            "peak_ratio": {g: float(self.sat_peak[g].max(initial=0.0))
                           for g in self.SAT_GRIDS},
            "recalibrations": int(self.recalibrations.sum()),
            "pending": len(self.drift_pending),
            "tainted": bool(self.tainted),
        }


def convert_mamba_decode(model, params, calib_tokens, ctx=None, *,
                         proj_path: str = "fused", projections=None,
                         mesh=None, mesh_axis: str = "model",
                         table_dtype=jnp.float32, paired: bool = False,
                         head: Optional[str] = None) -> PCILTMambaDecode:
    """Offline full-PCILT conversion of a ``MambaLM`` decode step.

    The once-per-lifetime build for the paper's end-to-end decode story:

    1. **calibrate** — one prefill pass over ``calib_tokens`` ``[B, S]``
       (``MambaLM.calibrate_pcilt``) captures per-layer absmax of every
       activation the converted step quantizes, turned into per-projection
       per-layer scales on the symmetric ``cfg.pcilt.act_bits`` grid;
    2. **build** — per-layer conv ``[C, V]`` tables stacked to ``[L, C, V]``
       and, when ``cfg.pcilt.apply_to_gemv``, one layer-stacked
       ``[L, G, V, O]`` grouped-table array per projection
       (``MambaLM.build_pcilt``), segment-sharded over ``mesh_axis`` when a
       mesh is given;
    3. **hoist** — the jitted decode executor is built once and reused
       every step (:class:`PCILTMambaDecode`).

    ``projections`` restricts the converted set (default: all six —
    ``nn.ssm.PROJ_NAMES``); ``proj_path`` selects the execution route
    (``"fused"`` is the deployment path; ``"kernel"`` is the host-packed
    baseline the benchmark measures against; ``"dense_fq"`` the parity
    oracle).  ``table_dtype=jnp.bfloat16`` halves table memory (the stacked
    kernel contracts and accumulates f32 either way).  ``paired=True``
    builds TL1-style paired multi-scalar tables instead — adjacent segment
    pairs merge into ``[G/2, L, V^2, O]`` seg-major stacks, halving fetches
    per output at ``V^2`` table width (``docs/paired_tables.md``); parity
    with the unpaired build is exact.  ``head="shared"``
    additionally converts the logits head to a shared-pool (ext.-3) PCILT
    calibrated on the ``ln_f`` output absmax.  The returned executor carries
    the bundle's conversion-time integrity record, verified at load.
    """
    from repro.nn.layers import Ctx

    cfg = model.cfg
    if cfg.pcilt is None:
        raise ValueError(
            "convert_mamba_decode requires model.cfg.pcilt (a configs.base."
            "PCILTConfig supplying act_bits/group for the table build); got "
            "None — set cfg = dataclasses.replace(cfg, "
            "pcilt=PCILTConfig(...)) before converting")
    ctx = ctx if ctx is not None else Ctx()
    spec = QuantSpec(bits=cfg.pcilt.act_bits, symmetric=True)
    amax = jax.jit(lambda p, b: model.calibrate_pcilt(p, b, ctx))(
        params, {"tokens": calib_tokens})

    def to_scale(a):
        return scale_from_amax(jnp.asarray(a, jnp.float32), spec)

    proj_scales = None
    if cfg.pcilt.apply_to_gemv:
        proj_scales = {"in": to_scale(amax["in"]), "out": to_scale(amax["out"])}
    if head is not None and head != "shared":
        raise ValueError(f"head= accepts None or 'shared', got {head!r}")
    pcilt = model.build_pcilt(
        params, to_scale(amax["conv_in"]), proj_scales=proj_scales,
        proj_path=proj_path, projections=projections, mesh=mesh,
        mesh_axis=mesh_axis, table_dtype=table_dtype, paired=paired,
        head_scale=to_scale(amax["head_in"]) if head == "shared" else None)
    return PCILTMambaDecode(model, pcilt, ctx)


def pcilt_apply(lin: PCILTLinear, x: jax.Array, path: str = "gather"):
    return lin(x, path=path)


def mlp_table_bytes(d_model: int, d_ff: int, act_bits: int, group: int,
                    value_bytes: int = 2) -> int:
    """Per-layer table memory for a gated MLP (3 kernels) — the feasibility
    number the paper's memory argument turns on.  Each kernel [n, out]
    becomes [n/group, 2**(bits*group), out] tables."""
    V = 1 << (act_bits * group)
    gate_up = 2 * (d_model // group) * V * d_ff * value_bytes
    down = (d_ff // group) * V * d_model * value_bytes
    return gate_up + down
