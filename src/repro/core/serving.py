"""PCILT serving-mode conversion for LM decode paths.

Implements the paper's deployment story for the framework's language models:
an *offline* table build ("done only once in the lifetime of a CNN") that
converts selected projection kernels into grouped PCILTs, plus the decode
helpers that execute them via the fetch paths.  Used by
``examples/serve_pcilt.py`` and the integration tests; the per-architecture
table-memory accounting (the paper's own feasibility analysis applied to the
10 assigned archs) is in ``benchmarks/paper_claims.py``.

Scoping (DESIGN.md §6): tables address the *decode GEMV* regime — batch-
starved, memory-bound — and the conv frontends.  Weight-side cardinality is
reduced by weight quantization first (paper: tables exist per distinct weight
value; shared-PCILT keeps memory feasible).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .quantization import QuantSpec, calibrate, quantize, dequantize
from .pcilt import (SharedGroupedTables, ShardedSharedPool,
                    build_grouped_tables, build_shared_grouped_tables,
                    shard_shared_grouped_tables)
from .lut_layers import mesh_shard_count, pcilt_linear

__all__ = ["PCILTLinear", "convert_kernel", "pcilt_apply", "mlp_table_bytes"]


class PCILTLinear:
    """A converted projection: grouped tables + activation quantizer.

    ``path="fused"`` executes the whole quantize→pack→fetch pipeline in one
    Pallas call (``repro.kernels.pcilt_fused``); ``path="shared"`` does the
    same over an extension-3 segment-deduped pool (``repro.kernels.
    pcilt_shared``) — the configuration that keeps table memory feasible for
    real LM projections.  All kernel paths dispatch tile shapes through the
    persistent autotune lookup table.  Call :meth:`tune` once per decode
    shape at serving warmup to populate it — every later dispatch (this
    process or the next) is a pure cache hit.

    Exactly one table representation needs to exist: dense ``tables``
    (``[G, V, O]``) and/or a ``shared`` pool.  A shared-only instance (the
    memory-feasible deployment) executes ``path="gather"`` and
    ``path="shared"``; dense-only instances execute everything else.

    With ``mesh=``, the layer is tensor-parallel: dense tables are placed
    under ``PartitionSpec(mesh_axis, None, None)`` (each device holds the
    ``[G/D, V, O]`` shard), a shared pool is pre-sharded into a
    ``ShardedSharedPool`` (per-device memory scales with the *local* pool
    cardinality), every ``__call__`` runs the fetch under ``shard_map`` with
    one ``psum`` of the partial adder-tree sums, and :meth:`tune` keys the
    autotune cache on the **local shard shape** — the shape the kernels
    actually see per device.  When ``mesh_axis`` does not divide ``G`` the
    layer falls back to replicated execution (divisibility fallback).
    """

    def __init__(self, tables: Optional[jax.Array], spec: QuantSpec,
                 scale: jax.Array, group: int,
                 shared: Optional[SharedGroupedTables] = None,
                 mesh=None, mesh_axis: str = "model"):
        if tables is None and shared is None:
            raise ValueError("PCILTLinear needs dense tables, a shared pool, "
                             "or both")
        self.tables = tables
        self.spec = spec
        self.scale = scale
        self.group = group
        self.shared = shared
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.shard_pools: Optional[ShardedSharedPool] = None
        if mesh is not None and self.shard_count > 1:
            if shared is not None:
                self.shard_pools = shard_shared_grouped_tables(
                    shared, self.shard_count)
                self._place_shard_pools()
            if tables is not None:
                # Park each [G/D, V, O] shard on its device now — the whole
                # point is that no device ever holds the global tables.
                from repro.nn.module import pcilt_table_sharding

                self.tables = jax.device_put(
                    tables, pcilt_table_sharding(mesh, tables.shape[0],
                                                 mesh_axis=mesh_axis))

    def _place_shard_pools(self) -> None:
        from repro.nn.module import pcilt_table_sharding

        sp = self.shard_pools
        self.shard_pools = ShardedSharedPool(
            pools=jax.device_put(
                sp.pools, pcilt_table_sharding(self.mesh, sp.n_shards, ndim=4,
                                               mesh_axis=self.mesh_axis)),
            seg_idx=jax.device_put(
                sp.seg_idx, pcilt_table_sharding(self.mesh, sp.n_shards,
                                                 ndim=2,
                                                 mesh_axis=self.mesh_axis)),
            group=sp.group, shard_cards=sp.shard_cards)

    @property
    def n_segments(self) -> int:
        if self.tables is not None:
            return self.tables.shape[0]
        return self.shared.n_segments

    @property
    def shard_count(self) -> int:
        """Effective G-shards on the layer's mesh (1 = replicated fallback)."""
        return mesh_shard_count(self.mesh, self.mesh_axis, self.n_segments)

    def table_bytes(self) -> int:
        """Bytes of the representation this layer would deploy (the shared
        pool when present — the paper's ext.-3 memory argument)."""
        if self.shared is not None:
            return self.shared.pool_bytes()
        return self.tables.size * self.tables.dtype.itemsize

    def per_device_table_bytes(self) -> int:
        """Table bytes each device holds under the layer's mesh.

        Dense tables shard exactly linearly (``G/D`` segments per device);
        shared layers stage the padded local pool.  Replicated layers (no
        mesh / fallback) hold everything everywhere.
        """
        if self.shard_pools is not None:
            return self.shard_pools.local_pool_bytes()
        return -(-self.table_bytes() // self.shard_count)

    def _pad_x(self, x: jax.Array) -> jax.Array:
        n = self.n_segments * self.group
        pad = n - x.shape[-1]
        if pad:
            x = jnp.concatenate([x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], -1)
        return x

    def _tables_for(self, path: str):
        if path == "shared" or (self.tables is None and path == "gather"):
            if self.shared is None:
                raise ValueError(
                    "no shared pool on this layer; convert with shared=True")
            return self.shard_pools if self.shard_pools is not None else self.shared
        if self.tables is None:
            raise ValueError(
                f"shared-only PCILTLinear executes path='shared' or 'gather', "
                f"not {path!r}")
        return self.tables

    def __call__(self, x: jax.Array, path: str = "gather") -> jax.Array:
        return pcilt_linear(self._pad_x(x), self._tables_for(path), self.spec,
                            self.scale, self.group, path=path,
                            mesh=self.mesh, mesh_axis=self.mesh_axis)

    def tune(self, x: jax.Array) -> jax.Array:
        """Eagerly autotune the fused kernel for this decode shape and record
        the winner in the persistent lookup table; returns the output.
        Shared-only layers tune the shared-pool kernel.

        Under a mesh, tuning runs on the **local shard shape** — one shard's
        ``[G/D, V, O]`` tables (or local pool) against the matching slice of
        the reduction dim — because that is the problem each device's kernel
        dispatches, and the shape key the sharded ``shard_map`` execution
        looks up at trace time.  Caches tuned at different device counts
        therefore occupy different keys and never collide.
        """
        from repro.kernels import ops  # local import: kernels are optional

        x = self._pad_x(x)
        flat = x.reshape(-1, x.shape[-1])
        D = self.shard_count
        if D > 1:
            Gl = self.n_segments // D
            xl = flat[:, : Gl * self.group]
            if self.tables is None:
                sp = self.shard_pools
                ops.pcilt_shared_gemv(xl, sp.pools[0], sp.seg_idx[0],
                                      self.spec, self.scale, self.group,
                                      autotune=True)
                return self(x, path="shared")
            ops.pcilt_fused_gemv(xl, self.tables[:Gl], self.spec, self.scale,
                                 self.group, autotune=True)
            return self(x, path="fused")
        if self.tables is None:
            out = ops.pcilt_shared_gemv(
                flat, self.shared.pool, self.shared.seg_idx, self.spec,
                self.scale, self.group, autotune=True)
        else:
            out = ops.pcilt_fused_gemv(flat, self.tables, self.spec,
                                       self.scale, self.group, autotune=True)
        return out.reshape(*x.shape[:-1], out.shape[-1])


def convert_kernel(kernel: jax.Array, act_spec: QuantSpec, act_scale,
                   group: int, weight_bits: Optional[int] = None,
                   shared: bool = False, mesh=None,
                   mesh_axis: str = "model") -> PCILTLinear:
    """Offline build for one [d_in, d_out] kernel.

    weight_bits: optionally quantize weights first (lowers table value
    diversity, the precondition for shared-PCILT dedup, ext. 3).
    shared: build the extension-3 segment-deduped pool *instead of* the dense
    tables — the layer then executes ``path="shared"`` (fused kernel) and
    ``path="gather"`` (pointer-gather reference), and its table memory scales
    with the weights' actual segment cardinality.  Usually combined with
    ``weight_bits`` (or otherwise weight-clustered kernels): dedup only bites
    when whole ``[group, d_out]`` segments repeat.
    mesh: build a tensor-parallel layer — tables are sharded on the segment
    axis over ``mesh_axis`` at conversion time (shared pools become per-shard
    local pools) and every call executes under ``shard_map`` with a psum of
    the partial sums.  Conversion is the offline step, so the sharding is
    too."""
    k = kernel.astype(jnp.float32)
    if kernel.ndim > 2:
        k = k.reshape(kernel.shape[0], -1)
    if weight_bits:
        wspec = QuantSpec(bits=weight_bits, symmetric=True)
        wscale = calibrate(k, wspec)
        k = dequantize(quantize(k, wspec, wscale), wspec, wscale)
    n, out = k.shape
    pad = (-n) % group
    if pad:
        k = jnp.concatenate([k, jnp.zeros((pad, out), k.dtype)], 0)
    if shared:
        pool = build_shared_grouped_tables(k, act_spec, act_scale, group)
        return PCILTLinear(None, act_spec, act_scale, group, shared=pool,
                           mesh=mesh, mesh_axis=mesh_axis)
    tables = build_grouped_tables(k, act_spec, act_scale, group)
    return PCILTLinear(tables, act_spec, act_scale, group, mesh=mesh,
                       mesh_axis=mesh_axis)


def pcilt_apply(lin: PCILTLinear, x: jax.Array, path: str = "gather"):
    return lin(x, path=path)


def mlp_table_bytes(d_model: int, d_ff: int, act_bits: int, group: int,
                    value_bytes: int = 2) -> int:
    """Per-layer table memory for a gated MLP (3 kernels) — the feasibility
    number the paper's memory argument turns on.  Each kernel [n, out]
    becomes [n/group, 2**(bits*group), out] tables."""
    V = 1 << (act_bits * group)
    gate_up = 2 * (d_model // group) * V * d_ff * value_bytes
    down = (d_ff // group) * V * d_model * value_bytes
    return gate_up + down
