"""Pre-Calculated Inference Lookup Table (PCILT) construction.

"Prior to the learning start, the multiplications of the filter values by all
possible activation values are calculated and placed in pre-calculated lookup
tables" (paper, §Basic Version).  This module builds those tables, in all the
paper's flavors:

* **scalar tables** — one table per weight, ``T[k, a] = f(w_k, val(a))``
  (basic algorithm, Fig. 1);
* **grouped tables** — one table per weight *segment*, entries hold the
  pre-summed partial dot product of the whole segment against one packed
  offset (extension 1, Fig. 5);
* **shared tables** — tables dedupe to the weight's *actual* cardinality;
  layers keep integer pointers into a shared pool (extension 3), with an
  optional second indirection level onto unique table *values*;
* **shared grouped tables** — extension 3 applied at *segment* granularity:
  the grouped ``[G, V, out]`` tables dedupe to a ``pool[X, V, out]`` of the
  ``X`` unique segment tables plus a ``seg_idx[G]`` int32 pointer vector
  (``SharedGroupedTables``).  Two segments share a pool row iff their
  ``[group, out]`` weight blocks are identical — the regime weight
  clustering / palettization / low weight cardinality produces, where
  ``X << G`` and table memory shrinks by ``G/X``.  This is the
  representation the shared-pool fused kernel
  (``repro.kernels.pcilt_shared``) consumes directly from VMEM;
* **custom convolutional functions** — ``f`` need not be multiplication
  (extension 2); any ``f(w, a_val)`` builds at the same cost and executes at
  zero extra inference cost.

Memory accounting lives here too (``table_bytes`` and friends) — the paper's
own feasibility argument is a memory argument, and ``benchmarks/paper_claims``
reproduces its 1.65 GB / ~100 MB / ~75 MB / ~25 MB / ~18 MB examples from
these formulas.

Sharded-table layout (tensor-parallel decode)
---------------------------------------------

Grouped tables for real LM projections reach GBs (``benchmarks/run.py``
``lm.*`` rows) — past single-device HBM.  The mesh execution path shards the
**segment axis** ``G`` across the ``"model"`` mesh axis:

* dense ``[G, V, O]`` tables live under ``PartitionSpec("model", None, None)``
  (logical axis ``"table_seg"`` in ``repro.nn.module.DEFAULT_RULES``), so each
  of the ``D`` devices holds the ``[G/D, V, O]`` tables of its contiguous
  segment block — per-device table bytes shrink linearly with the model axis;
* the paper's adder tree ``sum_s T[s, off_s]`` is associative, so each device
  fetches and sums only its local segments and one ``psum`` over ``"model"``
  combines the partial sums (the single cross-device collective, placed in
  ``repro.core.lut_layers``);
* shared (ext.-3) pools are sharded by **partitioning the pointer vector**:
  shard ``d`` keeps only the pool rows its ``seg_idx[d*G/D:(d+1)*G/D]`` slice
  references, remapped to local indices (:class:`ShardedSharedPool`,
  :func:`shard_shared_grouped_tables`) — per-device pool memory scales with
  the *local* cardinality ``X_d <= X``, preserving the extension-3 property
  under tensor parallelism.

If the mesh axis does not divide ``G``, execution falls back to replication
(single-device semantics), mirroring the divisibility fallback of
``repro.nn.module.ShardingRules``.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .quantization import QuantSpec, code_values
from .offsets import SegmentPlan, offset_grid

__all__ = [
    "mul_fn",
    "log_mul_fn",
    "build_scalar_tables",
    "build_grouped_tables",
    "build_paired_tables",
    "build_paired_stacked_tables",
    "SharedTables",
    "build_shared_tables",
    "SharedGroupedTables",
    "build_shared_grouped_tables",
    "ShardedSharedPool",
    "shard_shared_grouped_tables",
    "table_bytes",
    "grouped_table_bytes",
    "shared_table_bytes",
    "shared_pool_bytes",
    "build_cost_multiplies",
    "table_checksum",
    "stacked_checksums",
]

# ----------------------------------------------------------------------------
# Convolutional functions (extension 2).  A convolutional function maps a
# (weight, activation-value) pair to the number that enters the adder tree.
# The classic choice is multiplication; anything else rides for free because
# only the table build evaluates it.
# ----------------------------------------------------------------------------


def mul_fn(w, a):
    """The classic convolution: plain product."""
    return w * a


def log_mul_fn(w, a, gamma: float = 1.0):
    """A paper-suggested custom function: log-compressed product.

    Rescales the inferred value range non-uniformly (paper: "re-scale and
    modify the range of the inferred values and their distribution").
    """
    p = w * a
    return jnp.sign(p) * jnp.log1p(gamma * jnp.abs(p)) / gamma


# ----------------------------------------------------------------------------
# Table builders
# ----------------------------------------------------------------------------


def build_scalar_tables(
    w: jax.Array,
    spec: QuantSpec,
    scale,
    fn: Callable = mul_fn,
    dtype=jnp.float32,
) -> jax.Array:
    """Basic PCILT: per-weight tables.

    w: ``[n, out]`` reduction-major weights (a conv filter is flattened to
      ``n = kh*kw*cin`` per output channel).
    Returns ``T[n, K, out]`` with ``T[k, a, o] = fn(w[k, o], val(a))``.
    """
    vals = code_values(spec, scale, dtype)  # [K]
    return fn(w[:, None, :].astype(dtype), vals[None, :, None])


def build_grouped_tables(
    w: jax.Array,
    spec: QuantSpec,
    scale,
    group: int,
    plan: Optional[SegmentPlan] = None,
    fn: Callable = mul_fn,
    dtype=jnp.float32,
    build_chunk: int = 4096,
) -> jax.Array:
    """Extension-1 PCILT: per-segment pre-summed tables (Fig. 5).

    w: ``[n, out]``; segments follow ``plan`` (default: ``group`` contiguous
    positions per segment).  Returns ``T[G, V, out]`` with ``V = K**group``::

        T[s, v, o] = sum_j fn(w_seg[s, j, o], val(code_j(v)))

    so that a single fetch ``T[s, offset, o]`` yields the entire segment's
    contribution.  Built once per network lifetime; the build enumerates all
    ``V`` offsets (chunked so huge ``V`` stays within memory).
    """
    n, out = w.shape
    if plan is None:
        plan = SegmentPlan.contiguous(n, group)
    w_seg = plan.gather_weights(w).astype(dtype)  # [G, g, out]
    grid = offset_grid(spec.bits, plan.group)  # [V, g] codes
    vals = code_values(spec, scale, dtype)[grid]  # [V, g] values
    V = vals.shape[0]

    if fn is mul_fn:
        return jnp.einsum("vj,gjo->gvo", vals, w_seg)

    def chunk_tables(vchunk):  # [C, g] -> [G, C, out]
        contrib = fn(w_seg[:, None, :, :], vchunk[None, :, :, None])
        return jnp.sum(contrib, axis=2)

    chunks = [
        chunk_tables(vals[i : i + build_chunk]) for i in range(0, V, build_chunk)
    ]
    return jnp.concatenate(chunks, axis=1)


def build_paired_tables(
    w: jax.Array,
    spec: QuantSpec,
    scale,
    group: int,
    fn: Callable = mul_fn,
    dtype=jnp.float32,
    build_chunk: int = 4096,
) -> jax.Array:
    """TL1-style paired (multi-scalar) tables: ``[ceil(G/2), V**2, out]``.

    Pairs adjacent ``group``-wide segments into one double-wide segment so a
    single fetch covers *two* segments' worth of weights: the table trades
    ``V`` entries for ``V**2`` while halving the segment count ``G`` — half
    the fetches, half the adder-tree depth on the hot decode path.

    The paired index is **little-endian in the pair**, matching the fused
    kernels' ``_pack_flat`` shift-or over ``2*group`` codes::

        paired_off = off_even + off_odd * V        (V = K**group)

    so ``T2[s, off_even + off_odd*V] == T[2s, off_even] + T[2s+1, off_odd]``
    exactly (each paired entry is a single pre-summed dot over the combined
    ``2*group`` weights — same summation the unpaired pair of fetches adds at
    runtime).  When ``G`` is odd, ``w`` is zero-padded by one phantom segment
    whose table column is exactly zero under ``mul_fn`` (``0 * val == 0``),
    so parity with the unpaired tables holds bit-exactly.  ``dtype`` may be
    bf16 — the build is one einsum in ``dtype``, same as the unpaired build.
    """
    n, out = w.shape
    pair = 2 * group
    pad = (-n) % pair
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, out), w.dtype)], axis=0)
    return build_grouped_tables(w, spec, scale, pair, fn=fn, dtype=dtype,
                                build_chunk=build_chunk)


def build_paired_stacked_tables(
    ws: jax.Array,
    spec: QuantSpec,
    scales,
    group: int,
    fn: Callable = mul_fn,
    dtype=jnp.float32,
) -> jax.Array:
    """Layer-stacked paired tables in **segment-major** layout
    ``[G2, L, V**2, out]`` (``G2 = ceil(G/2)``).

    ``ws`` is ``[L, n, out]`` (one projection per layer), ``scales`` is
    ``[L]``.  Segment-major rather than the dense stack's layer-major
    ``[L, G, V, O]`` so the stacked paired kernel can fold the layer into the
    table's *value* axis: the BlockSpec stages a ``[Gb, L, V**2, Ob]`` block
    whose segment index is constant in the prefetched layer, and the kernel
    indexes row ``l*V**2 + off`` of the reshaped ``[Gb, L*V**2, Ob]`` block —
    a constant-iota row-gather XLA lowers to its batched fast path, instead
    of the traced-layer general gather that made the dense layout slow.
    """
    build = functools.partial(build_paired_tables, spec=spec, group=group,
                              fn=fn, dtype=dtype)
    t = jax.vmap(lambda w, s: build(w, scale=s))(ws, scales)  # [L, G2, V2, O]
    return jnp.transpose(t, (1, 0, 2, 3))


# ----------------------------------------------------------------------------
# Shared tables (extension 3)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class SharedTables:
    """Weight-deduped PCILT pool.

    ``pool[x, a] = fn(unique_w[x], val(a))`` and every layer weight is replaced
    by a pointer ``w_idx`` into the pool — "keep only one PCILT for given
    algorithm base value(s) and replace the others with pointers to it".

    With ``value_pool`` set, a second indirection maps table cells onto unique
    *values* (the paper's variant for low per-value diversity): ``pool`` then
    holds integer indices into ``value_pool``.
    """

    pool: jax.Array  # [X, K] table values, or int indices if value_pool
    w_idx: jax.Array  # [n, out] uint16 pointers into pool rows
    unique_w: jax.Array  # [X]
    value_pool: Optional[jax.Array] = None  # [U] unique table values
    #: lazily-built 1-wide segment pool (offline np.unique — built once)
    _grouped: Optional["SharedGroupedTables"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def as_grouped_pool(self) -> "SharedGroupedTables":
        """The scalar pool re-expressed as a 1-wide segment pool
        (:class:`SharedGroupedTables` with ``group=1``).

        Each of the ``n`` weight positions is a 1-wide segment whose table is
        the ``[K, out]`` slice its pointer row selects; positions with
        bit-identical pointer rows share one pool row, so the pool holds only
        the ``X' <= n`` *distinct rows of the pointer matrix* — never the
        dense ``[n, K, out]`` tables ``materialize()`` expands in HBM.  This
        is how the scalar-level extension-3 representation reaches the fused
        shared kernel (``path="shared"``) and the pointer-gather lookup.
        Must run outside jit (``np.unique`` on concrete pointers — part of
        the offline table build; the result is cached on the instance).
        """
        if self._grouped is None:
            pool = self.pool
            if self.value_pool is not None:
                pool = self.value_pool[pool]
            idx = np.asarray(self.w_idx)
            rows, inv = np.unique(idx, axis=0, return_inverse=True)  # [X',out]
            seg_pool = jnp.transpose(
                jnp.take(jnp.asarray(pool), jnp.asarray(rows), axis=0),
                (0, 2, 1))  # [X', out, K] -> [X', K, out]
            self._grouped = SharedGroupedTables(
                pool=seg_pool,
                seg_idx=jnp.asarray(inv.reshape(-1), jnp.int32),
                group=1,
            )
        return self._grouped

    def lookup(self, codes: jax.Array) -> jax.Array:
        """codes ``[..., n]`` -> summed dot result ``[..., out]``.

        Routed through the 1-wide segment pool's pointer-gather
        (:meth:`as_grouped_pool`): two advanced indexes on the deduped pool
        and one adder-tree sum — the dense ``[n, K, out]`` tables are never
        materialized in HBM.  Table-bytes accounting is unchanged (the pool
        is the same ``[X', K]``-cell storage, only re-blocked per segment).
        """
        return self.as_grouped_pool().lookup(codes.astype(jnp.int32))

    def materialize(self) -> jax.Array:
        """Expand pointers back into dense per-weight tables ``[n, K, out]``.

        Exists for parity tests and memory-accounting comparisons only — the
        execution paths (:meth:`lookup`, ``path="shared"``) go through
        :meth:`as_grouped_pool` and never call this.
        """
        pool = self.pool
        if self.value_pool is not None:
            pool = self.value_pool[pool]
        return jnp.transpose(pool[self.w_idx], (0, 2, 1))  # [n, out, K]->[n,K,out]

    @property
    def actual_cardinality(self) -> int:
        return int(self.unique_w.shape[0])


def build_shared_tables(
    w: jax.Array,
    spec: QuantSpec,
    scale,
    fn: Callable = mul_fn,
    dedup_values: bool = False,
    dtype=jnp.float32,
) -> SharedTables:
    """Build the shared pool for weights whose *actual* cardinality is small.

    Must run outside jit (uses ``np.unique`` on concrete weights — table
    construction is an offline, once-per-lifetime step in the paper).
    """
    w_np = np.asarray(w)
    uniq, inv = np.unique(w_np, return_inverse=True)
    vals = code_values(spec, scale, dtype)  # [K]
    pool = fn(jnp.asarray(uniq, dtype)[:, None], vals[None, :])  # [X, K]
    value_pool = None
    if dedup_values:
        pv, pinv = np.unique(np.asarray(pool), return_inverse=True)
        value_pool = jnp.asarray(pv, dtype)
        pool = jnp.asarray(pinv.reshape(pool.shape), jnp.int32)
    return SharedTables(
        pool=pool,
        w_idx=jnp.asarray(inv.reshape(w_np.shape), jnp.int32),
        unique_w=jnp.asarray(uniq, dtype),
        value_pool=value_pool,
    )


# ----------------------------------------------------------------------------
# Shared grouped tables (extension 3 at segment granularity)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class SharedGroupedTables:
    """Segment-deduped grouped PCILT pool (extension 3 over extension 1).

    ``pool[x, v, o]`` holds the ``X`` *unique* segment tables; ``seg_idx[g]``
    points segment ``g`` at its pool row, so the dense grouped tables are
    recoverable as ``pool[seg_idx]`` — "keep only one PCILT for given
    algorithm base value(s) and replace the others with pointers to it",
    applied to whole ``[group, out]`` weight segments instead of scalar
    weights.  Table memory scales with the weights' actual segment
    cardinality ``X``, not the nominal segment count ``G``.
    """

    pool: jax.Array  # [X, V, out] unique segment tables
    seg_idx: jax.Array  # [G] int32 pointers into pool rows
    group: int  # codes packed per offset (V == K**group)

    @property
    def n_segments(self) -> int:
        return int(self.seg_idx.shape[0])

    @property
    def pool_cardinality(self) -> int:
        return int(self.pool.shape[0])

    def materialize(self) -> jax.Array:
        """Expand pointers back into dense grouped tables ``[G, V, out]``.

        Exists for parity testing and for callers that insist on the dense
        fused path — the shared-pool kernel never calls it.
        """
        return jnp.take(self.pool, self.seg_idx, axis=0)

    def lookup(self, offsets: jax.Array) -> jax.Array:
        """Gather path: offsets ``[..., G]`` -> ``[..., out]`` without ever
        materializing the dense tables (double advanced-index on the pool)."""
        partial = self.pool[self.seg_idx, offsets.astype(jnp.int32)]
        return jnp.sum(partial, axis=-2)

    def pool_bytes(self, value_bytes: Optional[int] = None) -> int:
        """Ext.-3 memory: unique segment tables plus the pointer vector."""
        X, V, out = self.pool.shape
        vb = value_bytes if value_bytes is not None else self.pool.dtype.itemsize
        # The pool is exactly ext.-3 accounting with one packed-offset "act
        # bits" entry of log2(V), each table cell holding an out-vector.
        return (shared_table_bytes(X, [(V - 1).bit_length()], out * vb)
                + self.n_segments * self.seg_idx.dtype.itemsize)

    def dense_bytes(self, value_bytes: Optional[int] = None) -> int:
        """What the equivalent dense ``[G, V, out]`` tables would occupy."""
        _, V, out = self.pool.shape
        vb = value_bytes if value_bytes is not None else self.pool.dtype.itemsize
        return self.n_segments * V * out * vb

    @property
    def dedup_ratio(self) -> float:
        """Dense-to-pool table-memory ratio (≈ ``G / X`` for large tables)."""
        return self.dense_bytes() / max(self.pool_bytes(), 1)


def build_shared_grouped_tables(
    w: jax.Array,
    spec: QuantSpec,
    scale,
    group: int,
    plan: Optional[SegmentPlan] = None,
    fn: Callable = mul_fn,
    dtype=jnp.float32,
    build_chunk: int = 4096,
) -> SharedGroupedTables:
    """Segment-level extension-3 dedup over the grouped-table build.

    w: ``[n, out]`` reduction-major weights.  Segments follow ``plan``
    (default contiguous); segments whose ``[group, out]`` weight blocks are
    bit-identical share one pool row.  Only the ``X`` unique segment tables
    are ever built — the build cost, like the memory, scales with the actual
    segment cardinality.  Must run outside jit (``np.unique`` on concrete
    weights; table construction is the paper's offline once-per-lifetime
    step).
    """
    n, out = w.shape
    if plan is None:
        plan = SegmentPlan.contiguous(n, group)
    w_seg = np.asarray(plan.gather_weights(jnp.asarray(w)))  # [G, g, out]
    G = w_seg.shape[0]
    uniq, inv = np.unique(w_seg.reshape(G, -1), axis=0, return_inverse=True)
    X = uniq.shape[0]
    uw = jnp.asarray(uniq.reshape(X, plan.group, out), dtype)
    grid = offset_grid(spec.bits, plan.group)  # [V, g] codes
    vals = code_values(spec, scale, dtype)[grid]  # [V, g] values
    V = vals.shape[0]

    if fn is mul_fn:
        pool = jnp.einsum("vj,xjo->xvo", vals, uw)
    else:
        def chunk_tables(vchunk):  # [C, g] -> [X, C, out]
            contrib = fn(uw[:, None, :, :], vchunk[None, :, :, None])
            return jnp.sum(contrib, axis=2)

        pool = jnp.concatenate(
            [chunk_tables(vals[i:i + build_chunk])
             for i in range(0, V, build_chunk)], axis=1)
    return SharedGroupedTables(
        pool=pool,
        seg_idx=jnp.asarray(inv.reshape(-1), jnp.int32),
        group=plan.group,
    )


# ----------------------------------------------------------------------------
# Mesh-sharded shared pools (extension 3 under tensor parallelism)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedSharedPool:
    """Per-shard shared pools for mesh execution of an ext.-3 layer.

    The global ``SharedGroupedTables`` is partitioned along the segment axis
    into ``D`` contiguous blocks of ``Gl = G / D`` segments.  Shard ``d``
    keeps only the pool rows its pointer slice references — its *local*
    cardinality ``X_d <= X`` — remapped to local indices, and every local
    pool is zero-padded to ``Xmax = max_d X_d`` so the stacked operands have
    uniform shapes for ``shard_map`` (padded rows are never referenced by any
    local pointer).

    Layout (leading axis = shard = ``"model"`` mesh axis):

    * ``pools   [D, Xmax, V, O]`` — ``PartitionSpec("model", None, None, None)``
    * ``seg_idx [D, Gl]`` int32   — ``PartitionSpec("model", None)``

    so under ``shard_map`` each device sees one ``[Xmax, V, O]`` local pool
    plus its ``[Gl]`` local pointers, executes the shared-pool kernel over
    them, and contributes its partial adder-tree sum to the ``psum`` over the
    model axis.  Per-device table memory is ``Xmax*V*O*itemsize + Gl*4`` —
    local-``X`` pool math, not global ``G`` or global ``X``.
    """

    pools: jax.Array  # [D, Xmax, V, O] stacked local pools (rows zero-padded)
    seg_idx: jax.Array  # [D, Gl] int32 local pointers into the local pool
    group: int  # codes packed per offset (V == K**group)
    shard_cards: Tuple[int, ...] = ()  # true per-shard cardinality X_d (pre-pad)

    @property
    def n_shards(self) -> int:
        return int(self.pools.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_idx.shape[0] * self.seg_idx.shape[1])

    @property
    def max_cardinality(self) -> int:
        """Padded local pool rows ``Xmax`` — what every device stages."""
        return int(self.pools.shape[1])

    def local_pool_bytes(self, value_bytes: Optional[int] = None) -> int:
        """Per-device table memory: the padded local pool + local pointers."""
        _, Xmax, V, out = self.pools.shape
        vb = value_bytes if value_bytes is not None else self.pools.dtype.itemsize
        return (shared_table_bytes(Xmax, [(V - 1).bit_length()], out * vb)
                + self.seg_idx.shape[1] * self.seg_idx.dtype.itemsize)

    def materialize(self) -> jax.Array:
        """Dense ``[G, V, O]`` tables recovered shard by shard (parity tests)."""
        parts = [jnp.take(self.pools[d], self.seg_idx[d], axis=0)
                 for d in range(self.n_shards)]
        return jnp.concatenate(parts, axis=0)


def shard_shared_grouped_tables(
    st: SharedGroupedTables, n_shards: int
) -> ShardedSharedPool:
    """Offline shard build: partition ``seg_idx`` and dedupe pools per shard.

    Must run outside jit (``np.unique`` on concrete pointers — like every
    table build, sharding is part of the paper's once-per-lifetime offline
    step).  ``n_shards`` must divide ``G``; the mesh execution path applies
    its divisibility fallback *before* calling this.
    """
    G = st.n_segments
    if n_shards < 1 or G % n_shards:
        raise ValueError(
            f"n_shards={n_shards} must divide the segment count G={G} "
            f"(the caller applies the replication fallback otherwise)")
    Gl = G // n_shards
    si = np.asarray(st.seg_idx)
    pool = np.asarray(st.pool)
    locals_: list = []
    for d in range(n_shards):
        rows, inv = np.unique(si[d * Gl:(d + 1) * Gl], return_inverse=True)
        locals_.append((rows, inv.astype(np.int32)))
    x_max = max(len(rows) for rows, _ in locals_)
    pools = np.zeros((n_shards, x_max) + pool.shape[1:], pool.dtype)
    idx = np.zeros((n_shards, Gl), np.int32)
    for d, (rows, inv) in enumerate(locals_):
        pools[d, : len(rows)] = pool[rows]
        idx[d] = inv
    return ShardedSharedPool(
        pools=jnp.asarray(pools),
        seg_idx=jnp.asarray(idx),
        group=st.group,
        shard_cards=tuple(len(rows) for rows, _ in locals_),
    )


# ----------------------------------------------------------------------------
# Memory & build-cost accounting (drives benchmarks/paper_claims.py)
# ----------------------------------------------------------------------------


def table_bytes(n_weights: int, act_bits: int, value_bytes: int) -> int:
    """Basic-PCILT memory: one ``2**act_bits``-entry table per weight."""
    return n_weights * (1 << act_bits) * value_bytes


def grouped_table_bytes(
    n_weights: int, act_bits: int, group: int, value_bytes: int
) -> int:
    """Extension-1 memory: ``K**group`` entries per segment of ``group`` weights."""
    segments = -(-n_weights // group)
    return segments * (1 << (act_bits * group)) * value_bytes


def shared_table_bytes(
    actual_cardinality: int, act_bits_list: Sequence[int], value_bytes: int,
    nested: bool = False,
) -> int:
    """Extension-3 memory: unique tables only.

    ``nested=True`` models the paper's note that the table for a lower
    cardinality is a prefix of the higher-cardinality one, so only the largest
    table per base value is kept.
    """
    if nested:
        return actual_cardinality * (1 << max(act_bits_list)) * value_bytes
    return actual_cardinality * sum(1 << b for b in act_bits_list) * value_bytes


def shared_pool_bytes(pool_cardinality: int, act_bits: int, group: int,
                      out: int, value_bytes: int,
                      n_segments: int = 0, ptr_bytes: int = 4) -> int:
    """Segment-level extension-3 memory: ``X`` unique ``[K**group, out]``
    segment tables (plus the ``[G]`` pointer vector when ``n_segments`` is
    given) — the pool the shared fused kernel stages.  Delegates to
    :func:`shared_table_bytes` with the packed-offset width as the single
    "act bits" entry."""
    return (shared_table_bytes(pool_cardinality, [act_bits * group],
                               out * value_bytes)
            + n_segments * ptr_bytes)


def build_cost_multiplies(n_weights: int, act_bits: int) -> int:
    """Multiplications to build basic tables (paper: 5x5 INT8 -> 6,400)."""
    return n_weights * (1 << act_bits)


# ----------------------------------------------------------------------------
# Table integrity (serving resilience).  Tables are immutable deployment
# artifacts — any in-memory difference from the conversion-time bytes is
# corruption.  CRC-32 detects *every* error burst of <= 32 bits, so a single
# flipped table entry (float32/bfloat16 value, int32 seg_idx pointer) can
# never be missed — the zero-false-negative property the chaos suite
# unit-tests.
# ----------------------------------------------------------------------------


def table_checksum(arr) -> int:
    """CRC-32 over the raw bytes of a table array (gathers sharded arrays)."""
    a = np.ascontiguousarray(np.asarray(arr))
    return zlib.crc32(a.tobytes())


def stacked_checksums(arr, axis: int = 0) -> List[int]:
    """Per-layer CRC-32s for a stacked table — one checksum per slice along
    ``axis``, so verification localizes a breach to the layer that must be
    demoted.  Dense stacks are layer-major (``[L, G, V, O]``, ``axis=0``);
    paired stacks are segment-major (``[G2, L, V**2, O]``, ``axis=1``)."""
    a = np.asarray(arr)
    if axis:
        a = np.moveaxis(a, axis, 0)
    return [table_checksum(a[i]) for i in range(a.shape[0])]
