"""repro.core — the paper's contribution: PCILT.

Pre-Calculated Inference Lookup Tables (Gatchev & Mollov, 2021): with
low-cardinality activations, pre-compute every possible convolution partial
product into tables and *fetch* at inference time instead of multiplying.

Submodules: quantization (code grids + STE), offsets (activation->offset
packing, ext. 1), pcilt (table builders, ext. 2/3), lut_layers (inference
layers with gather / one-hot-MXU / Pallas paths), learnable (ext. 4).
"""

from .quantization import (
    QuantSpec,
    calibrate,
    scale_from_amax,
    quantize,
    quantize_with_stats,
    dequantize,
    fake_quant,
    code_values,
)
from .offsets import pack_offsets, unpack_offsets, offset_grid, SegmentPlan
from .pcilt import (
    mul_fn,
    log_mul_fn,
    build_scalar_tables,
    build_grouped_tables,
    SharedTables,
    build_shared_tables,
    SharedGroupedTables,
    build_shared_grouped_tables,
    ShardedSharedPool,
    shard_shared_grouped_tables,
    table_bytes,
    grouped_table_bytes,
    shared_table_bytes,
    shared_pool_bytes,
    build_cost_multiplies,
    table_checksum,
    stacked_checksums,
)
from .lut_layers import (
    lut_lookup,
    pcilt_linear,
    pcilt_conv2d,
    pcilt_depthwise_conv1d,
    build_dwconv_tables,
    im2col,
    conv_same_pads,
    mesh_shard_count,
)
from .learnable import (
    init_learnable_pcilt,
    apply_learnable_pcilt,
    effective_tables,
    extract_filters,
)
