"""Low-cardinality quantization for PCILT.

The paper's precondition is "low-cardinality activations": an activation can
only take ``K = 2**bits`` distinct values, so the product space
``{f(w, a) : a in codes}`` is enumerable and can be pre-calculated into a
lookup table.

This module provides the quantizers that produce those codes:

* symmetric / asymmetric affine quantization at 1..8 bits,
* absmax calibration,
* a straight-through estimator (STE) so quantized layers remain trainable
  (needed by the paper's "Using PCILTs as Weights" extension, and by
  quantization-aware training of the serving path).

Codes are always *unsigned* integers in ``[0, K)`` — in the paper they are the
table offsets, so an unsigned representation is the natural one.  The value a
code represents is ``(code - zero_point) * scale``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "calibrate",
    "scale_from_amax",
    "quantize",
    "quantize_with_stats",
    "dequantize",
    "fake_quant",
    "code_values",
]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantization grid.

    Attributes:
      bits: bit-width; cardinality is ``2**bits``.  The paper's sweet spot is
        ``bits <= 4`` ("many CNNs would be able to perform adequately with
        activation cardinality up to INT4"); ``bits == 1`` is the BoolHash
        boolean case.
      symmetric: if True the grid is centered (zero_point = (K-1)/2 rounded
        for signed data); if False the grid spans ``[0, K)`` with zero_point 0
        (natural for post-ReLU activations, which is the common CNN case).
    """

    bits: int = 4
    symmetric: bool = False

    def __post_init__(self):
        if not (1 <= self.bits <= 8):
            raise ValueError(f"PCILT targets 1..8 bit cardinality, got {self.bits}")
        if self.bits == 1 and self.symmetric:
            # a 2-value affine grid cannot straddle zero symmetrically; the
            # paper's boolean case is the asymmetric {0, 1} grid.
            raise ValueError("1-bit quantization must be asymmetric (boolean)")

    @property
    def cardinality(self) -> int:
        return 1 << self.bits

    @property
    def zero_point(self) -> int:
        # Symmetric grids put zero mid-range so negative activations are
        # representable; asymmetric grids are for non-negative data.
        return (self.cardinality // 2) if self.symmetric else 0

    @property
    def storage_dtype(self):
        return jnp.uint8  # all supported cardinalities fit a byte


def scale_from_amax(amax, spec: QuantSpec) -> jax.Array:
    """Observed absmax -> quantization scale on ``spec``'s grid.

    The single source of the amax-to-scale convention (span and epsilon
    clamp): :func:`calibrate` applies it to a tensor's observed range, and
    calibration passes that collect absmax statistics themselves — e.g. the
    per-layer decode-projection calibration in
    ``core.serving.convert_mamba_decode`` — apply it to their accumulators,
    so the scales they derive are exactly the scales ``quantize`` /
    ``fake_quant`` consume.
    """
    if spec.symmetric:
        # codes cover [-zp, K-1-zp]; bound by the smaller side magnitude.
        span = max(spec.cardinality - 1 - spec.zero_point, 1)
    else:
        span = spec.cardinality - 1
    return jnp.maximum(jnp.asarray(amax), 1e-8) / span


def calibrate(x: jax.Array, spec: QuantSpec, axis=None) -> jax.Array:
    """Absmax scale so that the observed range maps onto the code grid.

    Returns ``scale`` such that ``x / scale`` lands in the representable
    integer range.  ``axis`` permits per-channel calibration.
    """
    if spec.symmetric:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    else:
        amax = jnp.max(jnp.maximum(x, 0.0), axis=axis, keepdims=axis is not None)
    return scale_from_amax(amax, spec)


def quantize(x: jax.Array, spec: QuantSpec, scale) -> jax.Array:
    """Real values -> integer codes in ``[0, K)`` (uint8)."""
    q = jnp.round(x / scale) + spec.zero_point
    q = jnp.clip(q, 0, spec.cardinality - 1)
    return q.astype(spec.storage_dtype)


def quantize_with_stats(x: jax.Array, spec: QuantSpec, scale):
    """:func:`quantize` plus the saturation statistics the clip discards.

    Returns ``(codes, count, ratio)``: ``codes`` exactly as :func:`quantize`
    produces them, ``count`` the int32 number of elements whose *pre-clip*
    code ``round(x / scale) + zero_point`` fell outside ``[0, K)`` (i.e. the
    elements silently clamped to the table edge), and ``ratio`` the f32
    ``max(|x|) / scale`` — how far the observed range overshoots (``> 1``
    once activations exceed the calibrated absmax on a symmetric grid).

    This is the host-reference oracle for the in-kernel saturation counters
    of the fused fetch kernels: an element is *saturated* iff its rounded
    code leaves the grid, so a value landing exactly on the clip edge is in
    range.  Calibration drift (longer prompts, new domains, drifting
    recurrent state) shows up here long before outputs visibly degrade —
    the clip in :func:`quantize` is silent by design, and these stats are
    the only signal it emits.
    """
    # Identical arithmetic (dtype included) to quantize: the pre-clip code is
    # the same value quantize clamps, so codes here are bit-identical to
    # quantize's and the saturation predicate is exact, not approximate.
    q = jnp.round(x / scale) + spec.zero_point
    sat = (q < 0) | (q > spec.cardinality - 1)
    codes = jnp.clip(q, 0, spec.cardinality - 1).astype(spec.storage_dtype)
    count = jnp.sum(sat, dtype=jnp.int32)
    ratio = (jnp.max(jnp.abs(x)) / jnp.asarray(scale, x.dtype)).astype(
        jnp.float32)
    return codes, count, ratio


def dequantize(codes: jax.Array, spec: QuantSpec, scale, dtype=jnp.float32) -> jax.Array:
    """Integer codes -> real values on the quantization grid."""
    return (codes.astype(dtype) - spec.zero_point) * jnp.asarray(scale, dtype)


def code_values(spec: QuantSpec, scale, dtype=jnp.float32) -> jax.Array:
    """The ``K`` real values the grid can represent, indexed by code.

    This is the axis along which every PCILT is laid out: table entry ``T[a]``
    holds ``f(w, code_values()[a])``.
    """
    codes = jnp.arange(spec.cardinality, dtype=jnp.int32)
    return dequantize(codes, spec, scale, dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, spec: QuantSpec, scale) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient.

    Used for quantization-aware training and for the activations feeding
    learnable PCILTs (extension 4): forward sees grid values, backward passes
    the gradient straight through inside the clip range.
    """
    return dequantize(quantize(x, spec, scale), spec, scale, x.dtype)


def _fq_fwd(x, spec, scale):
    lo = (0 - spec.zero_point) * scale
    hi = (spec.cardinality - 1 - spec.zero_point) * scale
    return fake_quant(x, spec, scale), (x, lo, hi)


def _fq_bwd(spec, res, g):
    x, lo, hi = res
    mask = ((x >= lo) & (x <= hi)).astype(g.dtype)
    return (g * mask, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)
