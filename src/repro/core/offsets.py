"""Activation -> PCILT-offset pre-processing (paper extension 1, Figs. 5-7).

A PCILT *offset* is the integer address into a lookup table.  In the basic
algorithm the offset is a single activation code.  The extension packs ``g``
codes of cardinality ``K = 2**bits`` into one offset in ``[0, K**g)`` so a
single fetch retrieves the pre-summed partial dot-product of a whole filter
segment — the paper's BoolHash instance packs 8 booleans into an 8-bit offset.

On the paper's ASIC this packing is "separate circuitry ... through fast
operations (bit shifting and masking)".  On TPU we do exactly that on the VPU:
left-shifts and adds when ``K`` is a power of two (always true here).

The generalized form (paper: "activations ... a bitstream that can be
reprocessed into PCILT offsets in any needed way", Fig. 7) is expressed by a
``SegmentPlan``: an index map that may group *non-adjacent* positions, skip
positions entirely, or use one position in several segments.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .quantization import QuantSpec

__all__ = ["pack_offsets", "unpack_offsets", "offset_grid", "SegmentPlan"]


def _group_count(n: int, g: int) -> int:
    if n % g != 0:
        raise ValueError(f"reduction length {n} not divisible by group size {g}")
    return n // g


def pack_offsets(codes: jax.Array, bits: int, group: int) -> jax.Array:
    """Pack the trailing axis of ``codes`` into offsets, ``group`` at a time.

    codes: integer codes in [0, 2**bits), shape ``[..., n]`` with ``n % group == 0``.
    Returns offsets of shape ``[..., n // group]`` with values in
    ``[0, 2**(bits*group))``, packed little-endian (slot ``j`` occupies bits
    ``[j*bits, (j+1)*bits)``) via shift-or — the paper's shift/mask circuitry.
    """
    if bits * group > 30:
        raise ValueError(f"offset width {bits * group} bits exceeds int32 packing")
    n = codes.shape[-1]
    G = _group_count(n, group)
    c = codes.astype(jnp.int32).reshape(*codes.shape[:-1], G, group)
    shifts = (jnp.arange(group, dtype=jnp.int32) * bits)[(None,) * (c.ndim - 1)]
    return jnp.sum(jnp.left_shift(c, shifts), axis=-1).astype(jnp.int32)


def unpack_offsets(offsets: jax.Array, bits: int, group: int) -> jax.Array:
    """Inverse of :func:`pack_offsets`: ``[..., G] -> [..., G*group]`` codes."""
    mask = (1 << bits) - 1
    shifts = jnp.arange(group, dtype=jnp.int32) * bits
    codes = jnp.bitwise_and(
        jnp.right_shift(offsets[..., None], shifts[(None,) * offsets.ndim]), mask
    )
    return codes.reshape(*offsets.shape[:-1], offsets.shape[-1] * group)


def offset_grid(bits: int, group: int) -> jax.Array:
    """All ``K**group`` offsets unpacked into their per-slot codes.

    Shape ``[K**group, group]`` — row ``v`` holds the ``group`` activation
    codes whose packed offset equals ``v``.  This is the enumeration the table
    builder convolves with a weight segment (paper Fig. 5).
    """
    n_off = 1 << (bits * group)
    return unpack_offsets(jnp.arange(n_off, dtype=jnp.int32)[:, None], bits, group)


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """Generalized activation->segment mapping (paper Fig. 7).

    ``index[G, group]`` gives, for each segment slot, which flattened input
    position feeds it; ``-1`` marks an unused slot (reads as code 0 with a
    zero weight — the paper's "zero values are omitted").  A position may
    appear in more than one segment ("weights ... used in segments more than
    once", weighting it beyond the nominal filter range), and positions may be
    skipped entirely ("eliminating non-important filter positions").
    """

    index: np.ndarray  # int32 [G, group]

    @staticmethod
    def contiguous(n: int, group: int) -> "SegmentPlan":
        G = _group_count(n, group)
        return SegmentPlan(np.arange(n, dtype=np.int32).reshape(G, group))

    @property
    def n_segments(self) -> int:
        return self.index.shape[0]

    @property
    def group(self) -> int:
        return self.index.shape[1]

    def gather_codes(self, codes: jax.Array) -> jax.Array:
        """``[..., n] -> [..., G, group]`` codes per segment slot (skips -> 0)."""
        idx = jnp.asarray(np.where(self.index < 0, 0, self.index))
        g = jnp.take(codes, idx.reshape(-1), axis=-1)
        g = g.reshape(*codes.shape[:-1], *self.index.shape)
        return jnp.where(jnp.asarray(self.index >= 0), g, 0)

    def gather_weights(self, w: jax.Array) -> jax.Array:
        """``[n, ...] -> [G, group, ...]`` weight per segment slot (skips -> 0)."""
        idx = jnp.asarray(np.where(self.index < 0, 0, self.index))
        g = jnp.take(w, idx.reshape(-1), axis=0)
        g = g.reshape(*self.index.shape, *w.shape[1:])
        mask = jnp.asarray(self.index >= 0).reshape(
            *self.index.shape, *([1] * (w.ndim - 1))
        )
        return jnp.where(mask, g, 0)

    def pack(self, codes: jax.Array, bits: int) -> jax.Array:
        """Codes ``[..., n] -> offsets [..., G]`` following the plan."""
        seg = self.gather_codes(codes).astype(jnp.int32)
        shifts = (jnp.arange(self.group, dtype=jnp.int32) * bits)[
            (None,) * (seg.ndim - 1)
        ]
        return jnp.sum(jnp.left_shift(seg, shifts), axis=-1).astype(jnp.int32)
