"""Extension 4 — "Using PCILTs as Weights".

The table entries themselves become the learnable parameters: backpropagation
adjusts PCILT values instead of (or on top of) filter weights, "bringing a
similarity to the BNNs which do not have segregation between pattern and
input weights".  Parameter count decouples from inference compute — a bigger
table costs memory, never FLOPs.

The paper names four adjustment granularities; we parameterize the effective
table as ``T_eff = (base + offset_delta) * table_scale * filter_scale + entry_delta``
and expose each granularity as which factor is trainable:

* ``filter``  — one scalar per output filter (≡ classic input-weight multiply);
* ``table``   — one scalar per (segment, output) table (≡ adjusting the filter
                weights of that segment);
* ``offset``  — one delta per offset, shared across all tables of the filter
                (≡ per-activation-value filter adjustment);
* ``entry``   — every table cell free (maximal selectivity).

Gradients flow through the fetch: ``take_along_axis`` scatter-adds into the
table cells that were actually addressed, which is precisely the paper's
"accounting for the backpropagation result for the specific activation values
translating to this PCILT value".  Activations pass through an STE quantizer.

``extract_filters`` reconstructs classic weights from a trained table by
least squares — the paper's "analyze the final PCILT values and build back
from them weight-adjusted input filters".
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .quantization import QuantSpec, quantize, fake_quant
from .offsets import pack_offsets, offset_grid
from .pcilt import build_grouped_tables
from .lut_layers import lut_lookup

__all__ = ["init_learnable_pcilt", "apply_learnable_pcilt", "effective_tables",
           "extract_filters"]

GRANULARITIES = ("filter", "table", "offset", "entry")


def init_learnable_pcilt(
    key: jax.Array,
    n_in: int,
    n_out: int,
    spec: QuantSpec,
    scale: float,
    group: int,
    granularity: str = "entry",
    base_weights: Optional[jax.Array] = None,
    dtype=jnp.float32,
) -> Dict[str, jax.Array]:
    """Create params.  ``base`` comes from real weights when given (warm start),
    else random — the paper notes entries "can even be generated randomly"."""
    if granularity not in GRANULARITIES:
        raise ValueError(f"granularity must be one of {GRANULARITIES}")
    G = -(-n_in // group)
    V = 1 << (spec.bits * group)
    if base_weights is None:
        base_weights = jax.random.normal(key, (G * group, n_out), dtype) * (
            1.0 / jnp.sqrt(n_in)
        )
    pad = G * group - base_weights.shape[0]
    if pad:
        base_weights = jnp.concatenate(
            [base_weights, jnp.zeros((pad, n_out), dtype)], 0
        )
    base = build_grouped_tables(base_weights, spec, scale, group, dtype=dtype)
    params = {"base": base}
    if granularity == "filter":
        params["filter_scale"] = jnp.ones((n_out,), dtype)
    elif granularity == "table":
        params["table_scale"] = jnp.ones((G, n_out), dtype)
    elif granularity == "offset":
        params["offset_delta"] = jnp.zeros((V,), dtype)
    elif granularity == "entry":
        params["entry_delta"] = jnp.zeros((G, V, n_out), dtype)
    return params


def effective_tables(params: Dict[str, jax.Array]) -> jax.Array:
    """Combine base + adjustment into the table the fetch path uses."""
    t = params["base"]
    if "offset_delta" in params:
        t = t + params["offset_delta"][None, :, None]
    if "table_scale" in params:
        t = t * params["table_scale"][:, None, :]
    if "filter_scale" in params:
        t = t * params["filter_scale"][None, None, :]
    if "entry_delta" in params:
        t = t + params["entry_delta"]
    return t


def apply_learnable_pcilt(
    params: Dict[str, jax.Array],
    x: jax.Array,
    spec: QuantSpec,
    scale: float,
    group: int,
    path: str = "gather",
) -> jax.Array:
    """Forward pass ``[..., n_in] -> [..., n_out]``, differentiable end to end."""
    tables = effective_tables(params)
    G, V, O = tables.shape
    n = G * group
    pad = n - x.shape[-1]
    if pad:
        x = jnp.concatenate([x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], -1)
    # STE so upstream layers keep training through the quantizer.
    xq = fake_quant(x, spec, scale)
    codes = quantize(jax.lax.stop_gradient(xq), spec, scale)
    offsets = pack_offsets(codes, spec.bits, group)
    y = lut_lookup(tables, offsets, path=path)
    # Straight-through for x: d y / d x ≈ sum of the addressed weights — we
    # approximate with the STE-quantized linearization via a surrogate matmul
    # on the *stopped* tables' reconstructed filters.
    return y


def extract_filters(
    tables: jax.Array, spec: QuantSpec, scale: float, group: int
) -> jax.Array:
    """Least-squares reconstruction of classic filters from (trained) tables.

    Solves ``min_w || vals @ w_seg - T_seg ||``  per segment, where ``vals`` is
    the [V, group] matrix of unpacked offset values.  For tables that are an
    exact product construction this recovers the original weights exactly.
    Returns ``[G*group, out]``.
    """
    from .quantization import code_values

    G, V, O = tables.shape
    vals = code_values(spec, scale)[offset_grid(spec.bits, group)]  # [V, g]
    pinv = jnp.linalg.pinv(vals)  # [g, V]
    w_seg = jnp.einsum("gv,svo->sgo", pinv, tables)  # [G, g, O]
    return w_seg.reshape(G * group, O)
