"""Sharded, elastic, async checkpointing.

Layout on disk (one directory per step, atomic rename on completion):

    <root>/step_000100.tmp/ -> <root>/step_000100/
        manifest.json       # tree structure, shapes, dtypes, checksums
        shard_p0.npz        # this process's arrays (single flat npz per host)

Elasticity: the manifest stores *logical* array metadata only — restore
targets any mesh: arrays are loaded on host and ``jax.device_put`` with the
*new* mesh's NamedShardings (from the same logical-axis rules), so a run
checkpointed on a 256-chip pod resumes on 512 chips (or 8 CPU devices in the
tests) without a conversion step.

Async: ``save_async`` snapshots to host memory synchronously (cheap) and
writes in a daemon thread; ``wait()`` fences.  A failure mid-write never
corrupts the previous checkpoint (tmp-dir + rename).
"""

from __future__ import annotations

import json
import hashlib
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np
import jax

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]

_MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree):
    from repro.compat import tree_flatten_with_path

    flat, _ = tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]


def save(root: str, step: int, tree, extra: Optional[Dict[str, Any]] = None):
    """Synchronous checkpoint write with atomic rename."""
    leaves, _ = _flatten(tree)
    names = _paths(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    npz_path = os.path.join(tmp, "shard_p0.npz")
    np.savez(npz_path, **{f"a{i}": a for i, a in enumerate(host)})
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "names": names,
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "sha256": digest,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(root: str, step: int, like_tree, shardings=None, verify: bool = True):
    """Load a checkpoint into the structure of ``like_tree``.

    shardings: optional matching pytree of NamedShardings (the *current*
    mesh's) — this is the elastic re-mesh path.  Returns (tree, extra).
    """
    d = os.path.join(root, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    npz_path = os.path.join(d, "shard_p0.npz")
    if verify:
        digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {d} corrupt: sha mismatch")
    data = np.load(npz_path)
    leaves, treedef = _flatten(like_tree)
    names = _paths(like_tree)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch:\n saved: %s...\n want: %s..."
            % (manifest["names"][:4], names[:4]))
    arrays = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        out = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        out = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, out), manifest["extra"]


class Checkpointer:
    """Async wrapper with retention."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()
        # snapshot to host synchronously so training can mutate buffers
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snap = jax.tree.unflatten(treedef, host)

        def _write():
            save(self.root, step, snap, extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        step = latest_step(self.root)
        if step is None:
            return None, None, None
        tree, extra = restore(self.root, step, like_tree, shardings)
        return step, tree, extra
