"""Sharded / elastic / async checkpointing."""
from .checkpoint import save, restore, latest_step, Checkpointer
