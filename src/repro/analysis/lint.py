"""AST kernel lint for ``src/repro``.

Four rules, each encoding a convention the kernel zoo depends on but that
only dynamic tests exercised before this pass existed:

* **LINT001 bare-assert** — no bare ``assert`` in library code.  Typed
  ``ValueError`` naming the offending shapes is the repo convention (PR 5):
  asserts vanish under ``python -O`` and their bare-tuple messages tell a
  caller nothing.
* **LINT002 kernel-f32-accum** — every ``jnp.dot`` / ``lax.dot_general`` /
  matmul reachable from a Pallas kernel body must pass
  ``preferred_element_type=jnp.float32``.  bf16 tables are a supported
  storage dtype; a contraction that accumulates in the table dtype rounds
  the adder tree through bf16 on every grid step and breaks the
  parity-with-oracle contract silently.
* **LINT003 kernel-host-call** — no Python side effects or host calls
  (``print``/``open``/``os.*``/``np.*``/...) inside kernel bodies or
  BlockSpec ``index_map``s.  These either crash at trace time in ways that
  depend on which shapes compile first, or — worse — get constant-folded
  into the kernel and silently diverge from per-step semantics.
* **LINT004 autotune-key-completeness** — every ``ops.py`` dispatch site
  must key the ``TileCache`` on every shape symbol its candidate generator
  consumes.  A generator argument that does not reach the shape key means
  two different problems share one cache entry and dispatch each other's
  tiles.  Cross-checked from both directions: the call site's argument
  expressions are root-expanded through local assignments and compared
  against the key's expressions, and the generator's *signature* (via
  ``inspect.signature`` on ``kernels.autotune``) pins the parameter names so
  a generator growing a new shape parameter fires here until the key learns
  it.

Kernel bodies are discovered, not declared: any function passed (directly or
via ``functools.partial``) as the kernel argument of a ``pl.pallas_call`` is
a root, and the reachable set is closed transitively over same-package
helper calls (``_quantize`` / ``_strip_offsets`` / ... — including helpers
imported from sibling kernel modules).  Index maps are the lambdas (or
``index_map=`` arguments) of ``pl.BlockSpec`` calls.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import Finding, rel

__all__ = ["RULES", "lint_tree", "lint_files"]

RULES: Dict[str, str] = {
    "LINT001": "bare assert in library code (use a typed ValueError naming "
               "the offending shapes)",
    "LINT002": "contraction inside a Pallas kernel body without "
               "preferred_element_type=jnp.float32",
    "LINT003": "Python side effect / host call inside a Pallas kernel body "
               "or BlockSpec index_map",
    "LINT004": "autotune shape key misses a shape symbol the candidate "
               "generator consumes",
}

#: names whose *call* in a kernel body is a host-side effect.
_HOST_CALLS = {
    "print", "open", "input", "breakpoint", "exec", "eval", "compile",
    "setattr", "delattr", "globals", "locals", "vars", "id", "hash",
}
#: module roots whose attribute calls inside a kernel body run on the host.
_HOST_MODULES = {
    "os", "sys", "io", "json", "time", "logging", "random", "np", "numpy",
    "subprocess", "pathlib", "pickle", "socket", "threading", "warnings",
}
#: candidate-generator parameters that deliberately do not enter the shape
#: key: the scratch budget is a global constant, and dtype/itemsize enter
#: the key through its dedicated ``dtype=`` field.
_KEY_EXEMPT_PARAMS = {"scratch_budget", "itemsize"}


def _dot(node: ast.AST) -> str:
    """Render a Name/Attribute chain as 'a.b.c' ('' when not a pure chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _names(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _Module:
    """One parsed source file plus the lookup tables the rules need."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        #: top-level (and class-level) function defs by name
        self.functions: Dict[str, ast.FunctionDef] = {}
        #: from-import links: local name -> (module, remote name)
        self.imports: Dict[str, Tuple[str, str]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = (
                        node.module, alias.name)


def _parse(paths: Iterable[str]) -> Dict[str, _Module]:
    mods: Dict[str, _Module] = {}
    for path in paths:
        with open(path) as f:
            src = f.read()
        mods[path] = _Module(path, ast.parse(src, filename=path))
    return mods


# ----------------------------------------------------------------------------
# Kernel-body discovery: pallas_call roots + transitive helper closure
# ----------------------------------------------------------------------------


def _kernel_arg_name(call: ast.Call) -> Optional[str]:
    """The kernel function's name in ``pl.pallas_call(<kernel>, ...)`` —
    either a bare name or the first argument of a ``functools.partial``."""
    if not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Call) and _dot(arg.func) in (
            "functools.partial", "partial") and arg.args:
        arg = arg.args[0]
    if isinstance(arg, ast.Name):
        return arg.id
    return None


def _kernel_roots(mod: _Module) -> Set[str]:
    roots: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and _dot(node.func).endswith(
                "pallas_call"):
            name = _kernel_arg_name(node)
            if name:
                roots.add(name)
    return roots


def _reachable_kernel_fns(
    mods: Dict[str, _Module],
) -> Dict[str, List[Tuple[_Module, ast.FunctionDef]]]:
    """Close the kernel roots over same-package helper calls.

    Returns ``{qualified_name: [(module, fndef)]}`` for every function whose
    body executes inside a Pallas kernel.  Imported helpers are followed
    through ``from .x import y`` links by matching the *source* module's
    basename, so ``pcilt_shared``'s use of ``_strip_offsets`` resolves back
    into ``pcilt_fused.py``.
    """
    by_basename: Dict[str, List[_Module]] = {}
    for mod in mods.values():
        base = os.path.splitext(os.path.basename(mod.path))[0]
        by_basename.setdefault(base, []).append(mod)

    seen: Dict[str, List[Tuple[_Module, ast.FunctionDef]]] = {}
    work: List[Tuple[_Module, str]] = []
    for mod in mods.values():
        for name in _kernel_roots(mod):
            work.append((mod, name))

    def resolve(mod: _Module, name: str
                ) -> Optional[Tuple[_Module, ast.FunctionDef]]:
        if name in mod.functions:
            return mod, mod.functions[name]
        if name in mod.imports:
            src_mod, remote = mod.imports[name]
            base = src_mod.rsplit(".", 1)[-1]
            for cand in by_basename.get(base, ()):
                if remote in cand.functions:
                    return cand, cand.functions[remote]
        return None

    while work:
        mod, name = work.pop()
        hit = resolve(mod, name)
        if hit is None:
            continue
        fmod, fdef = hit
        qual = f"{fmod.path}::{fdef.name}"
        if qual in seen:
            continue
        seen[qual] = [(fmod, fdef)]
        for node in ast.walk(fdef):
            if isinstance(node, ast.Call):
                callee = _dot(node.func)
                if callee and "." not in callee:
                    work.append((fmod, callee))
    return seen


def _index_map_nodes(mod: _Module) -> List[ast.AST]:
    """The ``index_map`` functions of every ``pl.BlockSpec`` in the module:
    the second positional argument, or the ``index_map=`` keyword."""
    out: List[ast.AST] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and _dot(node.func).endswith("BlockSpec")):
            continue
        if len(node.args) >= 2:
            out.append(node.args[1])
        for kw in node.keywords:
            if kw.arg == "index_map":
                out.append(kw.value)
    return out


# ----------------------------------------------------------------------------
# LINT001 — bare assert
# ----------------------------------------------------------------------------


def _check_bare_assert(mod: _Module, root: str) -> List[Finding]:
    out = []
    enclosing: Dict[int, str] = {}
    for fn in ast.walk(mod.tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(fn):
                enclosing.setdefault(id(sub), fn.name)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assert):
            cond = ast.unparse(node.test)
            out.append(Finding(
                "LINT001", "error", rel(mod.path, root), node.lineno,
                f"bare assert ({cond!r}) in library code; raise a typed "
                f"ValueError naming the offending shapes instead",
                symbol=enclosing.get(id(node), "<module>")))
    return out


# ----------------------------------------------------------------------------
# LINT002 — f32 accumulation in kernel bodies
# ----------------------------------------------------------------------------

_DOT_CALLEES = ("jnp.dot", "jnp.matmul", "lax.dot_general",
                "jax.lax.dot_general", "jnp.einsum", "jax.numpy.dot",
                "jax.numpy.matmul", "jax.numpy.einsum")


def _is_f32_pref(kw_value: ast.AST) -> bool:
    return _dot(kw_value) in ("jnp.float32", "jax.numpy.float32",
                              "np.float32", "numpy.float32")


def _check_f32_accum(mod: _Module, fdef: ast.FunctionDef,
                     root: str) -> List[Finding]:
    out = []
    for node in ast.walk(fdef):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            out.append(Finding(
                "LINT002", "error", rel(mod.path, root), node.lineno,
                "matmul operator '@' in a Pallas kernel body cannot request "
                "f32 accumulation; use jnp.dot(..., "
                "preferred_element_type=jnp.float32)",
                symbol=fdef.name))
        if not isinstance(node, ast.Call):
            continue
        callee = _dot(node.func)
        if callee not in _DOT_CALLEES:
            continue
        pref = [kw for kw in node.keywords
                if kw.arg == "preferred_element_type"]
        if not pref:
            out.append(Finding(
                "LINT002", "error", rel(mod.path, root), node.lineno,
                f"{callee} in a Pallas kernel body without "
                f"preferred_element_type=jnp.float32; bf16 tables would "
                f"round the adder tree through bf16 every grid step",
                symbol=fdef.name))
        elif not _is_f32_pref(pref[0].value):
            out.append(Finding(
                "LINT002", "error", rel(mod.path, root), node.lineno,
                f"{callee} in a Pallas kernel body accumulates in "
                f"{ast.unparse(pref[0].value)}, not jnp.float32",
                symbol=fdef.name))
    return out


# ----------------------------------------------------------------------------
# LINT003 — host calls / side effects in kernel bodies and index maps
# ----------------------------------------------------------------------------


def _check_host_calls(mod: _Module, body: ast.AST, symbol: str,
                      root: str, where: str) -> List[Finding]:
    out = []
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        callee = _dot(node.func)
        if not callee:
            continue
        head = callee.split(".", 1)[0]
        if callee in _HOST_CALLS or head in _HOST_MODULES:
            out.append(Finding(
                "LINT003", "error", rel(mod.path, root), node.lineno,
                f"host call {callee!r} inside a {where}; kernel bodies and "
                f"index maps must be pure traced functions",
                symbol=symbol))
        elif isinstance(node.func, ast.Name) and node.func.id == "getattr":
            out.append(Finding(
                "LINT003", "error", rel(mod.path, root), node.lineno,
                f"dynamic getattr inside a {where}", symbol=symbol))
    for node in ast.walk(body):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            out.append(Finding(
                "LINT003", "error", rel(mod.path, root), node.lineno,
                f"global/nonlocal mutation inside a {where}", symbol=symbol))
    return out


# ----------------------------------------------------------------------------
# LINT004 — autotune-key completeness at ops dispatch sites
# ----------------------------------------------------------------------------


class _RootExpander:
    """Expand a variable of one function body to its *root atoms*.

    Atoms are the irreducible shape sources of a dispatch function:

    * ``('dim', base, i)`` — ``B = x.shape[0]`` or ``B, n = x.shape``;
    * ``('name', n)`` — a function parameter or otherwise opaque name.

    Arithmetic assignments expand transitively (``Wo = (Wp - kw) // s + 1``
    roots to ``{('dim', x, 2), ('name', kw), ('name', s)}``); tuple-returns
    from helper calls (``xp, _ = _pad_axis(x, ...)``) expand to the call
    arguments' roots.  This is what lets the rule accept a key that pins
    ``W``/``k``/``s`` when the generator consumes the derived ``Wo`` — and
    still fire when a generator argument's roots are wholly absent from the
    key.
    """

    def __init__(self, fdef: ast.FunctionDef):
        self.params = {a.arg for a in (fdef.args.posonlyargs + fdef.args.args
                                       + fdef.args.kwonlyargs)}
        #: name -> defining RHS expression (last one wins, in source order —
        #: good enough for the straight-line dispatch bodies this rule
        #: targets)
        self.defs: Dict[str, ast.AST] = {}
        #: name -> ('dim', base_name, index) for shape unpacks
        self.dims: Dict[str, Tuple[str, str, int]] = {}
        #: base name -> set of dim names unpacked from it
        self.shape_dims: Dict[str, Set[str]] = {}
        for node in ast.walk(fdef):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt, val = node.targets[0], node.value
            if isinstance(tgt, ast.Name):
                self._record(tgt.id, val, index=None)
            elif isinstance(tgt, ast.Tuple) and all(
                    isinstance(e, ast.Name) for e in tgt.elts):
                if (isinstance(val, ast.Attribute) and val.attr == "shape"):
                    base = _dot(val.value)
                    for i, e in enumerate(tgt.elts):
                        self.dims[e.id] = ("dim", base, i)
                        self.shape_dims.setdefault(base, set()).add(e.id)
                elif isinstance(val, ast.Tuple) and len(val.elts) == len(
                        tgt.elts):
                    for e, v in zip(tgt.elts, val.elts):
                        self._record(e.id, v, index=None)
                else:  # tuple-from-call: every target roots to the call args
                    for e in tgt.elts:
                        self.defs[e.id] = val

    def _record(self, name: str, val: ast.AST, index) -> None:
        # `B = x.shape[0]` / `O = tables.shape[-1]` -> dim atom
        if (isinstance(val, ast.Subscript)
                and isinstance(val.value, ast.Attribute)
                and val.value.attr == "shape"):
            base = _dot(val.value.value)
            idx = val.slice
            if isinstance(idx, ast.Constant) and isinstance(idx.value, int):
                self.dims[name] = ("dim", base, idx.value)
                self.shape_dims.setdefault(base, set()).add(name)
                return
            if (isinstance(idx, ast.UnaryOp) and isinstance(idx.op, ast.USub)
                    and isinstance(idx.operand, ast.Constant)):
                self.dims[name] = ("dim", base, -idx.operand.value)
                self.shape_dims.setdefault(base, set()).add(name)
                return
        self.defs[name] = val

    def roots_of_expr(self, expr: ast.AST, _depth: int = 0) -> Set[tuple]:
        out: Set[tuple] = set()
        for name in _names(expr):
            out |= self.roots_of_name(name, _depth)
        return out

    def roots_of_name(self, name: str, _depth: int = 0) -> Set[tuple]:
        if _depth > 12:  # cyclic defs (x = f(x)): stop at the name
            return {("name", name)}
        if name in self.dims:
            return {self.dims[name]}
        if name in self.defs:
            return self.roots_of_expr(self.defs[name], _depth + 1)
        return {("name", name)}


def _call_of(node: ast.AST, suffixes: Tuple[str, ...]) -> Optional[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            callee = _dot(sub.func)
            if any(callee.endswith(s) for s in suffixes):
                return sub
    return None


def _candidates_call(fdef: ast.FunctionDef) -> Optional[ast.Call]:
    return _call_of(fdef, ("_candidates",))


def _shape_key_call(fdef: ast.FunctionDef) -> Optional[ast.Call]:
    return _call_of(fdef, ("shape_key",))


def _check_autotune_keys(mod: _Module, root: str) -> List[Finding]:
    """Every dispatch function pairing a ``shape_key`` with a
    ``*_candidates`` call must key every shape symbol the generator
    consumes."""
    try:
        import inspect

        from repro.kernels import autotune as _atn
    except Exception:  # pragma: no cover - analysis must not hard-require jax
        _atn, inspect = None, None
    out: List[Finding] = []
    for fdef in mod.functions.values():
        key_call = _shape_key_call(fdef)
        cand_call = _candidates_call(fdef)
        if key_call is None or cand_call is None:
            continue
        gen_name = _dot(cand_call.func).rsplit(".", 1)[-1]
        exp = _RootExpander(fdef)

        # key side: every dim kwarg name, plus the root atoms of every kwarg
        # value expression (dtype= included — it covers itemsize arguments).
        key_dim_names = {kw.arg for kw in key_call.keywords if kw.arg}
        key_roots: Set[tuple] = set()
        for kw in key_call.keywords:
            key_roots |= exp.roots_of_expr(kw.value)

        def covered(atom: tuple) -> bool:
            if atom in key_roots:
                return True
            if atom[0] == "name":
                # a whole-array parameter is covered when every dim unpacked
                # from its .shape is itself keyed (directly or via derived
                # key expressions like To = x.shape[1] - k + 1)
                dims = exp.shape_dims.get(atom[1])
                if dims:
                    return all(
                        all(covered(a) for a in exp.roots_of_name(d))
                        or ("dim", atom[1], i) in key_roots
                        for i, d in enumerate(sorted(dims)))
            return False

        # generator side: bind call-site args to the generator's signature
        # so violations name the parameter, not an argument position.
        params: List[str] = []
        if _atn is not None and hasattr(_atn, gen_name):
            sig = inspect.signature(getattr(_atn, gen_name))
            params = list(sig.parameters)
        bound: List[Tuple[str, ast.AST]] = []
        for i, arg in enumerate(cand_call.args):
            bound.append((params[i] if i < len(params) else f"arg{i}", arg))
        for kw in cand_call.keywords:
            if kw.arg:
                if params and kw.arg not in params:
                    out.append(Finding(
                        "LINT004", "error", rel(mod.path, root),
                        cand_call.lineno,
                        f"{gen_name} has no parameter {kw.arg!r} "
                        f"(signature introspection)", symbol=fdef.name))
                    continue
                bound.append((kw.arg, kw.value))

        for pname, arg in bound:
            if pname in _KEY_EXEMPT_PARAMS:
                continue
            # itemsize-style args (x.dtype.itemsize) are covered by dtype=
            if isinstance(arg, ast.Attribute) and arg.attr == "itemsize":
                continue
            # parameter name matching a key dim is the common, legible case
            if pname in key_dim_names:
                continue
            if isinstance(arg, ast.Name) and arg.id in key_dim_names:
                continue
            missing = sorted(
                str(a) for a in exp.roots_of_expr(arg) if not covered(a))
            if missing:
                out.append(Finding(
                    "LINT004", "error", rel(mod.path, root), cand_call.lineno,
                    f"candidate generator {gen_name} consumes parameter "
                    f"{pname!r} (arg {ast.unparse(arg)!r}) whose shape roots "
                    f"never reach the autotune shape key; two problems "
                    f"differing only in it would share a cache entry; "
                    f"missing roots: {missing}",
                    symbol=fdef.name))
    return out


# ----------------------------------------------------------------------------
# Driver
# ----------------------------------------------------------------------------


def lint_files(paths: Iterable[str], root: Optional[str] = None
               ) -> List[Finding]:
    """Lint an explicit set of python files (tests use this on fixture
    snippets); returns all findings."""
    mods = _parse(list(paths))
    out: List[Finding] = []
    kernel_fns = _reachable_kernel_fns(mods)
    kernel_by_mod: Dict[str, List[ast.FunctionDef]] = {}
    for entries in kernel_fns.values():
        for fmod, fdef in entries:
            kernel_by_mod.setdefault(fmod.path, []).append(fdef)
    for mod in mods.values():
        out.extend(_check_bare_assert(mod, root))
        for fdef in kernel_by_mod.get(mod.path, ()):
            out.extend(_check_f32_accum(mod, fdef, root))
            out.extend(_check_host_calls(mod, fdef, fdef.name, root,
                                         "Pallas kernel body"))
        for im in _index_map_nodes(mod):
            out.extend(_check_host_calls(mod, im, "<index_map>", root,
                                         "BlockSpec index_map"))
        out.extend(_check_autotune_keys(mod, root))
    return out


def lint_tree(src_root: str, root: Optional[str] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``src_root`` (the library tree — tests
    and benchmarks have different conventions and are not scanned)."""
    paths = []
    for dirpath, _dirnames, filenames in os.walk(src_root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                paths.append(os.path.join(dirpath, fn))
    return lint_files(paths, root=root)
