"""Static contract checker for the PCILT kernel zoo.

The paper's premise — tables resident in fast on-chip memory — makes two
things load-bearing that ordinary tests only exercise dynamically, on
whichever shapes they happen to run: the analytic VMEM scratch bound
(``kernels.autotune._fit_scratch_gb`` / ``SCRATCH_BUDGET``) and the kernel
shape/dtype contracts.  This package proves those invariants *statically*,
for every candidate configuration — never executing a kernel — via three
passes (see ``docs/static_analysis.md`` for the rule catalogue):

* :mod:`repro.analysis.lint` — AST lint over ``src/repro``: f32-accumulation
  inside Pallas kernel bodies, no bare ``assert`` in library code, no host
  calls / Python side effects in kernel bodies or BlockSpec index maps, and
  autotune-key completeness at every ``ops.py`` dispatch site.
* :mod:`repro.analysis.vmem` — static VMEM/grid verifier: enumerates each
  candidate generator over a recorded shape sweep and, by abstract tracing
  only (``jax.make_jaxpr`` — the kernel is *traced*, never run), proves the
  per-grid-step scratch respects ``SCRATCH_BUDGET``, the scratch model
  matches the real kernel body, and every BlockSpec ``index_map`` stays
  in-bounds and tiles its operand without gaps over the full grid.
* :mod:`repro.analysis.schema` — versioned schemas for the autotune cache
  JSON (``us`` null-or-float, shape-key grammar) and the checked-in
  ``BENCH_*.json`` artifacts (including ``skipped`` rows).

CLI: ``python -m repro.analysis`` — ``file:line: RULE severity: message``
findings, exit code 1 when any un-baselined error remains (the CI gate),
``--write-baseline`` to accept the current findings as exceptions.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional

__all__ = [
    "Finding",
    "Baseline",
    "repo_root",
    "rel",
    "run_all",
]

#: bumped when finding fingerprints or pass semantics change incompatibly.
ANALYSIS_VERSION = 1

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source (or artifact) location.

    ``symbol`` is the enclosing function / kernel / artifact key — it anchors
    the baseline fingerprint so accepted exceptions survive unrelated line
    drift in the same file.
    """

    rule: str            # e.g. "LINT001", "VMEM002", "SCHEMA001"
    severity: str        # "error" | "warning"
    path: str            # repo-relative where possible
    line: int            # 1-based; 0 for whole-file/artifact findings
    message: str
    symbol: str = ""     # enclosing def / kernel name / JSON key

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"finding severity must be one of {SEVERITIES}, "
                f"got {self.severity!r} for rule {self.rule}")

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline: rule + file +
        enclosing symbol + the first message clause (shape lists and config
        reprs after the first ';' are allowed to drift)."""
        head = self.message.split(";")[0].strip()
        return f"{self.rule}|{self.path}|{self.symbol}|{head}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{loc}: {self.rule} {self.severity}: {self.message}{sym}"


class Baseline:
    """Accepted-exception list: a JSON file of finding fingerprints.

    A finding whose fingerprint is listed is reported as baselined and does
    not affect the exit code.  The file records the analysis version so a
    fingerprint-scheme change invalidates stale baselines loudly rather than
    silently accepting everything.
    """

    def __init__(self, fingerprints: Iterable[str] = (), path: str = ""):
        self.path = path
        self.fingerprints = set(fingerprints)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(path=path)
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) or "accepted" not in data:
            raise ValueError(
                f"baseline {path} is not a {{'version', 'accepted': [...]}} "
                f"object")
        ver = data.get("version")
        if ver != ANALYSIS_VERSION:
            raise ValueError(
                f"baseline {path} was written for analysis version {ver}, "
                f"this is version {ANALYSIS_VERSION}; regenerate it with "
                f"--write-baseline")
        return cls(data["accepted"], path=path)

    @classmethod
    def write(cls, path: str, findings: Iterable[Finding]) -> "Baseline":
        fps = sorted({f.fingerprint() for f in findings})
        with open(path, "w") as f:
            json.dump({"version": ANALYSIS_VERSION, "accepted": fps},
                      f, indent=1, sort_keys=True)
            f.write("\n")
        return cls(fps, path=path)

    def accepts(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.fingerprints


def repo_root() -> str:
    """The repository root, resolved from this package's location
    (``<root>/src/repro/analysis`` -> ``<root>``)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def rel(path: str, root: Optional[str] = None) -> str:
    root = root or repo_root()
    try:
        r = os.path.relpath(os.path.abspath(path), root)
    except ValueError:  # different drive (windows); keep absolute
        return path
    return path if r.startswith("..") else r


def run_all(
    root: Optional[str] = None,
    passes: Iterable[str] = ("lint", "vmem", "schema"),
    sweep: str = "quick",
    scratch_budget: Optional[float] = None,
) -> List[Finding]:
    """Run the requested passes over the repository; returns all findings.

    ``sweep`` selects the VMEM verifier's shape sweep (``quick`` | ``full``);
    ``scratch_budget`` overrides ``autotune.SCRATCH_BUDGET`` for the
    soundness check (tests shrink it to prove the verifier rejects).
    """
    root = root or repo_root()
    passes = set(passes)
    unknown = passes - {"lint", "vmem", "schema"}
    if unknown:
        raise ValueError(f"unknown analysis passes: {sorted(unknown)} "
                         f"(valid: lint, vmem, schema)")
    findings: List[Finding] = []
    if "lint" in passes:
        from repro.analysis import lint
        findings.extend(lint.lint_tree(os.path.join(root, "src", "repro"),
                                       root=root))
    if "vmem" in passes:
        from repro.analysis import vmem
        findings.extend(vmem.verify_all(sweep=sweep,
                                        scratch_budget=scratch_budget))
    if "schema" in passes:
        from repro.analysis import schema
        findings.extend(schema.validate_repo_artifacts(root))
    order = {"error": 0, "warning": 1}
    findings.sort(key=lambda f: (order[f.severity], f.path, f.line, f.rule))
    return findings
