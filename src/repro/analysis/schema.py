"""Versioned schemas for the repo's checked-in JSON artifacts.

Two artifact families drift silently if nothing pins them:

* the **autotune cache** (``kernels/autotune.py`` ``TileCache``): a flat map
  of shape keys to ``{"tiles", "us", "candidates"}`` entries.  The key
  grammar (``kernel|dim=val,...,dtype=...|backend=...``) is load-bearing —
  ``ops.py`` dispatch, the sharded no-collision policy, and the VMEM
  verifier's sweep ingestion all parse it — and ``us`` is strict JSON
  (``null`` or a finite float, never a bare ``NaN`` token).
* the **benchmark payloads** (``BENCH_pr*.json``, written by
  ``benchmarks/run.py``): top-level metadata plus ``rows`` of
  ``{"name", "us_per_call", "derived"}`` — including ``skipped`` rows,
  which must carry both the row-level ``"skipped"`` reason and an entry in
  the top-level ``skipped`` map (the "never silently under-report" contract
  from PR 4).

Validation is hand-rolled (no jsonschema dependency — the container may not
ship it) and versioned: ``BENCH_SCHEMA_VERSION`` / ``CACHE_SCHEMA_VERSION``
gate additive evolution; loosening a rule requires bumping the version and
the rule catalogue in ``docs/static_analysis.md``.

Rules: ``SCHEMA001`` (BENCH file violation), ``SCHEMA002`` (autotune cache
violation).  Both are errors — CI fails when a checked-in artifact drifts.
"""

from __future__ import annotations

import glob
import json
import math
import os
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis import Finding, rel

__all__ = [
    "BENCH_SCHEMA_VERSION",
    "CACHE_SCHEMA_VERSION",
    "KNOWN_KERNELS",
    "parse_shape_key",
    "validate_bench",
    "validate_tune_cache",
    "validate_repo_artifacts",
]

BENCH_SCHEMA_VERSION = 1
CACHE_SCHEMA_VERSION = 1

#: kernel families that may appear in a shape key, with the dims each one is
#: required to carry (the autotune module's documented key grammar).  A key
#: may carry *extra* dims (additive evolution is allowed without a version
#: bump); missing a required dim is a violation.
KNOWN_KERNELS: Dict[str, Tuple[str, ...]] = {
    "gemv_host": ("B", "G", "V", "O"),
    "conv2d_host": ("B", "Ho", "Wo", "G", "V", "O"),
    "fused_gemv": ("B", "G", "V", "O", "g", "bits"),
    # stacked (serving) families key the decode-batch row count R explicitly
    # alongside B so the R-aware row-tile sweep is cached per slot count
    "fused_gemv_stacked": ("B", "R", "L", "G", "V", "O", "g", "bits"),
    # paired (TL1-style) families: G and V are paired-space (G/2 segment
    # pairs at V**2 entries); g/bits stay the unpaired build parameters
    "fused_gemv_paired": ("B", "G", "V", "O", "g", "bits"),
    "fused_gemv_paired_stacked": ("B", "R", "L", "G", "V", "O", "g", "bits"),
    "fused_gemv_plan": ("B", "G", "V", "O", "g", "bits"),
    # monitored (in-kernel saturation counter) variants: same tiled problem,
    # extra scalar outputs — they key identically to their base family but
    # cache separately (the counter reduction changes the winning tile)
    "fused_gemv_stacked_sat": ("B", "R", "L", "G", "V", "O", "g", "bits"),
    "fused_gemv_paired_sat": ("B", "G", "V", "O", "g", "bits"),
    "fused_gemv_paired_stacked_sat": ("B", "R", "L", "G", "V", "O", "g",
                                      "bits"),
    "fused_dwconv1d_sat": ("B", "T", "C", "V", "k", "bits"),
    "fused_conv2d": ("B", "Ho", "W", "C", "k", "s", "G", "V", "O", "g",
                     "bits"),
    "fused_dwconv1d": ("B", "T", "C", "V", "k", "bits"),
    "shared_gemv": ("B", "G", "V", "O", "X", "g", "bits"),
    "shared_conv2d": ("B", "Ho", "W", "C", "k", "s", "G", "V", "O", "X",
                      "g", "bits"),
}

_KEY_RE = re.compile(
    r"^(?P<kernel>[a-z0-9_]+)\|"
    r"(?P<dims>(?:[A-Za-z]\w*=[^,|]+,)*)"
    r"dtype=(?P<dtype>[^,|]+)"
    r"\|backend=(?P<backend>\w+)$")


def parse_shape_key(key: str) -> Tuple[str, Dict[str, int], str, str]:
    """Parse ``kernel|d1=v1,...,dtype=D|backend=B`` -> (kernel, dims, dtype,
    backend).  Raises ``ValueError`` naming the malformed piece."""
    m = _KEY_RE.match(key)
    if not m:
        raise ValueError(
            f"shape key does not match "
            f"'kernel|dim=val,...,dtype=<dtype>|backend=<backend>': {key!r}")
    dims: Dict[str, int] = {}
    dim_str = m.group("dims").rstrip(",")
    for part in filter(None, dim_str.split(",")):
        name, _, val = part.partition("=")
        try:
            dims[name] = int(val)
        except ValueError:
            raise ValueError(
                f"shape-key dim {name!r} has non-integer value {val!r} "
                f"in key {key!r}") from None
    return m.group("kernel"), dims, m.group("dtype"), m.group("backend")


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _finite_num(x) -> bool:
    return _is_num(x) and math.isfinite(x)


# ----------------------------------------------------------------------------
# Autotune cache schema
# ----------------------------------------------------------------------------

_TILE_FIELDS = ("Bb", "Gb", "Ob", "row_tile")


def validate_tune_cache(obj, path: str = "<cache>") -> List[Finding]:
    """Validate one autotune cache payload (the parsed JSON object)."""
    out: List[Finding] = []

    def err(msg: str, key: str = "") -> None:
        out.append(Finding("SCHEMA002", "error", path, 0, msg, symbol=key))

    if not isinstance(obj, dict):
        err(f"cache root must be an object mapping shape keys to entries, "
            f"got {type(obj).__name__}")
        return out
    for key, entry in obj.items():
        try:
            kernel, dims, dtype, backend = parse_shape_key(key)
        except ValueError as e:
            err(f"bad shape key: {e}", key)
            continue
        if kernel not in KNOWN_KERNELS:
            err(f"unknown kernel family {kernel!r} "
                f"(known: {sorted(KNOWN_KERNELS)})", key)
        else:
            missing = [d for d in KNOWN_KERNELS[kernel] if d not in dims]
            if missing:
                err(f"key for kernel {kernel!r} is missing required dims "
                    f"{missing}; present: {sorted(dims)}", key)
        nonpos = {d: v for d, v in dims.items() if v < 1}
        if nonpos:
            err(f"key carries non-positive dims {nonpos}", key)
        if not isinstance(entry, dict):
            err(f"entry must be an object, got {type(entry).__name__}", key)
            continue
        extra = set(entry) - {"tiles", "us", "candidates"}
        if extra:
            err(f"entry carries unknown fields {sorted(extra)} "
                f"(schema v{CACHE_SCHEMA_VERSION} allows tiles/us/candidates)",
                key)
        tiles = entry.get("tiles")
        if not isinstance(tiles, dict):
            err(f"entry 'tiles' must be an object, got "
                f"{type(tiles).__name__}", key)
        else:
            for f in _TILE_FIELDS:
                v = tiles.get(f)
                if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                    err(f"tiles.{f} must be a positive int, got {v!r}", key)
            unknown = set(tiles) - set(_TILE_FIELDS)
            if unknown:
                err(f"tiles carries unknown fields {sorted(unknown)}", key)
        us = entry.get("us", "<absent>")
        if us == "<absent>":
            err("entry is missing 'us' (null when the tune was untimed)", key)
        elif us is not None and not _finite_num(us):
            err(f"'us' must be null or a finite number, got {us!r} "
                f"(bare NaN/Infinity tokens break strict parsers)", key)
        cand = entry.get("candidates")
        if not isinstance(cand, int) or isinstance(cand, bool) or cand < 0:
            err(f"'candidates' must be a non-negative int, got {cand!r}", key)
        elif us is not None and cand == 0:
            err("entry has a timed 'us' but candidates=0 — a timing with no "
                "timed candidate is contradictory", key)
    return out


# ----------------------------------------------------------------------------
# BENCH_*.json schema
# ----------------------------------------------------------------------------

_ROW_NAME_RE = re.compile(r"^[a-z0-9_]+\.[A-Za-z0-9_.\-]+$")


def validate_bench(obj, path: str = "<bench>") -> List[Finding]:
    """Validate one BENCH payload (the parsed JSON object)."""
    out: List[Finding] = []

    def err(msg: str, sym: str = "") -> None:
        out.append(Finding("SCHEMA001", "error", path, 0, msg, symbol=sym))

    if not isinstance(obj, dict):
        err(f"BENCH root must be an object, got {type(obj).__name__}")
        return out
    if not isinstance(obj.get("pr"), int) or isinstance(obj.get("pr"), bool):
        err(f"top-level 'pr' must be an int, got {obj.get('pr')!r}")
    for field in ("backend", "timing"):
        if not isinstance(obj.get(field), str) or not obj.get(field):
            err(f"top-level {field!r} must be a non-empty string, "
                f"got {obj.get(field)!r}")
    skipped = obj.get("skipped", {})
    if not isinstance(skipped, dict) or not all(
            isinstance(k, str) and isinstance(v, str)
            for k, v in skipped.items()):
        err(f"top-level 'skipped' must map sub-benchmark names to string "
            f"reasons, got {skipped!r}")
        skipped = {}
    rows = obj.get("rows")
    if not isinstance(rows, list) or not rows:
        err("top-level 'rows' must be a non-empty list")
        rows = []
    row_skips = set()
    for i, row in enumerate(rows):
        sym = f"rows[{i}]"
        if not isinstance(row, dict):
            err(f"row must be an object, got {type(row).__name__}", sym)
            continue
        name = row.get("name")
        if not isinstance(name, str) or not _ROW_NAME_RE.match(name):
            err(f"row 'name' must be a '<section>.<case>' string, "
                f"got {name!r}", sym)
        else:
            sym = name
        missing = {"name", "us_per_call", "derived"} - set(row)
        if missing:
            err(f"row is missing required fields {sorted(missing)}", sym)
        extra = set(row) - {"name", "us_per_call", "derived", "skipped"}
        if extra:
            err(f"row carries unknown fields {sorted(extra)} "
                f"(schema v{BENCH_SCHEMA_VERSION})", sym)
        us = row.get("us_per_call")
        if "us_per_call" in row and not _finite_num(us):
            err(f"row 'us_per_call' must be a finite number, got {us!r}", sym)
        der = row.get("derived")
        if "derived" in row and not (isinstance(der, str) or _finite_num(der)):
            err(f"row 'derived' must be a string or finite number, "
                f"got {der!r}", sym)
        skip = row.get("skipped")
        if skip is not None:
            if not isinstance(skip, str) or not skip:
                err(f"row 'skipped' must be a non-empty reason string, "
                    f"got {skip!r}", sym)
            if "derived" in row and not (
                    isinstance(der, str) and der.startswith("skipped: ")):
                err("skipped row's 'derived' must carry the "
                    "'skipped: <reason>' marker (the CSV mirror)", sym)
            if isinstance(name, str):
                row_skips.add(name)
                if name not in skipped:
                    err("skipped row has no entry in the top-level 'skipped' "
                        "map — the two views must agree", sym)
    for name in skipped:
        if name not in row_skips:
            err(f"top-level 'skipped' names {name!r} but no row carries the "
                f"skip — the two views must agree", name)
    # speedup blocks, when present, are flat name -> finite number maps.
    for field in ("speedup", "target_min_speedup"):
        block = obj.get(field)
        if block is None:
            continue
        if not isinstance(block, dict) or not all(
                isinstance(k, str) and _finite_num(v)
                for k, v in block.items()):
            err(f"top-level {field!r} must map metric names to finite "
                f"numbers, got {block!r}")
    # traffic block (BENCH_pr9+): open-loop load-sweep rows.  Each row
    # carries the typed outcome counts, and the counts must partition the
    # offered set — the overload-accounting invariant is enforced at the
    # artifact layer too, so a stale/hand-edited BENCH file cannot claim a
    # contract the engine did not uphold.
    traffic = obj.get("traffic")
    if traffic is not None:
        out.extend(_validate_traffic(traffic, err))
    # drift block (BENCH_pr10+): sentinel overhead + chaos-drift counts.
    drift = obj.get("drift")
    if drift is not None:
        _validate_drift(drift, err)
    return out


_DRIFT_CHAOS_COUNTS = ("demotions", "recalibrations", "sticky")


def _validate_drift(drift, err) -> None:
    """Validate a BENCH 'drift' block: the sentinel-overhead measurement
    (monitored vs unmonitored decode) and the chaos-drift event counts.
    The overhead ratio must actually be the quotient of the two timings —
    a hand-edited ratio cannot claim an overhead the timings don't show."""
    if not isinstance(drift, dict):
        err(f"top-level 'drift' must be an object, got "
            f"{type(drift).__name__}")
        return
    so = drift.get("sentinel_overhead")
    if not isinstance(so, dict):
        err(f"drift 'sentinel_overhead' must be an object with "
            f"monitored_us/unmonitored_us/ratio, got {so!r}",
            "drift.sentinel_overhead")
    else:
        vals = {}
        for f in ("monitored_us", "unmonitored_us", "ratio"):
            v = so.get(f)
            if not _finite_num(v) or v <= 0:
                err(f"drift sentinel_overhead.{f} must be a positive finite "
                    f"number, got {v!r}", "drift.sentinel_overhead")
            else:
                vals[f] = v
        if len(vals) == 3:
            q = vals["monitored_us"] / vals["unmonitored_us"]
            if abs(vals["ratio"] - q) > 0.01 * q:
                err(f"drift sentinel_overhead.ratio = {vals['ratio']:.4f} "
                    f"is not monitored_us/unmonitored_us = {q:.4f}",
                    "drift.sentinel_overhead")
    chaos = drift.get("chaos")
    if chaos is not None:
        if not isinstance(chaos, dict):
            err(f"drift 'chaos' must be an object, got "
                f"{type(chaos).__name__}", "drift.chaos")
        else:
            for f in _DRIFT_CHAOS_COUNTS:
                v = chaos.get(f)
                if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                    err(f"drift chaos.{f} must be a non-negative int, "
                        f"got {v!r}", "drift.chaos")
            rp = chaos.get("repromoted")
            if not isinstance(rp, bool):
                err(f"drift chaos.repromoted must be a bool, got {rp!r}",
                    "drift.chaos")
    extra = set(drift) - {"sentinel_overhead", "chaos"}
    if extra:
        err(f"drift block carries unknown fields {sorted(extra)} "
            f"(schema v{BENCH_SCHEMA_VERSION})", "drift")


_TRAFFIC_COUNTS = ("offered", "served", "degraded", "failed", "rejected")
_TRAFFIC_METRICS = ("shed_rate", "p50_token_s", "p99_token_s", "tokens_per_s")


def _validate_traffic(traffic, err) -> List[Finding]:
    """Validate a BENCH 'traffic' block: a list of load-sweep rows."""
    if not isinstance(traffic, list) or not traffic:
        err(f"top-level 'traffic' must be a non-empty list of load rows, "
            f"got {type(traffic).__name__}")
        return []
    for i, row in enumerate(traffic):
        sym = f"traffic[{i}]"
        if not isinstance(row, dict):
            err(f"traffic row must be an object, got {type(row).__name__}",
                sym)
            continue
        prof = row.get("profile")
        if not isinstance(prof, str) or not prof:
            err(f"traffic row 'profile' must be a non-empty string, "
                f"got {prof!r}", sym)
        else:
            sym = f"traffic[{i}]:{prof}@{row.get('load')}"
        if not _finite_num(row.get("load")) or row.get("load") <= 0:
            err(f"traffic row 'load' must be a positive finite number "
                f"(offered-load multiple of capacity), got "
                f"{row.get('load')!r}", sym)
        counts = {}
        for f in _TRAFFIC_COUNTS:
            v = row.get(f)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                err(f"traffic row {f!r} must be a non-negative int, "
                    f"got {v!r}", sym)
            else:
                counts[f] = v
        if len(counts) == len(_TRAFFIC_COUNTS):
            total = sum(counts[f] for f in _TRAFFIC_COUNTS[1:])
            if total != counts["offered"]:
                err(f"traffic row breaks the accounting invariant: "
                    f"served+degraded+failed+rejected = {total} != offered "
                    f"= {counts['offered']}", sym)
        for f in _TRAFFIC_METRICS:
            v = row.get(f)
            # percentile metrics are null when nothing completed (pure shed)
            if v is None and f in ("p50_token_s", "p99_token_s",
                                   "tokens_per_s"):
                continue
            if not _finite_num(v) or v < 0:
                err(f"traffic row {f!r} must be a non-negative finite "
                    f"number (or null for empty percentiles), got {v!r}",
                    sym)
    return []


# ----------------------------------------------------------------------------
# Repo artifact discovery
# ----------------------------------------------------------------------------


def _load(path: str) -> Tuple[Optional[object], Optional[str]]:
    try:
        with open(path) as f:
            return json.load(f), None
    except (OSError, ValueError) as e:
        return None, f"{type(e).__name__}: {e}"


def validate_repo_artifacts(root: str,
                            cache_path: Optional[str] = None
                            ) -> List[Finding]:
    """Validate every checked-in ``BENCH_*.json`` under ``root`` plus the
    autotune cache: an explicit ``cache_path``, else
    ``$REPRO_PCILT_TUNE_CACHE`` when set, else any committed
    ``*tiles*.json`` under the repo root.  A missing cache is fine (nothing
    committed yet); an unparseable artifact is a finding, not a crash."""
    out: List[Finding] = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        obj, emsg = _load(path)
        if emsg is not None:
            out.append(Finding("SCHEMA001", "error", rel(path, root), 0,
                               f"unreadable BENCH file ({emsg})"))
            continue
        out.extend(validate_bench(obj, rel(path, root)))
    caches = []
    if cache_path:
        caches.append(cache_path)
    else:
        env = os.environ.get("REPRO_PCILT_TUNE_CACHE")
        if env and os.path.exists(env):
            caches.append(env)
        caches.extend(sorted(glob.glob(os.path.join(root, "*tiles*.json"))))
    for path in caches:
        obj, emsg = _load(path)
        if emsg is not None:
            out.append(Finding("SCHEMA002", "error", rel(path, root), 0,
                               f"unreadable autotune cache ({emsg})"))
            continue
        out.extend(validate_tune_cache(obj, rel(path, root)))
    return out
