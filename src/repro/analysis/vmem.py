"""Static VMEM / grid verifier for the PCILT kernel zoo.

Proves — by abstract tracing only, never executing a kernel — that the
analytic scratch bound the candidate generators apply
(``kernels.autotune._fit_scratch_gb`` / ``SCRATCH_BUDGET``) is sound, and
that every BlockSpec ``index_map`` tiles its operand correctly over the full
grid.  For each kernel family the verifier:

1. enumerates the family's *actual* candidate generator over a recorded
   shape sweep (the same generator ``ops.py`` dispatches through — nothing
   is re-modeled on the analysis side);
2. recomputes each emitted candidate's modeled per-grid-step scratch (the
   one-hot the kernel materializes, plus the family's Gb-independent fixed
   bytes) and proves it respects the budget (**VMEM001** — this is exactly
   the clamp ``_fit_scratch_gb`` promises, so a generator change that stops
   applying it fires here);
3. traces the real jitted ``*_pallas`` wrapper with ``jax.make_jaxpr`` on
   ``ShapeDtypeStruct`` inputs — a trace, not a run — and from the recorded
   ``pallas_call`` equation:

   * evaluates every BlockSpec ``index_map`` jaxpr over the **full grid**
     (vectorized — the maps are elementwise in the grid indices) and checks
     each emitted block index stays in-bounds (**VMEM002**) and that
     grid-dependent axes tile their operand without gaps (**VMEM003**);
     scalar-prefetch-driven axes (the stacked decode kernel's layer axis)
     are exempt from coverage but bounds-checked for *every* prefetch value
     after ``discharge_state`` rewrites the ref-typed map into a pure one;
   * searches the kernel jaxpr (sub-jaxprs included) for an intermediate
     whose shape matches the modeled one-hot — the witness that the
     analytic model still describes the kernel body (**VMEM004**: model
     drift);

4. checks the *untuned fallback* (candidate 0 — what a cache miss
   dispatches) fits staged blocks + modeled scratch in the full per-core
   VMEM (**VMEM005**), and flags tuned candidates that exceed it and so
   rely on TPU compile-rejection inside ``tune`` (**VMEM006**, warning —
   by design ``tune`` skips rejected candidates, but they cost a compile).

``verify_all(sweep=..., scratch_budget=...)`` is the entry point;
``scratch_budget`` overrides the generators' budget so tests can prove the
verifier *rejects* once the budget shrinks below the smallest admissible
tile (soundness: the pass is not vacuously green).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import Finding

__all__ = ["RULES", "verify_all", "FAMILIES", "TOTAL_VMEM_BUDGET"]

RULES: Dict[str, str] = {
    "VMEM001": "emitted candidate's modeled per-grid-step scratch exceeds "
               "SCRATCH_BUDGET (the generator's analytic clamp was not "
               "applied)",
    "VMEM002": "BlockSpec index_map emits an out-of-bounds block index "
               "somewhere in the grid",
    "VMEM003": "grid walk leaves gaps: a grid-dependent block axis does not "
               "cover its operand",
    "VMEM004": "scratch model drift: traced kernel body lacks the modeled "
               "one-hot intermediate",
    "VMEM005": "untuned fallback candidate does not fit staged blocks + "
               "scratch in per-core VMEM",
    "VMEM006": "tuned candidate exceeds per-core VMEM and relies on "
               "compile-rejection at tune time",
}

_MiB = 2 ** 20
#: full per-core VMEM the fallback (cache-miss) candidate must fit into —
#: staged operand blocks plus modeled scratch.  Tuned candidates may exceed
#: it (``tune`` skips compile-rejected tilings), the fallback must not: a
#: cache miss dispatches it unconditionally.
TOTAL_VMEM_BUDGET = 16 * _MiB

#: full-grid index-map enumeration cap; sweeps are sized to stay below it
#: (above it the verifier samples bounds and skips the coverage proof).
_MAX_GRID_POINTS = 4096


# ----------------------------------------------------------------------------
# Family specs: tie each candidate generator to its kernel's scratch model,
# its jitted wrapper (for tracing), and a recorded shape sweep.
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Family:
    name: str                 # autotune kernel name (shape-key prefix)
    path: str                 # kernel source file findings anchor to
    sweep: Dict[str, List[dict]]          # {"quick": [...], "full": [...]}
    candidates: Callable      # (shape, budget) -> List[TileConfig]
    scratch_bytes: Callable   # (shape, cfg) -> int (the generator's model)
    witness: Callable         # (shape, eff) -> acceptable one-hot shapes
    trace: Callable           # (shape, cfg) -> (jaxpr, eff_cfg)


def _kpath(fname: str) -> str:
    return os.path.join("src", "repro", "kernels", fname)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _padded_O(O: int, Ob: int) -> int:
    return _round_up(O, Ob) if O >= 128 else O


def _build_families() -> List[Family]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune as atn
    from repro.kernels import ops
    from repro.kernels.pcilt_conv2d import pcilt_conv2d_pallas
    from repro.kernels.pcilt_dwconv1d import pcilt_fused_dwconv1d_pallas
    from repro.kernels.pcilt_fused import (
        pcilt_fused_conv2d_pallas, pcilt_fused_gemv_pallas,
        pcilt_fused_gemv_paired_pallas,
        pcilt_fused_gemv_paired_stacked_pallas, pcilt_fused_gemv_plan_pallas,
        pcilt_fused_gemv_stacked_pallas)
    from repro.kernels.pcilt_gemv import pcilt_gemv_pallas
    from repro.kernels.pcilt_shared import (pcilt_shared_conv2d_pallas,
                                            pcilt_shared_gemv_pallas)

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(tuple(shape), dtype)

    def tdt(s):
        return jnp.bfloat16 if s.get("itemsize", 4) == 2 else jnp.float32

    def mk(fn, *args, **static_kw):
        return jax.make_jaxpr(lambda *a: fn(*a, **static_kw))(*args)

    # -- gemv (host-packed + fused + stacked share the generator) ----------

    GEMV_SWEEP = {
        "quick": [dict(B=8, G=16, V=16, O=256, group=2, bits=2, itemsize=4),
                  dict(B=8, G=16, V=16, O=256, group=2, bits=2, itemsize=2)],
        "full": [dict(B=8, G=16, V=16, O=256, group=2, bits=2, itemsize=4),
                 dict(B=8, G=16, V=16, O=256, group=2, bits=2, itemsize=2),
                 dict(B=64, G=64, V=16, O=512, group=2, bits=2, itemsize=4),
                 dict(B=1, G=128, V=16, O=1024, group=4, bits=4, itemsize=2)],
    }

    def gemv_cands(s, budget):
        return atn.gemv_candidates(s["B"], s["G"], s["V"], s["O"],
                                   s["itemsize"], scratch_budget=budget)

    def gemv_scratch(s, c):
        # the fused [Bb, Gb*V] one-hot in table dtype — the exact quantity
        # _fit_scratch_gb(G, Bb, V, itemsize) bounds.
        return c.Bb * c.Gb * s["V"] * s["itemsize"]

    def host_gemv_trace(s, c):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_gemv_pallas,
               sds((Bp, s["G"]), jnp.int32),
               sds((s["G"], s["V"], Op), tdt(s)),
               interpret=True, tiles=tiles)
        return j, tiles

    def host_gemv_witness(s, eff):
        # host kernel one-hots one group per fori step: [Bb_eff, V] in table
        # dtype (the generator's [Bb, Gb, V] model is deliberately
        # conservative for this kernel).
        return [(eff[0], s["V"])]

    def fused_gemv_trace(s, c):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_gemv_pallas,
               sds((Bp, s["G"] * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["G"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, interpret=True)
        return j, tiles

    def fused_gemv_witness(s, eff):
        return [(eff[0], eff[1] * s["V"])]

    STACKED_SWEEP = {
        "quick": [dict(B=8, L=3, G=16, V=16, O=256, group=2, bits=2,
                       itemsize=4)],
        "full": [dict(B=8, L=3, G=16, V=16, O=256, group=2, bits=2,
                      itemsize=4),
                 dict(B=1, L=4, G=64, V=16, O=512, group=2, bits=2,
                      itemsize=2),
                 # batch-R serving regime: the R-aware row-tile sweep emits
                 # Bb sub-tiles (8/16/32) here — verify each one fits
                 dict(B=64, L=3, G=32, V=16, O=256, group=2, bits=2,
                      itemsize=4)],
    }

    def stacked_cands(s, budget):
        return atn.stacked_gemv_candidates(s["B"], s["L"], s["G"], s["V"],
                                           s["O"], s["itemsize"],
                                           scratch_budget=budget)

    def stacked_trace(s, c, counters=False):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_gemv_stacked_pallas,
               sds((1,), jnp.int32),
               sds((Bp, s["G"] * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["L"], s["G"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, counters=counters,
               interpret=True)
        return j, tiles

    # -- paired (TL1-style) gemv + seg-major stack -------------------------
    # G/V are paired-space (segment pairs at V**2 entries).  The paired
    # kernels gather table rows with take_along_axis — no one-hot — so the
    # scratch model is the f32 [Gb, Bb, Ob] fetched rows plus the [Bb, Gb]
    # pair-index plane (autotune._fit_paired_gb), with no V factor.

    PAIRED_SWEEP = {
        "quick": [dict(B=8, G=8, V=256, O=256, group=2, bits=2, itemsize=4)],
        "full": [dict(B=8, G=8, V=256, O=256, group=2, bits=2, itemsize=4),
                 dict(B=1, G=16, V=16, O=128, group=1, bits=2, itemsize=2)],
    }

    def paired_cands(s, budget):
        return atn.paired_gemv_candidates(s["B"], s["G"], s["V"], s["O"],
                                          s["itemsize"],
                                          scratch_budget=budget)

    def paired_scratch(s, c):
        # f32 fetched rows [Gb, Bb, Ob] + int32 pair indices [Bb, Gb] —
        # exactly what _fit_paired_gb(G, Bb, Ob) bounds (no V factor).
        return c.Gb * (c.Bb * c.Ob * 4 + c.Bb * 4)

    def paired_witness(s, eff):
        # the take_along_axis row-fetch intermediate [Gb, Bb, Ob]
        return [(eff[1], eff[0], eff[2])]

    def paired_trace(s, c, counters=False):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_gemv_paired_pallas,
               sds((Bp, s["G"] * 2 * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["G"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, counters=counters,
               interpret=True)
        return j, tiles

    PAIRED_STACKED_SWEEP = {
        "quick": [dict(B=8, L=2, G=8, V=256, O=128, group=2, bits=2,
                       itemsize=4)],
        "full": [dict(B=8, L=2, G=8, V=256, O=128, group=2, bits=2,
                      itemsize=4),
                 dict(B=1, L=4, G=16, V=16, O=128, group=1, bits=2,
                      itemsize=2),
                 # batch-R serving regime (row-tile sub-tiles of Bb=64)
                 dict(B=64, L=2, G=8, V=256, O=128, group=2, bits=2,
                      itemsize=4)],
    }

    def paired_stacked_cands(s, budget):
        return atn.paired_stacked_gemv_candidates(
            s["B"], s["L"], s["G"], s["V"], s["O"], s["itemsize"],
            scratch_budget=budget)

    def paired_stacked_trace(s, c, counters=False):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_gemv_paired_stacked_pallas,
               sds((1,), jnp.int32),
               sds((Bp, s["G"] * 2 * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["G"], s["L"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, counters=counters,
               interpret=True)
        return j, tiles

    # -- plan-gather gemv (generalized SegmentPlans on the fused path) -----
    # Same one-hot contraction (and so the same generator + scratch model +
    # witness) as fused_gemv; only the in-VMEM plan gather differs.

    def plan_trace(s, c):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_gemv_plan_pallas,
               sds((Bp, s["G"] * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["G"], s["group"]), jnp.int32),
               sds((s["G"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, interpret=True)
        return j, tiles

    # -- conv2d (host-packed + fused share the generator) ------------------

    CONV_SWEEP = {
        "quick": [dict(B=1, Ho=8, Wo=8, C=8, kh=3, kw=3, stride=1, G=36,
                       group=2, V=16, O=128, bits=2, itemsize=4)],
        "full": [dict(B=1, Ho=8, Wo=8, C=8, kh=3, kw=3, stride=1, G=36,
                      group=2, V=16, O=128, bits=2, itemsize=4),
                 dict(B=2, Ho=16, Wo=16, C=16, kh=5, kw=5, stride=1, G=200,
                      group=2, V=16, O=256, bits=2, itemsize=2)],
    }

    def host_conv_cands(s, budget):
        # the host dispatch site calls the generator with the default
        # conservative Wo=128 (it does not thread the real output width).
        return atn.conv2d_candidates(s["Ho"], s["G"], s["V"], s["O"],
                                     s["itemsize"], scratch_budget=budget)

    def host_conv_scratch(s, c):
        return c.row_tile * 128 * c.Gb * s["V"] * s["itemsize"]

    def host_conv_trace(s, c):
        tiles = ops._fit_conv_tiles((c.row_tile, c.Gb, c.Ob),
                                    s["Ho"], s["G"], s["O"])
        Wop = _round_up(s["Wo"], 8) if s["Wo"] >= 8 else s["Wo"]
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_conv2d_pallas,
               sds((s["B"], s["Ho"], Wop, s["G"]), jnp.int32),
               sds((s["G"], s["V"], Op), tdt(s)),
               interpret=True, tiles=tiles)
        return j, tiles

    def host_conv_witness(s, eff):
        Wop = _round_up(s["Wo"], 8) if s["Wo"] >= 8 else s["Wo"]
        return [(eff[0] * Wop, s["V"])]

    def fused_conv_cands(s, budget):
        return atn.conv2d_candidates(s["Ho"], s["G"], s["V"], s["O"],
                                     s["itemsize"], Wo=s["Wo"],
                                     scratch_budget=budget)

    def fused_conv_scratch(s, c):
        return c.row_tile * s["Wo"] * c.Gb * s["V"] * s["itemsize"]

    def fused_conv_trace(s, c):
        tiles = ops._fit_conv_tiles((c.row_tile, c.Gb, c.Ob),
                                    s["Ho"], s["G"], s["O"])
        Hp = (s["Ho"] - 1) * s["stride"] + s["kh"]
        Wp = (s["Wo"] - 1) * s["stride"] + s["kw"]
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_fused_conv2d_pallas,
               sds((s["B"], Hp, Wp, s["C"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((1, 1), jnp.int32),
               sds((s["G"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], kh=s["kh"], kw=s["kw"], stride=s["stride"],
               n_total=s["G"] * s["group"], tiles=tiles, interpret=True)
        return j, tiles

    def fused_conv_witness(s, eff):
        return [(eff[0] * s["Wo"], eff[1] * s["V"])]

    # -- shared pool (extension 3) ----------------------------------------

    SHARED_GEMV_SWEEP = {
        "quick": [dict(B=8, G=16, X=4, V=16, O=256, group=2, bits=2,
                       itemsize=4)],
        "full": [dict(B=8, G=16, X=4, V=16, O=256, group=2, bits=2,
                      itemsize=4),
                 dict(B=16, G=128, X=8, V=16, O=512, group=2, bits=2,
                      itemsize=2)],
    }

    def shared_gemv_cands(s, budget):
        return atn.shared_gemv_candidates(s["B"], s["G"], s["V"], s["O"],
                                          s["X"], s["itemsize"],
                                          scratch_budget=budget)

    def shared_gemv_scratch(s, c):
        # f32 [Bb, Gb, V] one-hot + Gb-independent counts/pool fixed bytes.
        fixed = atn._shared_fixed_bytes(c.Bb, s["V"], s["X"], c.Ob,
                                        s["itemsize"])
        return c.Bb * c.Gb * s["V"] * 4 + fixed

    def shared_gemv_trace(s, c):
        tiles = ops._fit_tiles((c.Bb, c.Gb, c.Ob), s["B"], s["G"], s["O"])
        Bp = _round_up(s["B"], tiles[0])
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_shared_gemv_pallas,
               sds((Bp, s["G"] * s["group"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((1, s["G"]), jnp.int32),
               sds((s["X"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], tiles=tiles, interpret=True)
        return j, tiles

    def shared_gemv_witness(s, eff):
        return [(eff[0], eff[1], s["V"])]

    SHARED_CONV_SWEEP = {
        "quick": [dict(B=1, Ho=8, Wo=8, C=8, kh=3, kw=3, stride=1, G=36,
                       X=4, group=2, V=16, O=128, bits=2, itemsize=4)],
        "full": [dict(B=1, Ho=8, Wo=8, C=8, kh=3, kw=3, stride=1, G=36,
                      X=4, group=2, V=16, O=128, bits=2, itemsize=4),
                 dict(B=2, Ho=16, Wo=16, C=16, kh=5, kw=5, stride=1, G=200,
                      X=8, group=2, V=16, O=256, bits=2, itemsize=2)],
    }

    def shared_conv_cands(s, budget):
        return atn.shared_conv2d_candidates(s["Ho"], s["G"], s["V"], s["O"],
                                            s["X"], s["itemsize"],
                                            Wo=s["Wo"], scratch_budget=budget)

    def shared_conv_scratch(s, c):
        R = c.row_tile * s["Wo"]
        fixed = atn._shared_fixed_bytes(R, s["V"], s["X"], c.Ob,
                                        s["itemsize"])
        return R * c.Gb * s["V"] * 4 + fixed

    def shared_conv_trace(s, c):
        tiles = ops._fit_conv_tiles((c.row_tile, c.Gb, c.Ob),
                                    s["Ho"], s["G"], s["O"])
        Hp = (s["Ho"] - 1) * s["stride"] + s["kh"]
        Wp = (s["Wo"] - 1) * s["stride"] + s["kw"]
        Op = _padded_O(s["O"], tiles[2])
        j = mk(pcilt_shared_conv2d_pallas,
               sds((s["B"], Hp, Wp, s["C"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((1, 1), jnp.int32),
               sds((1, s["G"]), jnp.int32),
               sds((s["X"], s["V"], Op), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               group=s["group"], kh=s["kh"], kw=s["kw"], stride=s["stride"],
               n_total=s["G"] * s["group"], tiles=tiles, interpret=True)
        return j, tiles

    def shared_conv_witness(s, eff):
        return [(eff[0] * s["Wo"], eff[1], s["V"])]

    # -- fused depthwise conv1d --------------------------------------------

    DW_SWEEP = {
        "quick": [dict(B=2, To=16, C=128, k=4, bits=2, itemsize=4)],
        "full": [dict(B=2, To=16, C=128, k=4, bits=2, itemsize=4),
                 dict(B=1, To=64, C=256, k=4, bits=2, itemsize=2)],
    }

    def dw_V(s):
        return 1 << (s["bits"] * s["k"])

    def dw_cands(s, budget):
        return atn.dwconv1d_candidates(s["To"], s["C"], dw_V(s), s["k"],
                                       s["itemsize"], scratch_budget=budget)

    def dw_eff(s, c):
        return (atn._div_down(s["To"], max(1, c.Bb)),
                atn._div_down(s["C"], max(1, c.Ob)))

    def dw_scratch(s, c):
        V = dw_V(s)
        h = (s["bits"] * s["k"]) // 2
        Vl, Vh = 1 << h, V >> h
        Tb, Cb = dw_eff(s, c)
        fixed = (s["To"] + s["k"] - 1) * Cb * 4 + Cb * V * s["itemsize"]
        return Tb * Cb * (Vl + 2 * Vh) * 4 + fixed

    def dw_trace(s, c, counters=False):
        Tb, Cb = dw_eff(s, c)
        Tp = s["To"] + s["k"] - 1
        j = mk(pcilt_fused_dwconv1d_pallas,
               sds((s["B"], Tp, s["C"]), jnp.float32),
               sds((1, 1), jnp.float32),
               sds((s["C"], dw_V(s)), tdt(s)),
               bits=s["bits"], zero_point=(1 << s["bits"]) // 2,
               k=s["k"], tiles=(Tb, Cb), counters=counters, interpret=True)
        return j, (Tb, Cb)

    def dw_witness(s, eff):
        h = (s["bits"] * s["k"]) // 2
        Vh = dw_V(s) >> h
        Tb, Cb = eff
        # the factored fetch's largest intermediate: the [Cb, Vh, Tb]
        # partial-fetch tensor (f32)
        return [(Cb, Vh, Tb)]

    return [
        Family("gemv_host", _kpath("pcilt_gemv.py"), GEMV_SWEEP,
               gemv_cands, gemv_scratch, host_gemv_witness, host_gemv_trace),
        Family("fused_gemv", _kpath("pcilt_fused.py"), GEMV_SWEEP,
               gemv_cands, gemv_scratch, fused_gemv_witness,
               fused_gemv_trace),
        Family("fused_gemv_stacked", _kpath("pcilt_fused.py"), STACKED_SWEEP,
               stacked_cands, gemv_scratch, fused_gemv_witness,
               stacked_trace),
        Family("fused_gemv_paired", _kpath("pcilt_fused.py"), PAIRED_SWEEP,
               paired_cands, paired_scratch, paired_witness, paired_trace),
        Family("fused_gemv_paired_stacked", _kpath("pcilt_fused.py"),
               PAIRED_STACKED_SWEEP, paired_stacked_cands, paired_scratch,
               paired_witness, paired_stacked_trace),
        Family("fused_gemv_plan", _kpath("pcilt_fused.py"), GEMV_SWEEP,
               gemv_cands, gemv_scratch, fused_gemv_witness, plan_trace),
        Family("conv2d_host", _kpath("pcilt_conv2d.py"), CONV_SWEEP,
               host_conv_cands, host_conv_scratch, host_conv_witness,
               host_conv_trace),
        Family("fused_conv2d", _kpath("pcilt_fused.py"), CONV_SWEEP,
               fused_conv_cands, fused_conv_scratch, fused_conv_witness,
               fused_conv_trace),
        Family("shared_gemv", _kpath("pcilt_shared.py"), SHARED_GEMV_SWEEP,
               shared_gemv_cands, shared_gemv_scratch, shared_gemv_witness,
               shared_gemv_trace),
        Family("shared_conv2d", _kpath("pcilt_shared.py"), SHARED_CONV_SWEEP,
               shared_conv_cands, shared_conv_scratch, shared_conv_witness,
               shared_conv_trace),
        Family("fused_dwconv1d", _kpath("pcilt_dwconv1d.py"), DW_SWEEP,
               dw_cands, dw_scratch, dw_witness, dw_trace),
        # monitored (_sat) variants: same candidate generators, scratch
        # models, and one-hot witnesses as their base families — the trace
        # compiles with counters=True, so the verifier proves the counter
        # reduction adds no modeled scratch and the [1,1] counter outputs'
        # constant index maps stay in-bounds over the full grid
        Family("fused_gemv_stacked_sat", _kpath("pcilt_fused.py"),
               STACKED_SWEEP, stacked_cands, gemv_scratch,
               fused_gemv_witness,
               lambda s, c: stacked_trace(s, c, counters=True)),
        Family("fused_gemv_paired_sat", _kpath("pcilt_fused.py"),
               PAIRED_SWEEP, paired_cands, paired_scratch, paired_witness,
               lambda s, c: paired_trace(s, c, counters=True)),
        Family("fused_gemv_paired_stacked_sat", _kpath("pcilt_fused.py"),
               PAIRED_STACKED_SWEEP, paired_stacked_cands, paired_scratch,
               paired_witness,
               lambda s, c: paired_stacked_trace(s, c, counters=True)),
        Family("fused_dwconv1d_sat", _kpath("pcilt_dwconv1d.py"), DW_SWEEP,
               dw_cands, dw_scratch, dw_witness,
               lambda s, c: dw_trace(s, c, counters=True)),
    ]


_FAMILIES: Optional[List[Family]] = None


def FAMILIES() -> List[Family]:
    global _FAMILIES
    if _FAMILIES is None:
        _FAMILIES = _build_families()
    return _FAMILIES


# ----------------------------------------------------------------------------
# Jaxpr plumbing: find the pallas_call, walk sub-jaxprs, eval index maps
# ----------------------------------------------------------------------------


def _subjaxprs(params: dict):
    import jax

    def as_jaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from as_jaxprs(x)

    for v in params.values():
        yield from as_jaxprs(v)


def _find_pallas_eqn(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            return eqn
        for sub in _subjaxprs(eqn.params):
            hit = _find_pallas_eqn(sub)
            if hit is not None:
                return hit
    return None


def _all_avals(jaxpr, out: Optional[list] = None) -> list:
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for sub in _subjaxprs(eqn.params):
            _all_avals(sub, out)
    return out


def _block_shape(bm) -> Tuple[int, ...]:
    return tuple(int(b) if isinstance(b, int) else 1
                 for b in bm.block_shape)


def _eval_index_map(bm, grid_vecs, prefetch_val):
    """Evaluate one BlockSpec index-map jaxpr over *vectors* of grid indices
    (the maps are elementwise in the grid coordinates, so one eval covers
    the whole grid).  ``prefetch_val`` is the scalar-prefetch operand value
    (or None); ref-typed maps are rewritten pure via ``discharge_state``
    first.  Returns one int array per block axis, broadcast to grid size."""
    import jax
    import numpy as np

    ij = bm.index_map_jaxpr
    n = len(grid_vecs[0]) if len(grid_vecs) else 1
    if prefetch_val is None:
        outs = jax.core.eval_jaxpr(ij.jaxpr, ij.consts, *grid_vecs)
    else:
        from jax._src.state.discharge import discharge_state
        dj, dconsts = discharge_state(ij.jaxpr, ij.consts)
        outs = jax.core.eval_jaxpr(dj, dconsts, *grid_vecs, prefetch_val)
        outs = outs[:len(outs) - 1]  # drop the discharged final ref value
    return [np.broadcast_to(np.asarray(o, np.int64).reshape(-1)
                            if np.ndim(o) else np.asarray(o, np.int64), (n,))
            for o in outs]


def _check_blocks(fam: Family, sym: str, eqn, L: Optional[int]
                  ) -> List[Finding]:
    """VMEM002/VMEM003 for one traced config: bounds + coverage of every
    BlockSpec over the full grid."""
    import numpy as np

    findings: List[Finding] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    total = int(np.prod(grid)) if grid else 1
    if total > _MAX_GRID_POINTS:  # sweeps are sized to avoid this
        mesh = [np.linspace(0, g - 1, num=min(g, 64), dtype=np.int64)
                for g in grid]
        coverage_ok = False
    else:
        mesh = [np.arange(g, dtype=np.int64) for g in grid]
        coverage_ok = True
    pts = np.meshgrid(*mesh, indexing="ij") if mesh else []
    grid_vecs = [p.reshape(-1) for p in pts]

    n_out = len(eqn.outvars)
    n_index = int(getattr(gm, "num_index_operands", 0))
    prefetch_vals = [None]
    if n_index:
        prefetch_vals = [np.array([l], np.int32) for l in range(L or 1)]

    for bi, bm in enumerate(gm.block_mappings):
        is_output = bi >= len(gm.block_mappings) - n_out
        bs = _block_shape(bm)
        dims = tuple(int(d) for d in bm.array_shape_dtype.shape)
        nblocks = [max(1, -(-d // b)) for d, b in zip(dims, bs)]
        per_l = []
        for pv in prefetch_vals:
            idx = _eval_index_map(bm, grid_vecs, pv)
            per_l.append(idx)
            for a, (ia, nb) in enumerate(zip(idx, nblocks)):
                bad = (ia < 0) | (ia >= nb)
                if bad.any():
                    w = int(np.argmax(bad))
                    pt = tuple(int(v[w]) for v in grid_vecs)
                    findings.append(Finding(
                        "VMEM002", "error", fam.path, 0,
                        f"operand {bi} axis {a}: index_map emits block "
                        f"{int(ia[w])} outside [0, {nb}) at grid point "
                        f"{pt}" + (f" (prefetch={int(pv[0])})" if pv
                                   is not None else "")
                        + f"; array dims {dims}, block {bs}",
                        symbol=sym))
                    break
        if not coverage_ok or not per_l:
            continue
        idx0 = per_l[0]
        # axes whose index changes with the prefetch value (the stacked
        # kernel's layer axis) are staged per-prefetch, not per-grid —
        # exempt from grid coverage (bounds were checked for every value).
        prefetch_axes = set()
        for other in per_l[1:]:
            for a in range(len(idx0)):
                if not np.array_equal(idx0[a], other[a]):
                    prefetch_axes.add(a)
        for a, nb in enumerate(nblocks):
            if a in prefetch_axes:
                continue
            seen = set(np.unique(idx0[a]).tolist())
            varies = len(seen) > 1
            if (is_output or varies) and seen != set(range(nb)):
                missing = sorted(set(range(nb)) - seen)[:8]
                findings.append(Finding(
                    "VMEM003", "error", fam.path, 0,
                    f"operand {bi} axis {a}: grid walk covers blocks "
                    f"{sorted(seen)[:8]} of [0, {nb}) — operand is tiled "
                    f"with gaps (missing {missing})"
                    + ("" if is_output else " on a grid-dependent axis"),
                    symbol=sym))
        if is_output and len(nblocks) <= 4 and coverage_ok:
            want = set(itertools.product(*(range(nb) for nb in nblocks)))
            got = set(zip(*(tuple(x.tolist()) for x in idx0))) if idx0 \
                else set()
            if got != want:
                findings.append(Finding(
                    "VMEM003", "error", fam.path, 0,
                    f"output operand {bi}: grid writes {len(got)} of "
                    f"{len(want)} blocks — some output blocks are never "
                    f"visited",
                    symbol=sym))
    return findings


def _staged_bytes(eqn) -> int:
    gm = eqn.params["grid_mapping"]
    total = 0
    for bm in gm.block_mappings:
        bs = _block_shape(bm)
        n = 1
        for b in bs:
            n *= b
        total += n * bm.array_shape_dtype.dtype.itemsize
    return total


def _has_witness(eqn, shapes: Sequence[Tuple[int, ...]]) -> bool:
    kj = eqn.params["jaxpr"]
    import jax
    if isinstance(kj, jax.core.ClosedJaxpr):
        kj = kj.jaxpr
    want = {tuple(s) for s in shapes}
    for aval in _all_avals(kj):
        if tuple(aval.shape) in want:
            return True
    return False


# ----------------------------------------------------------------------------
# Driver
# ----------------------------------------------------------------------------


def verify_all(sweep: str = "quick",
               scratch_budget: Optional[float] = None,
               families: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the static verifier over every kernel family's candidate
    generator and traced wrapper.  ``scratch_budget=None`` uses the shipped
    ``SCRATCH_BUDGET``; tests shrink it to prove non-vacuity."""
    from repro.kernels import autotune as atn

    if sweep not in ("quick", "full"):
        raise ValueError(f"sweep must be 'quick' or 'full', got {sweep!r}")
    budget = atn.SCRATCH_BUDGET if scratch_budget is None else scratch_budget
    findings: List[Finding] = []
    for fam in FAMILIES():
        if families is not None and fam.name not in families:
            continue
        for s in fam.sweep[sweep]:
            shape_tag = ",".join(f"{k}={v}" for k, v in sorted(s.items()))
            cands = fam.candidates(s, budget)
            if not cands:
                findings.append(Finding(
                    "VMEM001", "error", fam.path, 0,
                    f"candidate generator emitted no candidates for shape "
                    f"{shape_tag}", symbol=fam.name))
                continue
            traced = set()
            for ci, cfg in enumerate(cands):
                sym = f"{fam.name}[{shape_tag}]#{ci}"
                scratch = fam.scratch_bytes(s, cfg)
                if scratch > budget:
                    findings.append(Finding(
                        "VMEM001", "error", fam.path, 0,
                        f"candidate {cfg} modeled scratch "
                        f"{scratch} B > SCRATCH_BUDGET {int(budget)} B"
                        f"; the analytic clamp (_fit_scratch_gb) was not "
                        f"applied for shape {shape_tag}",
                        symbol=sym))
                jaxpr, eff = fam.trace(s, cfg)
                eqn = _find_pallas_eqn(jaxpr.jaxpr)
                if eqn is None:
                    findings.append(Finding(
                        "VMEM004", "error", fam.path, 0,
                        "no pallas_call equation found in traced wrapper",
                        symbol=sym))
                    continue
                key = (tuple(eff), tuple(int(g) for g in
                                         eqn.params["grid_mapping"].grid))
                if key not in traced:
                    traced.add(key)
                    findings.extend(_check_blocks(fam, sym, eqn, s.get("L")))
                    if not _has_witness(eqn, fam.witness(s, eff)):
                        findings.append(Finding(
                            "VMEM004", "error", fam.path, 0,
                            f"traced kernel body has no intermediate of the "
                            f"modeled one-hot shape "
                            f"{list(fam.witness(s, eff))}"
                            f"; the scratch model no longer describes the "
                            f"kernel (shape {shape_tag}, config {cfg})",
                            symbol=sym))
                total = _staged_bytes(eqn) + scratch
                if ci == 0 and total > TOTAL_VMEM_BUDGET:
                    findings.append(Finding(
                        "VMEM005", "error", fam.path, 0,
                        f"untuned fallback candidate {cfg} stages "
                        f"{_staged_bytes(eqn)} B + {scratch} B scratch "
                        f"> {TOTAL_VMEM_BUDGET} B per-core VMEM"
                        f"; a cache miss cannot dispatch (shape "
                        f"{shape_tag})",
                        symbol=sym))
                elif ci > 0 and total > TOTAL_VMEM_BUDGET:
                    findings.append(Finding(
                        "VMEM006", "warning", fam.path, 0,
                        f"candidate {cfg} stages {_staged_bytes(eqn)} B + "
                        f"{scratch} B scratch > {TOTAL_VMEM_BUDGET} B"
                        f"; it relies on compile-rejection at tune time "
                        f"(shape {shape_tag})",
                        symbol=sym))
    return findings
