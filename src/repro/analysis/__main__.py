"""CLI for the PCILT static contract checker.

    python -m repro.analysis                   # all passes, exit 1 on errors
    python -m repro.analysis --passes lint     # just the AST lint
    python -m repro.analysis --sweep full      # exhaustive VMEM shape sweep
    python -m repro.analysis --write-baseline  # accept current findings

Findings print as ``file:line: RULE severity: message [symbol]``.  The exit
code is 1 when any *error* finding is not in the baseline file
(``<root>/.analysis-baseline.json`` by default) — warnings never gate.  CI
runs this via ``scripts/lint.sh``; the whole run traces kernels abstractly
but never executes one.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis import Baseline, Finding, repo_root, run_all

DEFAULT_BASELINE = ".analysis-baseline.json"


def main(argv: List[str] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification of PCILT kernel invariants, VMEM "
                    "budgets, and autotune/bench artifact schemas.")
    p.add_argument("--passes", default="lint,vmem,schema",
                   help="comma-separated subset of: lint, vmem, schema")
    p.add_argument("--sweep", default="quick", choices=("quick", "full"),
                   help="VMEM verifier shape sweep (default: quick)")
    p.add_argument("--root", default=None,
                   help="repository root (default: derived from the package "
                        "location)")
    p.add_argument("--baseline", default=None,
                   help=f"baseline file of accepted finding fingerprints "
                        f"(default: <root>/{DEFAULT_BASELINE})")
    p.add_argument("--write-baseline", action="store_true",
                   help="write the current findings to the baseline file "
                        "and exit 0")
    p.add_argument("--scratch-budget", type=float, default=None,
                   help="override autotune.SCRATCH_BUDGET bytes for the "
                        "VMEM pass (soundness experiments)")
    args = p.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else repo_root()
    passes = tuple(s.strip() for s in args.passes.split(",") if s.strip())
    baseline_path = args.baseline or os.path.join(root, DEFAULT_BASELINE)

    try:
        findings = run_all(root=root, passes=passes, sweep=args.sweep,
                           scratch_budget=args.scratch_budget)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        Baseline.write(baseline_path, findings)
        print(f"wrote {len(findings)} accepted fingerprint(s) to "
              f"{baseline_path}")
        return 0

    try:
        baseline = Baseline.load(baseline_path)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    gating: List[Finding] = []
    n_base = n_warn = 0
    for f in findings:
        suffix = ""
        if baseline.accepts(f):
            n_base += 1
            suffix = "  (baselined)"
        elif f.severity == "warning":
            n_warn += 1
        else:
            gating.append(f)
        print(f.render() + suffix)
    print(f"repro.analysis: {len(findings)} finding(s) — {len(gating)} "
          f"error(s), {n_warn} warning(s), {n_base} baselined "
          f"[passes: {','.join(passes)}; sweep: {args.sweep}]")
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
