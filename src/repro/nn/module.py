"""Declarative parameters with logical-axis sharding.

Models in this framework declare their parameters as a pytree of
:class:`ParamSpec` — shape, dtype, initializer, and **logical axis names**
(``"embed"``, ``"heads"``, ``"mlp"``, ``"vocab"``, ``"expert"``, ...).  A
:class:`ShardingRules` table maps logical axes onto mesh axes (the MaxText /
t5x pattern).  From one spec tree we derive, without ever materializing
weights:

* ``shardings(specs, mesh, rules)``   — NamedShardings for pjit,
* ``shape_structs(specs, mesh, rules)`` — ShapeDtypeStructs for the dry-run
  (this is how a 400B-parameter model lowers on a CPU host: nothing is
  allocated),
* ``materialize(specs, key)``         — real weights for runnable examples.

Divisibility fallback: if a logical axis maps to a mesh axis whose size does
not divide the dimension (e.g. 2 KV heads over a 16-way model axis), that
dimension silently falls back to replication.  This keeps one rule table
valid across all 10 assigned architectures; the dry-run report surfaces the
fallbacks so they are a conscious cost, not a hidden one.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParamSpec",
    "ShardingRules",
    "DEFAULT_RULES",
    "logical_to_partition_spec",
    "shardings",
    "shape_structs",
    "materialize",
    "flatten_with_path",
    "count_params",
    "spec_bytes",
    "PCILT_TABLE_AXES",
    "pcilt_table_pspec",
    "pcilt_table_sharding",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter: shape + logical axes + init recipe."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes)."""

    rules: Dict[str, Any]
    mesh_axis_sizes: Dict[str, int]

    @staticmethod
    def for_mesh(mesh: Mesh, rules: Optional[Dict[str, Any]] = None) -> "ShardingRules":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        return ShardingRules(rules=dict(rules or DEFAULT_RULES), mesh_axis_sizes=sizes)

    def mesh_axes_for(self, logical: Optional[str], dim: int):
        """Resolve one logical axis, applying the divisibility fallback."""
        if logical is None:
            return None
        target = self.rules.get(logical)
        if target is None:
            return None
        axes = target if isinstance(target, tuple) else (target,)
        # keep only mesh axes that exist; check divisibility of the product
        axes = tuple(a for a in axes if a in self.mesh_axis_sizes)
        if not axes:
            return None
        total = math.prod(self.mesh_axis_sizes[a] for a in axes)
        if dim % total != 0:
            return None
        return axes if len(axes) > 1 else axes[0]


#: batch over (pod,)data; TP dims over model; FSDP over data on the embed dim.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq_sp": "model",      # sequence parallelism (activations only)
    "cache_seq": ("pod", "data"),  # KV-cache time axis (engages when batch=1)
    "embed": ("data", "pod"),  # FSDP axes on weights' d_model dim (params
                               # shard over the pod axis too on 512 chips)
    "embed_tp": "model",    # rows of row-parallel matmuls (flattened heads*dim / mlp)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "ssm_heads": "model",
    "layers": None,
    "stage": "stage",
    # PCILT [G, V, O] grouped tables (and ShardedSharedPool shard stacks):
    # the segment axis G shards over the tensor-parallel axis; each device's
    # fetch-and-sum partial is psum'd (core.lut_layers mesh execution).
    "table_seg": "model",
}


#: Logical axes of a grouped PCILT table ``[G, V, O]``: only the segment
#: axis shards — the offset axis V is addressed by every device's local
#: fetches and the out axis rides the adder tree / psum.
PCILT_TABLE_AXES: Tuple[Optional[str], ...] = ("table_seg", None, None)


def pcilt_table_pspec(G: int, ndim: int = 3,
                      rules: Optional[ShardingRules] = None,
                      mesh_axis: Optional[str] = None,
                      seg_axis: int = 0) -> P:
    """PartitionSpec for a PCILT table operand whose segment axis is
    position ``seg_axis``.

    The segment axis (``G`` for dense ``[G, V, O]`` tables, the shard stack
    for ``ShardedSharedPool.pools``/``.seg_idx``; ``seg_axis=1`` for the
    layer-stacked ``[L, G, V, O]`` decode tables, whose leading layer axis
    rides the decode scan and must replicate) shards over the
    ``"table_seg"`` rule with the usual divisibility fallback; every other
    axis replicates.  ``mesh_axis`` overrides the rule table (still applying
    the fallback) for callers that shard over a non-default axis.
    """
    if mesh_axis is not None and rules is not None:
        rules = ShardingRules(rules={"table_seg": mesh_axis},
                              mesh_axis_sizes=rules.mesh_axis_sizes)
    resolved = rules.mesh_axes_for("table_seg", G) if rules is not None else None
    parts = [None] * ndim
    parts[seg_axis] = resolved
    return P(*parts)


def pcilt_table_sharding(mesh: Mesh, G: int, ndim: int = 3,
                         rules: Optional[ShardingRules] = None,
                         mesh_axis: Optional[str] = None,
                         seg_axis: int = 0) -> NamedSharding:
    """NamedSharding placing a PCILT table operand on ``mesh`` (G sharded)."""
    rules = rules or ShardingRules.for_mesh(mesh)
    return NamedSharding(mesh, pcilt_table_pspec(G, ndim, rules, mesh_axis,
                                                 seg_axis))


def logical_to_partition_spec(
    spec_axes: Sequence[Optional[str]], shape: Sequence[int], rules: ShardingRules
) -> P:
    parts = []
    used = set()
    for ax, dim in zip(spec_axes, shape):
        resolved = rules.mesh_axes_for(ax, dim)
        # one mesh axis may shard only one dim; later dims fall back
        flat = (
            tuple(resolved)
            if isinstance(resolved, tuple)
            else (resolved,) if resolved else ()
        )
        if any(a in used for a in flat):
            resolved = None
        used.update(flat)
        parts.append(resolved)
    return P(*parts)


def _tree_map_specs(fn: Callable[[ParamSpec], Any], specs):
    return jax.tree.map(fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def shardings(specs, mesh: Mesh, rules: Optional[ShardingRules] = None):
    rules = rules or ShardingRules.for_mesh(mesh)
    return _tree_map_specs(
        lambda s: NamedSharding(
            mesh, logical_to_partition_spec(s.axes, s.shape, rules)
        ),
        specs,
    )


def shape_structs(specs, mesh: Optional[Mesh] = None, rules=None):
    """ShapeDtypeStructs (with shardings when a mesh is given) — dry-run food."""
    if mesh is None:
        return _tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)
    shards = shardings(specs, mesh, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs,
        shards,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(spec.shape[:-1])
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path``, version-tolerant (see ``repro.compat``)."""
    from repro.compat import tree_flatten_with_path

    return tree_flatten_with_path(tree, is_leaf=is_leaf)


def materialize(specs, key: jax.Array):
    """Concrete params; per-leaf keys derived by path so order is stable."""
    leaves, treedef = flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    out = []
    for path, spec in leaves:
        sub = jax.random.fold_in(key, abs(hash(jax.tree_util.keystr(path))) % (2**31))
        out.append(_init_one(spec, sub))
    return jax.tree.unflatten(treedef, out)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) for s in leaves)


def spec_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)
