"""Mamba2 (state-space duality) blocks: chunked train/prefill + O(1) decode.

The SSD algorithm (Dao & Gu, 2024) splits the sequence into chunks: an
intra-chunk quadratic term (masked ``C Bᵀ`` attention-like matmuls — MXU
food), plus an inter-chunk state recurrence carried by ``lax.scan``.  Decode
keeps a constant-size ``(conv_state, ssd_state)`` instead of a KV cache —
which is exactly why the ``long_500k`` cell is runnable for the SSM/hybrid
architectures (DESIGN.md §7).

Sharding: projections are tensor-parallel on the inner channel dim ("mlp"
rule); the SSD interior shards over heads when the head count divides the TP
degree (zamba2: 112 heads ✓) and falls back to replicated SSD compute for
tiny models (mamba2-130m: 24 heads — noted in the roofline analysis).

PCILT integration (paper §6): the depthwise conv1d frontend is the paper's
small-filter/large-signal sweet spot; with ``cfg.pcilt`` set, serving builds
per-layer ``[C, V]`` tables once (``build_pcilt_conv`` /
``MambaLM.build_pcilt``) and both prefill and decode route the conv through
the **fused** PCILT pipeline (``core.lut_layers.pcilt_depthwise_conv1d``
``path="fused"``): quantize, causal tap-stack, offset-pack, and the
one-fetch-per-output lookup all run in VMEM — the decode step's offsets
never exist in HBM.  Tables are plain arrays, so they scan over the layer
axis exactly like parameters.

Full-PCILT decode (PR 5): the decode *projections* — ``wz``/``wx``/``wB``/
``wC``/``wdt`` on the block input and ``wo`` on the gated output — also
execute as table fetches.  The per-layer ``[G, V, O]`` grouped tables of
each projection stack into one layer-resident ``[L, G, V, O]`` array
(``MambaLM.build_pcilt(proj_scales=...)`` /
``core.serving.convert_mamba_decode``); the decode scan carries only the
integer layer index and that layer's calibrated activation scale, and
:func:`_proj` dispatches ``core.lut_layers.pcilt_linear(stacked=layer)`` —
the scalar-prefetch stacked kernel stages the layer's tiles straight out of
the resident stack, so a decode step's matmuls become fetches end to end.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Ctx, dense_spec, dense, rmsnorm_spec, rmsnorm
from .module import ParamSpec

__all__ = ["mamba_spec", "mamba_block", "mamba_decode", "ssm_cache_specs",
           "build_pcilt_conv", "PROJ_NAMES"]

#: The decode projections a full-PCILT conversion replaces with table
#: fetches: the five block-input projections plus the output projection.
PROJ_NAMES = ("wz", "wx", "wB", "wC", "wdt", "wo")


def build_pcilt_conv(params, cfg, scale):
    """Offline PCILT build for one layer's conv frontend: ``conv_w [k, C]``
    -> per-channel tables ``[C, 2**(act_bits*k)]`` (requires ``cfg.pcilt``).

    ``scale`` is the calibrated per-tensor activation scale of the conv
    input (the pre-activation ``xBC`` stream).  The returned dict is what
    ``mamba_block`` / ``mamba_decode`` accept as ``pcilt=``; stack the
    tables over layers to scan them (``models.mamba.MambaLM.build_pcilt``).
    """
    from repro.core import QuantSpec, build_dwconv_tables

    if cfg.pcilt is None:
        raise ValueError(
            "build_pcilt_conv requires cfg.pcilt (a configs.base.PCILTConfig "
            "supplying act_bits/group for the table build); got None — set "
            "cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(...)) before "
            "converting, or run the conv dense with pcilt=None")
    # the conv input (xBC) is a pre-activation stream — signed, so the
    # grid must straddle zero (symmetric), unlike post-ReLU CNN codes
    spec = QuantSpec(bits=cfg.pcilt.act_bits, symmetric=True)
    tables = build_dwconv_tables(params["conv_w"], spec, scale)
    return {"tables": tables, "scale": scale, "spec": spec}


def _proj(params, name, x, cfg, proj, with_stats: bool = False):
    """One decode projection: PCILT stacked fetch, host-packed baseline, the
    fake-quant dense reference, or the plain dense matmul.

    ``proj`` is the per-layer slice of the full-PCILT bundle (see
    ``models.mamba.MambaLM.decode_step``): the stacked ``[L, G, V, O]``
    tables per projection (closure-resident, *not* scanned), this layer's
    index and calibrated per-tensor scales (both scan-carried), the shared
    ``QuantSpec``/``group``, and the dispatch ``path`` — ``"fused"`` (the
    scalar-prefetch stacked kernel), a host-packed reference path
    (``"kernel"``/``"gather"``/``"onehot"``: slices the layer's table, the
    copy the stacked kernel avoids — the benchmark baseline), or
    ``"dense_fq"`` (dense matmul on fake-quantized input: the parity oracle
    the table fetch must equal, since the fetch is exact on the quantized
    grid).

    Resilience: when the bundle carries a per-layer health bit
    (``proj["ok"]``, a traced bool from ``decode_step(layer_ok=...)``), the
    fetch runs under ``lax.cond`` against the dense fake-quant oracle — a
    layer whose tables failed their integrity/health check is demoted to the
    oracle branch without retracing (the bit is a runtime argument, not a
    closure constant), and the request keeps being served correctly.

    Drift sentinel: ``with_stats=True`` returns ``(out, count, ratio)`` —
    the saturation statistics of the quantizer feeding this projection
    (``core.quantization.quantize_with_stats`` semantics).  The fused
    stacked fetch reduces the counters inside the kernel grid; the *oracle*
    branch computes the identical stats host-side on the same input, so a
    demoted layer keeps reporting drift (the monitor can observe recovery /
    recalibrate while the layer serves from the oracle) and both
    ``lax.cond`` branches return matching pytrees.
    """
    if proj is None or name not in proj["tables"]:
        out = dense(params[name], x, cfg.dtype)
        if with_stats:  # dense projections never saturate a quantizer
            return out, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32)
        return out
    from repro.core import fake_quant, pcilt_linear, quantize_with_stats

    scale = proj["scale"][name]
    path = proj.get("path", "fused")

    def _oracle(xx):
        xq = fake_quant(xx.astype(jnp.float32), proj["spec"], scale)
        out = dense(params[name], xq, jnp.float32).astype(cfg.dtype)
        if with_stats:
            _, count, ratio = quantize_with_stats(xx, proj["spec"], scale)
            return out, count, ratio
        return out

    if path == "dense_fq":
        return _oracle(x)

    def _fetch(xx):
        tables = proj["tables"][name]
        paired = bool(proj.get("paired"))
        # Covered reduction width: dense stacks are [L, G, V, O] (G on axis
        # 1, width G*group); paired stacks are seg-major [G2, L, V2, O]
        # (pairs on axis 0, width G2*2*group — phantom slot included).
        want = (tables.shape[0] * 2 * proj["group"] if paired
                else tables.shape[1] * proj["group"])
        pad = want - xx.shape[-1]
        if pad:  # group-alignment slots: table rows built from zero weights
            xx = jnp.concatenate(
                [xx, jnp.zeros((*xx.shape[:-1], pad), xx.dtype)], axis=-1)
        out = pcilt_linear(xx, tables, proj["spec"], scale, proj["group"],
                           path=path, stacked=proj["layer"],
                           mesh=proj.get("mesh"),
                           mesh_axis=proj.get("mesh_axis", "model"),
                           paired=paired, return_stats=with_stats)
        if with_stats:
            out, count, ratio = out
            return out.astype(cfg.dtype), count, ratio
        return out.astype(cfg.dtype)

    ok = proj.get("ok")
    if ok is None:
        return _fetch(x)
    return jax.lax.cond(ok, _fetch, _oracle, x)


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def mamba_spec(cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_ch = _dims(cfg)
    GN = s.n_groups * s.d_state
    return {
        "wz": dense_spec(d, d_inner, ("embed", "mlp"), dtype=dtype),
        "wx": dense_spec(d, d_inner, ("embed", "mlp"), dtype=dtype),
        "wB": dense_spec(d, GN, ("embed", None), dtype=dtype),
        "wC": dense_spec(d, GN, ("embed", None), dtype=dtype),
        "wdt": dense_spec(d, H, ("embed", None), dtype=dtype),
        "conv_w": ParamSpec((s.conv_kernel, conv_ch), (None, "mlp"), dtype, "fan_in"),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), dtype, "zeros"),
        "A_log": ParamSpec((H,), (None,), dtype, "zeros"),
        "dt_bias": ParamSpec((H,), (None,), dtype, "zeros"),
        "D": ParamSpec((H,), (None,), dtype, "ones"),
        "norm": rmsnorm_spec(d_inner, dtype),
        "wo": dense_spec(d_inner, d, ("mlp", "embed"), dtype=dtype),
    }


def _conv1d(params, cfg, x, conv_state=None, pcilt=None,
            with_stats: bool = False):
    """Causal depthwise conv over [B, T, C]; returns (y, new_state).

    With ``pcilt`` set (see :func:`build_pcilt_conv`) the tap-dot is a PCILT
    fetch through the fused Pallas pipeline: decode evaluates the assembled
    ``[B, k, C]`` window as a VALID conv (one fetch per channel), full
    sequences run the CAUSAL fused kernel over the whole signal.

    ``with_stats=True`` appends the quantizer's saturation ``(count,
    ratio)`` to the return tuple (``quantize_with_stats`` semantics over
    the conv input; the demoted oracle branch computes the identical stats
    host-side so a demoted layer keeps reporting drift).
    """
    k = cfg.ssm.conv_kernel
    w = params["conv_w"].astype(x.dtype)  # [k, C]
    zero_stats = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))
    if conv_state is not None:  # decode: state [B, k-1, C]
        window = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # [B,k,C]
        if pcilt is not None:
            from repro.core import (fake_quant, pcilt_depthwise_conv1d,
                                    quantize_with_stats)

            def _fetch(win):
                out = pcilt_depthwise_conv1d(
                    win, params["conv_w"], pcilt["spec"],
                    pcilt["scale"], tables=pcilt["tables"], path="fused",
                    padding="VALID", return_stats=with_stats)  # [B, 1, C]
                if with_stats:
                    out, count, ratio = out
                    return out.astype(x.dtype), count, ratio
                return out.astype(x.dtype)

            def _oracle(win):
                wq = fake_quant(win.astype(jnp.float32), pcilt["spec"],
                                pcilt["scale"])
                out = jnp.einsum(
                    "bkc,kc->bc", wq, params["conv_w"].astype(jnp.float32)
                )[:, None].astype(x.dtype)
                if with_stats:
                    _, count, ratio = quantize_with_stats(
                        win, pcilt["spec"], pcilt["scale"])
                    return out, count, ratio
                return out

            ok = pcilt.get("ok")
            win = window[:, -k:]
            y = _fetch(win) if ok is None else jax.lax.cond(
                ok, _fetch, _oracle, win)
            if with_stats:
                y, count, ratio = y
        else:
            y = jnp.einsum("bkc,kc->bc", window[:, -k:], w)[:, None]
            count, ratio = zero_stats
        new_state = window[:, -(k - 1):]
        y = y + params["conv_b"].astype(x.dtype)
        if with_stats:
            return y, new_state, count, ratio
        return y, new_state
    if pcilt is not None:
        from repro.core import pcilt_depthwise_conv1d

        y = pcilt_depthwise_conv1d(
            x, params["conv_w"], pcilt["spec"], pcilt["scale"],
            tables=pcilt["tables"], path="fused", padding="CAUSAL",
            return_stats=with_stats)
        if with_stats:
            y, count, ratio = y
            return (y.astype(x.dtype) + params["conv_b"].astype(x.dtype),
                    None, count, ratio)
        return y.astype(x.dtype) + params["conv_b"].astype(x.dtype), None
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    y = y + params["conv_b"].astype(x.dtype)
    if with_stats:
        return y, None, *zero_stats
    return y, None


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """SSD over full sequences — mixed precision.

    xh [B,T,H,P]; dt [B,T,H] (post-softplus, fp32); A [H] (negative);
    Bm, Cm [B,T,H,N] (already repeated across the head group).
    Returns y [B,T,H,P] (bf16) and the final state [B,H,N,P] (fp32).

    Precision policy: the O(T)-sized operands (xh, B, C, xdt, decay-scaled
    variants) stay bf16 — they dominate residency in the backward pass —
    while the numerically-sensitive pieces (log-decay cumsums, inter-chunk
    state recurrence, matmul accumulation via preferred_element_type) run
    fp32.
    """
    f32 = jnp.float32
    cd = jnp.bfloat16
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    while T % Q:
        Q -= 1
    C_ = T // Q

    def r(t):  # [B,T,...] -> [B,C,Q,...]
        return t.reshape(Bsz, C_, Q, *t.shape[2:])

    xh, dt, Bm, Cm = r(xh.astype(cd)), r(dt.astype(f32)), r(Bm.astype(cd)), r(Cm.astype(cd))
    a = dt * A[None, None, None]                      # [B,C,Q,H] log-decay f32
    cum = jnp.cumsum(a, axis=2)                       # within-chunk cumsum
    # decay from j to i (i >= j): exp(cum_i - cum_j)
    li = cum[..., :, None, :]                         # [B,C,Q,1,H] at i
    lj = cum[..., None, :, :]                         # [B,C,1,Q,H] at j
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(li - lj), 0.0)        # [B,C,Q,Q,H] f32 (chunk-local)

    xdt = (xh * dt[..., None].astype(cd)).astype(cd)  # [B,C,Q,H,P] bf16
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cm, Bm,
                        preferred_element_type=f32) * L
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(cd), xdt,
                         preferred_element_type=f32)

    # chunk-final states: S_c = sum_j exp(cum_last - cum_j) * B_j ⊗ xdt_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # [B,C,Q,H] f32
    Bd = (Bm * decay_to_end[..., None].astype(cd)).astype(cd)
    S = jnp.einsum("bcjhn,bcjhp->bchnp", Bd, xdt, preferred_element_type=f32)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))         # [B,C,H] f32

    def step(h, inp):
        s_c, g_c = inp  # [B,H,N,P] f32, [B,H] f32
        h_new = h * g_c[..., None, None] + s_c
        return h_new, h.astype(cd)  # emit state *entering* the chunk

    S_t = jnp.moveaxis(S, 1, 0)                       # [C,B,H,N,P] f32
    g_t = jnp.moveaxis(chunk_decay, 1, 0)             # [C,B,H]
    h_final, h_enter = jax.lax.scan(
        step, jnp.zeros((Bsz, H, N, P), f32), (S_t, g_t)
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)             # [B,C,H,N,P] bf16

    Ce = (Cm * jnp.exp(cum)[..., None].astype(cd)).astype(cd)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Ce, h_enter,
                         preferred_element_type=f32)
    y = (y_intra + y_inter).astype(cd).reshape(Bsz, T, H, P)
    return y, h_final


def _split_heads(cfg, ctx, x_in, B_in, C_in, dt_in):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    Bsz, T = x_in.shape[:2]
    xh = x_in.reshape(Bsz, T, H, s.head_dim)
    xh = ctx.constrain(xh, "batch", None, "ssm_heads", None)
    rep = H // s.n_groups
    Bm = jnp.repeat(B_in.reshape(Bsz, T, s.n_groups, s.d_state), rep, axis=2)
    Cm = jnp.repeat(C_in.reshape(Bsz, T, s.n_groups, s.d_state), rep, axis=2)
    Bm = ctx.constrain(Bm, "batch", None, "ssm_heads", None)
    Cm = ctx.constrain(Cm, "batch", None, "ssm_heads", None)
    return xh, Bm, Cm


def _finish(params, cfg, ctx, y, xh, z, proj=None, return_inner=False,
            with_stats: bool = False):
    d_inner, H, _ = _dims(cfg)
    Bsz, T = y.shape[:2]
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, T, d_inner)
    y = y * jax.nn.silu(z.astype(y.dtype))
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    out = _proj(params, "wo", y, cfg, proj, with_stats=with_stats)
    if with_stats:
        out, count, ratio = out
    out = ctx.constrain(out, "batch", "seq_sp", None)
    if with_stats:
        return out, count, ratio
    if return_inner:  # the wo input — what projection calibration observes
        return out, y
    return out


def mamba_block(params, cfg, ctx: Ctx, x: jax.Array,
                return_state: bool = False, pcilt=None,
                return_calib: bool = False):
    """Full-sequence Mamba2 block (train / prefill).  x [B,T,d] -> [B,T,d].

    ``return_state=True`` additionally emits the decode-ready
    ``{"conv", "ssd"}`` state at the final position (prefill).  ``pcilt``
    (from :func:`build_pcilt_conv`) routes the conv frontend through the
    fused PCILT pipeline.  ``return_calib=True`` additionally emits the
    absmax of the internally-produced PCILT'd activations — the conv input
    (pre-activation ``xBC``) and the ``wo`` input (post-norm gated ``y``) —
    for projection/conv scale calibration
    (``models.mamba.MambaLM.calibrate_pcilt``)."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    z = dense(params["wz"], x, cfg.dtype)
    xi = dense(params["wx"], x, cfg.dtype)
    Bi = dense(params["wB"], x, cfg.dtype)
    Ci = dense(params["wC"], x, cfg.dtype)
    # dt projection in bf16 (fp32 here would materialize a full-width fp32
    # copy of x per layer); softplus/decay math upcasts the tiny [B,T,H]
    dt = dense(params["wdt"], x, cfg.dtype).astype(jnp.float32)
    xi = ctx.constrain(xi, "batch", None, "mlp")

    xBC = jnp.concatenate([xi, Bi, Ci], axis=-1)
    conv_tail = xBC[:, -(s.conv_kernel - 1):]  # pre-activation window
    conv_in_amax = jnp.max(jnp.abs(xBC)).astype(jnp.float32) \
        if return_calib else None
    xBC, _ = _conv1d(params, cfg, xBC, pcilt=pcilt)
    xBC = jax.nn.silu(xBC)
    xi, Bi, Ci = jnp.split(
        xBC, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1
    )

    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh, Bm, Cm = _split_heads(cfg, ctx, xi, Bi, Ci, dt)
    y, h_final = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    out = _finish(params, cfg, ctx, y.astype(cfg.dtype), xh, z,
                  return_inner=return_calib)
    results = []
    if return_calib:
        out, wo_in = out
        results.append({"conv_in": conv_in_amax,
                        "wo_in": jnp.max(jnp.abs(wo_in)).astype(jnp.float32)})
    if return_state:
        results.insert(0, {"conv": conv_tail.astype(jnp.float32),
                           "ssd": h_final.astype(jnp.float32)})
    if results:
        return (out, *results)
    return out


def mamba_decode(
    params, cfg, ctx: Ctx, x: jax.Array, state: Dict, pcilt=None,
    with_stats: bool = False
):
    """One-token step.  x [B,1,d]; state {conv [B,k-1,C], ssd [B,H,N,P]}.

    ``pcilt`` (from :func:`build_pcilt_conv`) replaces the conv frontend's
    tap-dot with one fused PCILT fetch per channel; a ``pcilt["proj"]``
    bundle (``MambaLM.build_pcilt(proj_scales=...)``) additionally routes
    every projection through the layer-stacked fused PCILT GEMV via
    :func:`_proj` — the decode step is then fetch-bound end to end.

    ``with_stats=True`` additionally returns the layer's saturation
    statistics ``{"in"|"conv"|"out": {"count", "ratio"}}`` — one entry per
    *distinct* quantizer the step runs: ``wz``/``wx``/``wB``/``wC``/``wdt``
    all quantize the same block input at the same ``"in"`` scale, so ``wx``
    stands in for the whole input grid; ``"conv"`` is the conv-frontend
    window; ``"out"`` is the post-norm gated ``wo`` input.  ``out`` and the
    new state are bit-identical to the ``with_stats=False`` step."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    proj = None if pcilt is None else pcilt.get("proj")
    stats = {}
    z = _proj(params, "wz", x, cfg, proj)
    xi = _proj(params, "wx", x, cfg, proj, with_stats=with_stats)
    if with_stats:
        xi, count, ratio = xi
        stats["in"] = {"count": count, "ratio": ratio}
    Bi = _proj(params, "wB", x, cfg, proj)
    Ci = _proj(params, "wC", x, cfg, proj)
    dt = _proj(params, "wdt", x, cfg, proj).astype(jnp.float32)

    xBC = jnp.concatenate([xi, Bi, Ci], axis=-1)
    conv = _conv1d(params, cfg, xBC, state["conv"], pcilt=pcilt,
                   with_stats=with_stats)
    if with_stats:
        xBC, conv_state, count, ratio = conv
        stats["conv"] = {"count": count, "ratio": ratio}
    else:
        xBC, conv_state = conv
    xBC = jax.nn.silu(xBC)
    xi, Bi, Ci = jnp.split(
        xBC, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1
    )

    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))[:, 0]  # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh, Bm, Cm = _split_heads(cfg, ctx, xi, Bi, Ci, dt)
    xh1, Bm1, Cm1 = xh[:, 0].astype(jnp.float32), Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32)

    dA = jnp.exp(dt * A[None])                        # [B,H]
    h = state["ssd"].astype(jnp.float32)
    h = h * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bm1 * dt[..., None], xh1
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cm1, h)[:, None]  # [B,1,H,P]
    out = _finish(params, cfg, ctx, y.astype(cfg.dtype), xh, z, proj=proj,
                  with_stats=with_stats)
    new_state = {"conv": conv_state.astype(state["conv"].dtype),
                 "ssd": h.astype(state["ssd"].dtype)}
    if with_stats:
        out, count, ratio = out
        stats["out"] = {"count": count, "ratio": ratio}
        return out, new_state, stats
    return out, new_state


def ssm_cache_specs(cfg, batch: int, n_layers: int, layer_axis: bool = True):
    s = cfg.ssm
    d_inner, H, conv_ch = _dims(cfg)
    conv = (batch, s.conv_kernel - 1, conv_ch)
    ssd = (batch, H, s.d_state, s.head_dim)
    conv_axes = ("batch", None, "mlp")
    ssd_axes = ("batch", "ssm_heads", None, None)
    if layer_axis:
        conv, ssd = (n_layers, *conv), (n_layers, *ssd)
        conv_axes, ssd_axes = ("layers", *conv_axes), ("layers", *ssd_axes)
    return {
        "conv": ParamSpec(conv, conv_axes, jnp.float32, "zeros"),
        "ssd": ParamSpec(ssd, ssd_axes, jnp.float32, "zeros"),
    }
