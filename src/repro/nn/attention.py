"""Grouped-query attention with all the zoo's variants.

One implementation covers: MHA/GQA (kv-head repeat), QKV bias (qwen1.5/2.5),
qk-norm (qwen3), sliding-window (mistral/llava — rolling KV buffer at decode,
which is what makes ``long_500k`` a constant-memory cell for that arch),
cross-attention (whisper decoder), and padded head counts for 16-way tensor
parallelism (DESIGN.md; padding lives in the config so param shapes are
mesh-independent).

Sharding: Q/K/V interiors are constrained over the ``heads``/``kv_heads``
logical axes; KV heads smaller than the TP degree fall back to replication via
the rules' divisibility fallback, and the GQA head-repeat then *slices* the
replicated KV locally (free) instead of forcing an all-gather of Q-sized
tensors.  Score/attend einsums run in fp32.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Ctx, dense_spec, dense, rmsnorm_spec, rmsnorm, rope
from .module import ParamSpec

__all__ = ["attention_spec", "attention", "init_cache_specs"]

NEG_INF = -1e30


def attention_spec(cfg, d_in: Optional[int] = None, dtype=jnp.float32):
    d = d_in or cfg.d_model
    Hp, Hk, Dh = cfg.padded_heads, cfg.padded_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": dense_spec(d, (Hp, Dh), ("embed", "heads", None), cfg.qkv_bias, dtype),
        "wk": dense_spec(d, (Hk, Dh), ("embed", "kv_heads", None), cfg.qkv_bias, dtype),
        "wv": dense_spec(d, (Hk, Dh), ("embed", "kv_heads", None), cfg.qkv_bias, dtype),
        "wo": {"kernel": ParamSpec((Hp, Dh, cfg.d_model),
                                   ("heads", None, "embed"), dtype, "fan_in")},
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_spec(Dh, dtype)
        p["k_norm"] = rmsnorm_spec(Dh, dtype)
    return p


def _project_qkv(params, cfg, ctx: Ctx, x, positions):
    Hp, Hk, Dh = cfg.padded_heads, cfg.padded_kv_heads, cfg.resolved_head_dim
    q = dense(params["wq"], x, cfg.dtype)  # [B, S, Hp, Dh]
    k = dense(params["wk"], x, cfg.dtype)  # [B, S, Hk, Dh]
    v = dense(params["wv"], x, cfg.dtype)
    q = ctx.constrain(q, "batch", None, "heads", None)
    k = ctx.constrain(k, "batch", None, "kv_heads", None)
    v = ctx.constrain(v, "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope" and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


#: past this many score elements per head, switch to the chunked path
_CHUNK_THRESHOLD = 2048 * 2048
_Q_CHUNK = 1024


def _repeat_kv(ctx, q, k, v):
    Hp, Hk = q.shape[-2], k.shape[-2]
    if Hk != Hp:  # GQA: repeat KV; replicated->sharded is a local slice
        rep = Hp // Hk
        k = jnp.repeat(k, rep, axis=-2)
        v = jnp.repeat(v, rep, axis=-2)
        time_sharded = False
        if ctx.decode and ctx.mesh is not None:
            # decode may carry a time-sharded cache (kvshard variant): keep
            # the time axis sharded through the repeat — forcing heads there
            # would all-gather the whole cache every step.  Only applies when
            # the cache_seq rule actually resolves (base rules: batch owns
            # the data axes, cache_seq falls back, heads stay sharded).
            from .module import logical_to_partition_spec

            spec = logical_to_partition_spec(
                ("batch", "cache_seq", "kv_heads", None), k.shape, ctx.rules)
            time_sharded = spec[1] is not None
        if time_sharded:
            k = ctx.constrain(k, "batch", "cache_seq", None, None)
            v = ctx.constrain(v, "batch", "cache_seq", None, None)
        else:
            k = ctx.constrain(k, "batch", None, "heads", None)
            v = ctx.constrain(v, "batch", None, "heads", None)
    return k, v


def _sdpa_dense(cfg, ctx: Ctx, q, k, v, mask) -> jax.Array:
    """Materialized-scores path (small S·T: decode, smoke tests)."""
    Dh = q.shape[-1]
    scores = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (Dh ** -0.5)
    scores = ctx.constrain(scores, "batch", "heads", None, None)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return ctx.constrain(out.astype(cfg.dtype), "batch", None, "heads", None)


def _sdpa_chunked(cfg, ctx: Ctx, q, k, v, q_pos, kv_pos, causal: bool):
    """Flash-style attention: scan over query blocks, never materializing the
    [B,H,S,T] score tensor (peak = one [B,H,q_blk,T] f32 block).

    This is what makes ``prefill_32k`` (and 4k training of the big archs) fit
    v5e HBM in the XLA path; the Pallas flash kernel replaces it on real
    hardware.  Beyond-paper memory optimization recorded in §Perf.
    """
    B, S, Hp, Dh = q.shape
    T = k.shape[1]
    blk = _Q_CHUNK
    while S % blk:
        blk //= 2
    n = S // blk
    # operands stay bf16 (no full-seq fp32 copies); the MXU accumulates the
    # score/attend matmuls in fp32 via preferred_element_type, and softmax
    # normalization runs on the fp32 block scores — flash-kernel numerics.
    qf = jnp.moveaxis(q.astype(cfg.dtype).reshape(B, n, blk, Hp, Dh), 1, 0)
    qp = jnp.moveaxis(q_pos.reshape(B, n, blk), 1, 0)
    kf = k.astype(cfg.dtype)
    vf = v.astype(cfg.dtype)

    def block(qb, qpb):
        # [B, blk, Hp, Dh], [B, blk] -> [B, blk, Hp, Dh]
        s = jnp.einsum("bshd,bthd->bhst", qb, kf,
                       preferred_element_type=jnp.float32) * (Dh ** -0.5)
        if causal:
            m = kv_pos[:, None, :] <= qpb[:, :, None]
            if cfg.window:
                m &= kv_pos[:, None, :] > qpb[:, :, None] - cfg.window
            s = jnp.where(m[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(cfg.dtype)
        ob = jnp.einsum("bhst,bthd->bshd", p, vf,
                        preferred_element_type=jnp.float32)
        return ob.astype(cfg.dtype)

    # remat each q-block: backward recomputes block scores/probs instead of
    # stacking [n, B, H, blk, T] fp32 probs — the flash-attention property
    # must hold through the backward pass too.
    block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(lambda c, inp: (c, block(*inp)), (), (qf, qp))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hp, Dh)
    return ctx.constrain(out, "batch", None, "heads", None)


def _sdpa(cfg, ctx: Ctx, q, k, v, mask) -> jax.Array:
    """q [B,S,Hp,Dh]; k,v [B,T,Hk,Dh]; mask [B,1,S,T] bool or None."""
    k, v = _repeat_kv(ctx, q, k, v)
    return _sdpa_dense(cfg, ctx, q, k, v, mask)


def _causal_mask(q_pos, kv_pos, window: int):
    """q_pos [B,S], kv_pos [B,T] -> [B,1,S,T] bool."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        m &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    return m[:, None]


def attention(
    params,
    cfg,
    ctx: Ctx,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    cache: Optional[Dict] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output [B,S,d], updated cache).

    Full-sequence when ``cache is None``; single-step decode updates the
    cache in place (rolling slot for sliding-window configs).
    ``cross_kv=(k, v)`` switches to cross-attention (whisper decoder).
    """
    B, S, _ = x.shape
    if cross_kv is not None:
        Hp, Dh = cfg.padded_heads, cfg.resolved_head_dim
        q = dense(params["wq"], x, cfg.dtype)
        if cfg.qk_norm:
            q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        q = ctx.constrain(q, "batch", None, "heads", None)
        kr, vr = _repeat_kv(ctx, q, cross_kv[0], cross_kv[1])
        if S * kr.shape[1] >= _CHUNK_THRESHOLD:
            zeros = jnp.zeros((B, kr.shape[1]), jnp.int32)
            out = _sdpa_chunked(cfg, ctx, q, kr, vr, positions, zeros,
                                causal=False)
        else:
            out = _sdpa_dense(cfg, ctx, q, kr, vr, None)
    elif cache is None:
        q, k, v = _project_qkv(params, cfg, ctx, x, positions)
        kr, vr = _repeat_kv(ctx, q, k, v)
        if causal and S * S >= _CHUNK_THRESHOLD:
            out = _sdpa_chunked(cfg, ctx, q, kr, vr, positions, positions,
                                causal=True)
        else:
            mask = _causal_mask(positions, positions, cfg.window) if causal else None
            out = _sdpa_dense(cfg, ctx, q, kr, vr, mask)
        cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    else:
        q, k_new, v_new = _project_qkv(params, cfg, ctx, x, positions)
        T = cache["k"].shape[1]
        idx = cache["pos"]  # scalar int32: next write position
        slot = jnp.mod(idx, T) if cfg.window else idx
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        cache = {"k": k, "v": v}
        if cfg.window:
            # rolling buffer: every slot holds a token within the window once
            # idx >= T; before that, mask unwritten slots.
            kv_pos = jnp.arange(T, dtype=jnp.int32)[None]
            valid = kv_pos <= idx  # slots written so far (idx new included)
            mask = jnp.broadcast_to(valid[:, None, None, :], (B, 1, S, T))
        else:
            kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
            mask = _causal_mask(positions, kv_pos, 0)
        out = _sdpa(cfg, ctx, q, k, v, mask)

    from .layers import row_parallel

    y = row_parallel(ctx, out.astype(cfg.dtype), params["wo"]["kernel"],
                     "bshd,hde->bse")
    if y is None:
        y = jnp.einsum("bshd,hde->bse", out.astype(cfg.dtype),
                       params["wo"]["kernel"].astype(cfg.dtype))
        y = ctx.constrain(y, "batch", "seq_sp", None)
    return y, cache


def init_cache_specs(cfg, batch: int, max_len: int, n_layers: int,
                     layer_axis: bool = True):
    """ParamSpec pytree for a decode KV cache (sharded batch/kv_heads; the
    cache's time axis falls to the data axis when batch can't shard —
    the long_500k batch-1 case)."""
    Hk, Dh = cfg.padded_kv_heads, cfg.resolved_head_dim
    T = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, T, Hk, Dh)
    axes = ("batch", "cache_seq", "kv_heads", None)
    if layer_axis:
        shape = (n_layers, *shape)
        axes = ("layers", *axes)
    return {
        "k": ParamSpec(shape, axes, jnp.bfloat16, "zeros"),
        "v": ParamSpec(shape, axes, jnp.bfloat16, "zeros"),
    }
