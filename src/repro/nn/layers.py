"""Base layers: sharding context, dense/embedding/norms, rotary embeddings.

All apply functions are pure: ``f(params, x, ...) -> y`` over pytrees built
from :mod:`repro.nn.module` ParamSpecs.  A :class:`Ctx` carries the mesh and
logical->mesh rules so layers can place internal activation sharding
constraints (the Megatron-SP pattern: residual stream sequence-sharded over
the model axis; attention/MLP interiors sharded over heads/mlp).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .module import ParamSpec, ShardingRules, logical_to_partition_spec

from repro.compat import shard_map



__all__ = ["Ctx", "dense_spec", "dense", "embed_spec", "rmsnorm_spec", "rmsnorm",
           "layernorm_spec", "layernorm", "rope", "sinusoidal_positions"]


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Execution context: mesh + rules (None = single-device smoke mode)."""

    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None
    decode: bool = False
    explicit_rs: bool = False  # §Perf: shard_map row-parallel matmuls with
                               # explicit bf16 psum_scatter instead of
                               # letting the partitioner all-reduce

    def constrain(self, x: jax.Array, *logical_axes):
        """Sharding constraint via logical axes; no-op without a mesh.

        Divisibility fallback in the rules means e.g. a seq axis of length 1
        (decode) silently replicates instead of erroring.
        """
        if self.mesh is None:
            return x
        spec = logical_to_partition_spec(logical_axes, x.shape, self.rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    @property
    def data_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def dense_spec(d_in: int, d_out, axes, bias: bool = False, dtype=jnp.float32,
               scale: float = 1.0, init: str = "fan_in"):
    """Kernel [d_in, *d_out] (+ optional bias).  ``axes`` covers all dims."""
    out_shape = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    p = {"kernel": ParamSpec((d_in, *out_shape), tuple(axes), dtype, init, scale)}
    if bias:
        p["bias"] = ParamSpec(tuple(out_shape), tuple(axes[1:]), dtype, "zeros")
    return p


def dense(params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """x [..., d_in] @ kernel [d_in, *rest] -> [..., *rest]."""
    k = params["kernel"].astype(compute_dtype)
    kernel_2d = k.reshape(k.shape[0], -1)
    y = (x.astype(compute_dtype) @ kernel_2d).reshape(*x.shape[:-1], *k.shape[1:])
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def row_parallel(ctx: Ctx, x: jax.Array, w: jax.Array, eq: str,
                 w_gather_axes=("data", "pod")) -> Optional[jax.Array]:
    """Explicit Megatron-SP row-parallel contraction (§Perf 'rowrs').

    ``y = einsum(eq, x, w)`` where the contraction dims are model-sharded
    (x's heads/mlp axis, w's matching axis), finishing with a **bf16
    psum_scatter onto the sequence axis** — vs the partitioner's choice of a
    full (fp32-widened on this backend) all-reduce + slice.  Ring bytes:
    RS = N vs AR = 2N, and the wire dtype stays bf16.

    Returns None when inapplicable (no mesh / seq not divisible / flag off)
    so callers fall back to the einsum + sharding-constraint path.
    """
    if ctx.mesh is None or not ctx.explicit_rs:
        return None
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    tp = sizes.get("model", 1)
    S = x.shape[1]
    if tp == 1 or S % tp or S < tp:
        return None
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in sizes)
    gather_axes = tuple(a for a in w_gather_axes if a in sizes)
    # w: [contract..., d_out] with contract dim 0 model-sharded and d_out
    # FSDP-sharded on the last axis; x: [B, S, contract...] model-sharded
    # on dim 2
    x_spec = P(dp, None, "model", *([None] * (x.ndim - 3)))
    w_spec = P("model", *([None] * (w.ndim - 2)), gather_axes or None)

    def body(xl, wl):
        if gather_axes:
            wl = jax.lax.all_gather(wl.astype(xl.dtype), gather_axes,
                                    axis=wl.ndim - 1, tiled=True)
        y = jnp.einsum(eq, xl, wl.astype(xl.dtype))
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)

    return shard_map(
        body, mesh=ctx.mesh, in_specs=(x_spec, w_spec),
        out_specs=P(dp, "model", None), check_vma=False,
    )(x, w)


# ---------------------------------------------------------------------------
# Embedding / norms
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int, dtype=jnp.float32):
    # 1/sqrt(d) init keeps tied logits ~unit variance at init (CE ≈ ln V)
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), dtype, "embed",
                                   scale=d ** -0.5)}


def rmsnorm_spec(d: int, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), (None,), dtype, "ones")}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), (None,), dtype, "ones"),
            "bias": ParamSpec((d,), (None,), dtype, "zeros")}


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x [..., S, H, D] (D even), positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(length: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32) + offset
    half = d // 2
    freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos[:, None] * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
