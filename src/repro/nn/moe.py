"""Expert-parallel Mixture-of-Experts with explicit shard_map collectives.

Design (DESIGN.md §4): experts shard over the ``model`` mesh axis; tokens
arrive **sequence-sharded** over the same axis (Megatron-SP residual stream),
so dispatch is two capacity-bounded ``all_to_all``s — the minimal-byte EP
schedule — rather than a replicated-compute psum.  At decode (seq len 1 the
sequence can't shard) the layer switches to the psum combine automatically.

Dispatch is scatter-based (positions from a cumsum over the one-hot routing
matrix), all shapes static.  Expert count pads up to the mesh (dead experts
masked at the router, ``-inf`` logits) — the config owns the padding so
parameter trees are mesh-independent.

Aux losses (load-balance + router z-loss) are returned for the trainer.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Ctx, dense
from .module import ParamSpec

from repro.compat import axis_size, shard_map



__all__ = ["moe_spec", "moe_apply"]


def moe_spec(cfg, dtype=jnp.float32):
    m = cfg.moe
    E, d, f = m.padded_experts, cfg.d_model, m.d_ff_expert
    return {
        "router": {"kernel": ParamSpec((d, E), (None, None), dtype, "fan_in")},
        "w_gate": ParamSpec((E, d, f), ("expert", "embed", None), dtype, "fan_in"),
        "w_up": ParamSpec((E, d, f), ("expert", "embed", None), dtype, "fan_in"),
        "w_down": ParamSpec((E, f, d), ("expert", None, "embed"), dtype, "fan_in"),
    }


def _route(params, cfg, x_tokens, compute_dtype):
    """x [t, d] -> (probs [t, k], experts [t, k], aux losses)."""
    m = cfg.moe
    logits = dense(params["router"], x_tokens, jnp.float32)  # [t, E_pad]
    if m.padded_experts > m.n_experts:  # dead padding experts never win
        pad = jnp.full((m.padded_experts - m.n_experts,), -1e30, jnp.float32)
        logits = logits.at[..., m.n_experts:].set(pad)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, experts = jax.lax.top_k(probs_full, m.top_k)  # [t, k]
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # load-balance (Switch) + z-loss
    t = x_tokens.shape[0]
    dispatch_frac = jnp.zeros((m.padded_experts,), jnp.float32).at[
        experts.reshape(-1)
    ].add(1.0) / (t * m.top_k)
    prob_frac = probs_full.mean(0)
    aux = {
        "load_balance": m.n_experts * jnp.sum(dispatch_frac * prob_frac),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return probs.astype(compute_dtype), experts, aux


def _expert_ffn(recv, w_gate, w_up, w_down, compute_dtype):
    """recv [E_loc, c, d] through gated-SiLU expert FFNs."""
    g = jnp.einsum("ecd,edf->ecf", recv, w_gate.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", recv, w_up.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(compute_dtype))


def _moe_body(params, cfg, x_local, model_axis: Optional[str],
              data_axes: Tuple[str, ...], use_a2a: bool):
    """shard_map body.  x_local [t, d] local tokens; expert weights local
    shards [E_loc, ...].  Returns (y_local [t, d], aux)."""
    m = cfg.moe
    cd = cfg.dtype
    E = m.padded_experts
    tp = 1
    if model_axis is not None:
        tp = axis_size(model_axis)
    E_loc = E // tp
    t, d = x_local.shape

    probs, experts, aux = _route(params, cfg, x_local, cd)
    k = m.top_k
    cap = max(1, int(math.ceil(t * k * m.capacity_factor / m.n_experts)))

    flat_e = experts.reshape(-1)                      # [t*k]
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_p = probs.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # [t*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # pre-count
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap                                         # dropped past capacity

    x_cast = x_local.astype(cd)
    send = jnp.zeros((E, cap, d), cd)
    # dropped (over-capacity) entries get an out-of-bounds expert index so the
    # scatter discards them instead of clobbering a real slot
    send = send.at[
        jnp.where(keep, flat_e, E),
        jnp.where(keep, flat_pos, 0),
    ].set(x_cast[flat_tok], mode="drop")

    if use_a2a and model_axis is not None:
        # [E, cap, d] -> split E across shards, gather sources on the cap axis
        recv = jax.lax.all_to_all(
            send, model_axis, split_axis=0, concat_axis=1, tiled=True
        )  # [E_loc, tp*cap, d]
    elif model_axis is not None:
        # psum mode: every shard routed the same (replicated) tokens; take
        # this shard's expert slice locally.
        shard = jax.lax.axis_index(model_axis)
        recv = jax.lax.dynamic_slice_in_dim(send, shard * E_loc, E_loc, axis=0)
    else:
        recv = send

    out = _expert_ffn(recv, params["w_gate"], params["w_up"], params["w_down"], cd)

    if use_a2a and model_axis is not None:
        ret = jax.lax.all_to_all(
            out, model_axis, split_axis=1, concat_axis=0, tiled=True
        )  # [E, cap, d]
    elif model_axis is not None:
        ret = jnp.zeros((E, cap, d), cd)
        shard = jax.lax.axis_index(model_axis)
        ret = jax.lax.dynamic_update_slice_in_dim(ret, out, shard * E_loc, axis=0)
    else:
        ret = out

    gathered = ret[
        jnp.where(keep, flat_e, 0), jnp.where(keep, flat_pos, 0)
    ]  # [t*k, d]
    contrib = jnp.where(keep[:, None], gathered * flat_p[:, None], 0.0)
    y = jnp.zeros((t, d), cd).at[flat_tok].add(contrib)

    if model_axis is not None and not use_a2a:
        y = jax.lax.psum(y, model_axis)
    # aux losses: average across shards so the trainer sees one scalar
    if model_axis is not None:
        axes = tuple(a for a in (*data_axes, model_axis) if a)
        aux = {n: jax.lax.pmean(v, axes) for n, v in aux.items()}
    return y, aux


def moe_apply(params, cfg, ctx: Ctx, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """x [B, S, d] -> (y [B, S, d], aux).  Chooses the EP schedule:

    * mesh + S divisible by TP  -> sequence-sharded all_to_all dispatch,
    * mesh + tiny S (decode)    -> replicated-token psum combine,
    * no mesh (smoke tests)     -> single-shard local routing.
    """
    B, S, d = x.shape
    if ctx.mesh is None:
        y, aux = _moe_body(params, cfg, x.reshape(-1, d), None, (), False)
        return y.reshape(B, S, d), aux

    mesh = ctx.mesh
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    dp_axes = ctx.data_axes
    use_a2a = S % tp == 0 and S >= tp
    x_spec = P(dp_axes, "model" if use_a2a else None, None)

    wspecs = {
        "router": {"kernel": P(None, None)},
        "w_gate": P("model", "data" if "data" in mesh.axis_names else None, None),
        "w_up": P("model", "data" if "data" in mesh.axis_names else None, None),
        "w_down": P("model", None, "data" if "data" in mesh.axis_names else None),
    }

    def body(p, xl):
        bl, sl, _ = xl.shape
        # FSDP: expert weights arrive data-sharded on d/f; cast to the
        # compute dtype *first* so the gather moves bf16, then gather.
        if "data" in mesh.axis_names:
            cast = lambda a: a.astype(cfg.dtype)
            p = dict(
                p,
                w_gate=jax.lax.all_gather(cast(p["w_gate"]), "data", axis=1,
                                          tiled=True),
                w_up=jax.lax.all_gather(cast(p["w_up"]), "data", axis=1,
                                        tiled=True),
                w_down=jax.lax.all_gather(cast(p["w_down"]), "data", axis=2,
                                          tiled=True),
            )
        y, aux = _moe_body(p, cfg, xl.reshape(-1, d), "model", dp_axes, use_a2a)
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(wspecs, x_spec),
        out_specs=(x_spec, {"load_balance": P(), "router_z": P()}),
        check_vma=False,
    )(params, x)
    return y, aux
