"""Layer library: declarative params + sharded transformer/SSM/MoE layers."""

from .module import (
    ParamSpec, ShardingRules, DEFAULT_RULES, logical_to_partition_spec,
    shardings, shape_structs, materialize, count_params, spec_bytes,
    PCILT_TABLE_AXES, pcilt_table_pspec, pcilt_table_sharding,
)
from .layers import Ctx
