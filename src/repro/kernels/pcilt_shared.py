"""Fused Pallas kernels for shared-pool PCILTs (paper extension 3).

Extension 3 keeps "only one PCILT for given algorithm base value(s) and
replace[s] the others with pointers to it".  At segment granularity that is a
deduped pool ``pool[X, V, O]`` of unique segment tables plus an integer
pointer vector ``seg_idx[G]`` mapping each of the ``G`` segments onto its pool
row (``core.pcilt.SharedGroupedTables``).  The dense-fused kernels
(``pcilt_fused.py``) cannot consume that representation — they would force a
``materialize()`` back to the full ``[G, V, O]`` tables in HBM, forfeiting the
entire ext.-3 memory win before the first fetch.

The kernels here stage **the pool and the pointers, never the dense tables**:

* the ``[X, V, Ob]`` pool tile and the ``[Gb]`` pointer block live in VMEM
  (``X << G`` is the whole point — the staged bytes scale with the weights'
  *actual* segment cardinality, so even "stage every group" tilings fit);
* the pointer indirection is resolved *inside* the kernel by accumulating the
  activation one-hot into **pool space**: every segment pointing at pool row
  ``x`` with offset ``v`` fetches the *same* table cell, so the fetch-and-add
  over this grid step's ``Gb`` segments collapses to a multiplicity count
  followed by one small contraction::

      ohv[r, g, v]     = (off[r, g] == v)          # [R, Gb, V] — same build
      sel[g, x]        = (seg_idx[g] == x)         # [Gb, X]    — tiny
      counts[r, v, x]  = sum_g ohv[r, g, v] * sel[g, x]
      out[r, :]       += counts.reshape(R, V*X) @ pool_t.reshape(V*X, Ob)

  where ``pool_t`` is the pool staged **pre-transposed** to ``[V, X, Ob]``
  (done once on the host by ``ops.py``) so the count layout lines up with no
  in-kernel transpose.  The fetch contraction therefore shrinks from the
  dense path's ``[R, Gb*V] x [Gb*V, Ob]`` to ``[R, X*V] x [X*V, Ob]`` —
  fetch compute scales with the pool cardinality ``X``, not the segment
  count ``G``, mirroring exactly how ext. 3 makes the table *memory* scale
  with ``X``.  No data-dependent addressing reaches the memory system
  (compares + two matmuls, TPU-friendly);
* the activation side is identical to the dense-fused pipeline — quantize and
  little-endian shift-or pack in VMEM (helpers imported from
  ``pcilt_fused``) — and counts are small integers built in f32 (exact up to
  2**24 ≫ any Gb), so ``path="shared"`` matches the gather reference to f32
  summation-order tolerance.

Tiling comes from the caller (``ops.py``) via the persistent autotune lookup
table under the ``shared_gemv`` / ``shared_conv2d`` shape keys, which include
the pool cardinality ``X`` (``autotune.shared_*_candidates``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pcilt_fused import _pack_flat, _quantize, _strip_offsets

__all__ = ["pcilt_shared_gemv_pallas", "pcilt_shared_conv2d_pallas"]


def _pool_counts_dot(off, idx, pool_t, *, V: int, X: int):
    """The pooled fetch: ``off [R, Gb]``, ``idx [Gb]``,
    ``pool_t [V, X, Ob]`` (pre-transposed pool) -> f32 ``[R, Ob]``.

    Every segment pointing at pool row ``x`` with offset ``v`` fetches the
    *same* cell, so the adder tree over this grid step's ``Gb`` segments is
    ``counts @ pool``: count how many segments land on each ``(v, x)`` cell
    (an ``[R*V, Gb] x [Gb, X]`` contraction over the dense-cost one-hot),
    then one ``[R, V*X] x [V*X, Ob]`` MXU contraction — ``X/Gb`` of the
    dense kernel's fetch FLOPs.  Counts are small integers built in f32
    (exact up to 2**24 ≫ any Gb), so no precision is lost to the
    multiplicity trick; bf16 pools are promoted to f32 for the contraction
    like the dense path's ``preferred_element_type`` accumulation.
    """
    R, Gb = off.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (R, Gb, V), 2)
    ohv = (off[:, :, None] == lanes).astype(jnp.float32)  # [R, Gb, V]
    sel = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (Gb, X), 1)).astype(jnp.float32)  # [Gb, X]
    counts = jax.lax.dot_general(
        ohv, sel, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [R, V, X]
    return jnp.dot(counts.reshape(R, V * X),
                   pool_t.reshape(V * X, pool_t.shape[-1]).astype(jnp.float32),
                   preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------------
# Shared-pool fused GEMV
# ----------------------------------------------------------------------------


def _gemv_kernel(x_ref, scale_ref, idx_ref, pool_ref, out_ref, *,
                 bits: int, zero_point: int, group: int,
                 Gb: int, V: int, X: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*group]
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    out_ref[...] += _pool_counts_dot(off, idx_ref[0], pool_ref[...], V=V, X=X)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "interpret"),
)
def pcilt_shared_gemv_pallas(
    x: jax.Array,
    scale: jax.Array,
    seg_idx: jax.Array,
    pool: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, n]`` float, scale ``[1, 1]``, seg_idx ``[1, G]`` int32,
    pool ``[X, V, O]`` -> ``[B, O]``.

    ``n == G * group``; B, O are padded to tile multiples by ``ops.py``;
    ``tiles`` is a ``(Bb, Gb, Ob)`` tuple with ``Gb | G``.  The whole pool is
    staged per output tile (pre-transposed to ``[V, X, Ob]`` so the count
    layout needs no in-kernel transpose); only the ``[Gb]`` pointer block
    walks the G axis.
    """
    B, n = x.shape
    G = seg_idx.shape[-1]
    X, V, O = pool.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} "
            f"(x {x.shape}, seg_idx {seg_idx.shape}, pool {pool.shape})")
    pool_t = jnp.transpose(pool, (1, 0, 2))  # [V, X, O], once per call
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_gemv_kernel, bits=bits, zero_point=zero_point,
                          group=group, Gb=Gb, V=V, X=X),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, Gb * group), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, Gb), lambda i, j, k: (0, k)),
            pl.BlockSpec((V, X, Ob), lambda i, j, k: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(x, scale, seg_idx, pool_t).astype(pool.dtype)


# ----------------------------------------------------------------------------
# Shared-pool fused conv2d
# ----------------------------------------------------------------------------


def _conv_kernel(x_ref, scale_ref, seg_ref, idx_ref, pool_ref, out_ref, *,
                 bits: int, zero_point: int, group: int,
                 kh: int, kw: int, stride: int,
                 Gb: int, V: int, X: int, Hb: int, n_pad: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    off = _strip_offsets(x_ref, scale_ref, seg_ref,
                         bits=bits, zero_point=zero_point,
                         group=group, kh=kh, kw=kw, stride=stride,
                         Gb=Gb, Hb=Hb, n_pad=n_pad)  # [Hb*Wo, Gb]
    acc = _pool_counts_dot(off, idx_ref[0], pool_ref[...], V=V, X=X)
    out_ref[...] += acc.reshape(out_ref.shape)  # [Hb*Wo, Ob] f32


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "kh", "kw", "stride",
                     "n_total", "tiles", "interpret"),
)
def pcilt_shared_conv2d_pallas(
    x: jax.Array,
    scale: jax.Array,
    seg_offset: jax.Array,
    seg_idx: jax.Array,
    pool: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    kh: int,
    kw: int,
    stride: int = 1,
    n_total: int = 0,
    tiles=None,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, Hp, Wp, C]`` float (already spatially padded), scale ``[1, 1]``,
    seg_offset ``[1, 1]`` int32, seg_idx ``[1, G]`` int32, pool ``[X, V, O]``
    -> ``[B, Ho, Wo, O]``.

    Same contract as ``pcilt_fused_conv2d_pallas`` with the dense ``[G, V, O]``
    table operand replaced by (pointers, pool); ``tiles`` is ``(Hb, Gb, Ob)``
    with ``Gb | G`` and ``Hb | Ho``.  ``seg_offset`` / ``n_total`` carry the
    shard's first global segment and the global padded reduction length under
    ``shard_map`` (0 / ``G * group`` when unsharded): pointers stay *local*
    to the staged pool, only the activation-side im2col slice is global.
    """
    B, Hp, Wp, C = x.shape
    G = seg_idx.shape[-1]
    X, V, O = pool.shape
    n = kh * kw * C
    n_tot = n_total or G * group
    if n_tot < max(n, G * group):
        raise ValueError(
            f"n_total {n_tot} must cover the patch length kh*kw*C = {n} "
            f"and the table span G*group = {G}*{group} "
            f"(x {x.shape}, seg_idx {seg_idx.shape}, pool {pool.shape})")
    pool_t = jnp.transpose(pool, (1, 0, 2))  # [V, X, O], once per call
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    Hb, Gb, Ob = tiles
    grid = (B, Ho // Hb, pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_conv_kernel, bits=bits, zero_point=zero_point,
                          group=group, kh=kh, kw=kw, stride=stride,
                          Gb=Gb, V=V, X=X, Hb=Hb, n_pad=n_tot - n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, r, j, k: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((1, Gb), lambda b, r, j, k: (0, k)),
            pl.BlockSpec((V, X, Ob), lambda b, r, j, k: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, Hb, Wo, Ob), lambda b, r, j, k: (b, r, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, O), jnp.float32),
        interpret=interpret,
    )(x, scale, seg_offset, seg_idx, pool_t).astype(pool.dtype)
