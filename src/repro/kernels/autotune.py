"""Persistent tile autotuner for the PCILT Pallas kernels.

Mirrors the PyTorch-Inductor template lookup-table design: the best kernel
tiling for a given problem shape is discovered *once* by timing a small set of
candidate configurations, then persisted to a JSON lookup table keyed by
``(kernel, B, G, V, O, dtype, backend)``.  Every later dispatch on the same
shape key is a pure dict hit — zero timing runs, zero extra compiles.

Cache format (JSON, one object per shape key)::

    {
      "fused_gemv|B=8,G=512,V=16,O=1024,bits=2,g=2,dtype=float32|backend=cpu": {
        "tiles": {"Bb": 8, "Gb": 512, "Ob": 128, "row_tile": 8},
        "us": 812.4,          # winning candidate's measured microseconds,
                              # or null when every candidate failed to run
                              # (the heuristic fallback was recorded untimed)
        "candidates": 4       # how many tilings were timed at record time
      },
      "shared_gemv|B=8,G=512,O=1024,V=16,X=16,bits=2,g=2,...": {...},
      ...
    }

Shape-key dimensions are kernel-specific; the shared-pool kernels
(``shared_gemv`` / ``shared_conv2d``) add ``X``, the pool cardinality (number
of deduped segment tables), because the staged-pool VMEM footprint — and so
the winning tiling — scales with ``X`` rather than ``G``.  The layer-stacked
decode GEMV (``pcilt_fused.pcilt_fused_gemv_stacked_pallas``) records under
``fused_gemv_stacked`` keys shaped
``fused_gemv_stacked|B=...,G=...,L=...,O=...,V=...,bits=...,g=...,dtype=...|backend=...``:
``L`` is the stacked layer count (a ``[L, G, V, O]`` operand with a
different ``L`` is a different HBM-resident problem even though the staged
per-layer ``[1, Gb, V, Ob]`` tile is L-independent), and ``G`` is — as for
every mesh-dispatched kernel — the **local** shard's segment count
(``G/D`` under a model-axis mesh), so stacked tunings recorded at different
device counts occupy different keys; the ``tiles`` entry reuses the plain
``TileConfig`` fields (``Bb``/``Gb``/``Ob``; ``row_tile`` unused, recorded
as 8), and a failed stacked tune records ``us: null`` exactly like every
other kernel.  The fused
depthwise-conv1d kernel records under ``fused_dwconv1d`` keys shaped
``fused_dwconv1d|B=...,C=...,T=...,V=...,bits=...,k=...,dtype=...|backend=...``
(``T`` is the *output* length, ``k`` the tap count); its ``tiles`` entry
reuses the ``TileConfig`` fields as ``Bb`` = time tile ``Tb`` and ``Ob`` =
channel tile ``Cb`` (``Gb``/``row_tile`` unused, recorded as 1/8).  Conv2d
keys tuned under a mesh use the **local** shard's ``G`` (see below); the
``seg_offset`` operand of the fused/shared conv kernels does not enter the
key — it only shifts which patch columns the in-VMEM im2col slices, never
the tiling-relevant shapes.  ``us`` is strict JSON: ``null``, never a bare
``NaN`` token (which ``jq`` and strict parsers reject); ``TileCache`` both
writes and tolerates it.

**Sharded keying policy.**  Mesh execution (``core.lut_layers`` ``mesh=``)
dispatches the kernels from inside ``shard_map``, so the shapes reaching
``shape_key`` are the per-device *local* shard shapes — ``G/D`` segments,
local pool cardinality — and ``PCILTLinear.tune`` likewise tunes on the
local shard.  Two caches tuned at different device counts therefore record
under different keys (``G=512`` at 1 device vs ``G=256`` at 2 vs ``G=128``
at 4 ...) and can never collide; conversely, two deployments whose local
problems are identical deliberately share one entry — the tiling depends
only on the problem the kernel actually sees.  A failed sharded tune records
``us: null`` exactly like an unsharded one.

The cache file lives at ``$REPRO_PCILT_TUNE_CACHE`` (tests point this at a
tmpdir) or ``~/.cache/repro-pcilt/tiles.json`` by default, and is written
atomically (tmp + rename) so concurrent processes can share it.  On save, a
process merges the freshest on-disk state with **only the keys it recorded
itself** — last writer wins per key, and a writer can never clobber another
process's newer entry for a key it merely loaded at startup.

Policy:

* **lookup** is always on: every ``ops.py`` dispatch consults the cache and
  uses the recorded tiles on a hit, falling back to the VMEM-budget heuristic
  (``default_tiles``) on a miss.
* **tuning** (the timing runs on a miss) only happens eagerly — never under a
  ``jit`` trace, where there are no concrete arrays to time — and only when
  requested: pass ``autotune=True`` to the ``ops`` wrappers, or set
  ``REPRO_PCILT_AUTOTUNE=1`` to make it the ambient default.

``TIMING_RUNS`` counts individual timed candidate executions; tests assert it
stays zero on a warm cache (the "second process does no work" contract).
"""

from __future__ import annotations

import dataclasses
import json
import logging
import math
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

log = logging.getLogger("repro.autotune")

__all__ = [
    "TileConfig",
    "TileCache",
    "get_cache",
    "reset_cache",
    "shape_key",
    "lookup",
    "tune",
    "gemv_candidates",
    "stacked_gemv_candidates",
    "paired_gemv_candidates",
    "paired_stacked_gemv_candidates",
    "conv2d_candidates",
    "shared_gemv_candidates",
    "shared_conv2d_candidates",
    "dwconv1d_candidates",
    "autotune_enabled",
    "TIMING_RUNS",
    "SCRATCH_BUDGET",
]

#: incremented once per timed candidate execution (reps included).  Tests use
#: this to assert that a warm cache performs *zero* timing runs.
TIMING_RUNS = 0

_DEFAULT_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-pcilt", "tiles.json"
)


#: quarantined cache files kept per path — repeated corruption (flaky disk,
#: crashing writer) must not grow unbounded ``.corrupt`` litter
QUARANTINE_KEEP = 3


def _quarantine_path(path: str) -> str:
    """Timestamp-suffixed quarantine name: ``<path>.corrupt-<ns>``.  Distinct
    per incident, so a second corruption never overwrites the post-mortem
    bytes of the first (the fixed ``.corrupt`` suffix did exactly that)."""
    return f"{path}.corrupt-{time.time_ns()}"


def _prune_quarantine(path: str, keep: int = QUARANTINE_KEEP) -> None:
    """Drop all but the ``keep`` newest quarantined copies of ``path``.
    Sorted by the name's timestamp suffix, not mtime — quarantine renames
    preserve the corrupt file's original mtime, which says when it was
    *written*, not when it was caught."""
    base = os.path.basename(path) + ".corrupt-"
    d = os.path.dirname(path) or "."
    try:
        names = [n for n in os.listdir(d) if n.startswith(base)
                 and n[len(base):].isdigit()]
    except OSError:
        return
    for stale in sorted(names, key=lambda n: int(n[len(base):]))[:-keep]:
        try:
            os.remove(os.path.join(d, stale))
        except OSError:
            pass


def _read_json(path: str, quarantine: bool = True) -> Dict[str, dict]:
    """Read a cache file, tolerating absence silently but never *silently*
    resetting on corruption: an unreadable/unparseable file is loudly
    warned about and (when ``quarantine``) renamed to a timestamped
    ``<path>.corrupt-<ns>`` so the bytes survive for post-mortem while
    tuning restarts empty.  Only the newest :data:`QUARANTINE_KEEP`
    quarantined copies are retained."""
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:
        qpath = _quarantine_path(path)
        log.warning(
            "autotune cache %s is unreadable (%s: %s); starting empty — "
            "corrupt file preserved at %s",
            path, type(e).__name__, e, qpath)
        if quarantine:
            try:
                os.replace(path, qpath)
            except OSError:
                pass  # read-only fs etc.: keep serving, just without quarantine
            _prune_quarantine(path)
        return {}


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One kernel tiling: batch/group/output block plus the conv row strip."""

    Bb: int
    Gb: int
    Ob: int
    row_tile: int = 8

    def to_json(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, int]) -> "TileConfig":
        cfg = TileConfig(
            Bb=int(d["Bb"]), Gb=int(d["Gb"]), Ob=int(d["Ob"]),
            row_tile=int(d.get("row_tile", 8)),
        )
        if min(cfg.Bb, cfg.Gb, cfg.Ob, cfg.row_tile) < 1:
            raise ValueError(f"non-positive tile in cache entry: {d}")
        return cfg


def autotune_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve an ``autotune=`` argument against the ambient env default."""
    if flag is not None:
        return flag
    return os.environ.get("REPRO_PCILT_AUTOTUNE", "0") not in ("", "0", "false")


def shape_key(kernel: str, *, dtype, backend: str, **dims: int) -> str:
    """Stable string key for one problem shape, e.g.
    ``fused_gemv|B=8,G=512,V=16,O=1024,dtype=float32|backend=cpu``."""
    parts = ",".join(f"{k}={v}" for k, v in sorted(dims.items()))
    return f"{kernel}|{parts},dtype={dtype}|backend={backend}"


class TileCache:
    """The persistent shape-key -> TileConfig lookup table."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get("REPRO_PCILT_TUNE_CACHE") or _DEFAULT_CACHE
        self._entries: Dict[str, dict] = {}
        #: keys recorded by *this process* — the only keys a save may overwrite
        #: on disk (the "last writer wins per key only" contract).
        self._dirty: set = set()
        self._load()

    def _load(self) -> None:
        self._entries = _read_json(self.path)

    def _save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # Start from the freshest on-disk state and overlay only the keys this
        # process actually recorded.  Overlaying the whole in-memory dict would
        # clobber entries a concurrent tuner wrote after our startup load with
        # our stale copies of them.  A file that went corrupt since load is
        # quarantined (warned + renamed *.corrupt) and the merge starts empty.
        on_disk = _read_json(self.path)
        merged = dict(on_disk)
        merged.update({k: self._entries[k] for k in self._dirty
                       if k in self._entries})
        for e in merged.values():
            # Legacy cache files may carry bare-NaN timings (json.load accepts
            # them); sanitize on the way out or allow_nan=False below would
            # crash every later record() — dispatch must never crash on a
            # malformed cache.
            if isinstance(e, dict) and isinstance(e.get("us"), float) \
                    and not math.isfinite(e["us"]):
                e["us"] = None
        self._entries = merged
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            # allow_nan=False: a bare NaN token is not valid JSON and breaks
            # strict parsers / jq on the shared cache file.
            json.dump(self._entries, f, indent=1, sort_keys=True,
                      allow_nan=False)
        os.replace(tmp, self.path)

    def lookup(self, key: str) -> Optional[TileConfig]:
        e = self._entries.get(key)
        if not e:
            return None
        try:
            return TileConfig.from_json(e["tiles"])
        except (KeyError, TypeError, ValueError):
            # A malformed hand-edited / cross-version entry must degrade to
            # the heuristic, never crash dispatch.
            return None

    def record(self, key: str, tiles: TileConfig, us: Optional[float],
               candidates: int) -> None:
        if us is not None and not math.isfinite(us):
            us = None  # "untimed fallback" is null in the JSON, never NaN/Inf
        self._entries[key] = {
            "tiles": tiles.to_json(), "us": us, "candidates": candidates,
        }
        self._dirty.add(key)
        self._save()


_CACHE: Optional[TileCache] = None


def get_cache() -> TileCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = TileCache()
    return _CACHE


def reset_cache(path: Optional[str] = None) -> TileCache:
    """Drop the in-memory cache and reload from disk (tests: simulates a fresh
    process sharing the same persisted lookup table)."""
    global _CACHE
    _CACHE = TileCache(path)
    return _CACHE


def lookup(key: str) -> Optional[TileConfig]:
    return get_cache().lookup(key)


def _time_one(fn: Callable[[], None], reps: int, warmup: int) -> float:
    global TIMING_RUNS
    for _ in range(warmup):
        fn()
        TIMING_RUNS += 1
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
        TIMING_RUNS += 1
    return (time.perf_counter() - t0) / reps * 1e6  # us


def tune(
    key: str,
    candidates: Sequence[TileConfig],
    bench: Callable[[TileConfig], Callable[[], None]],
    reps: int = 2,
    warmup: int = 1,
) -> TileConfig:
    """Miss -> time every candidate, record the winner; hit -> return it.

    ``bench(cfg)`` returns a nullary closure that runs the kernel once (and
    blocks) at tiling ``cfg``.  A candidate that fails to run (e.g. a tiling
    the backend rejects) is skipped rather than fatal.
    """
    cache = get_cache()
    hit = cache.lookup(key)
    if hit is not None:
        return hit
    best: Optional[TileConfig] = None
    best_us = float("inf")
    tried = 0
    for cfg in candidates:
        try:
            fn = bench(cfg)
            us = _time_one(fn, reps=reps, warmup=warmup)
        except Exception:
            continue
        tried += 1
        if us < best_us:
            best, best_us = cfg, us
    if best is None:  # nothing ran; fall back to the first heuristic candidate
        # Recorded with us=null (valid JSON) — "untimed", not a bare NaN token.
        best, best_us = candidates[0], None
    cache.record(key, best, best_us, tried)
    return best


# ----------------------------------------------------------------------------
# Candidate generators.  Small sets on purpose: each candidate costs a kernel
# compile at tune time, and the heuristic default is always candidate 0 so a
# degenerate tune (every candidate fails) still dispatches correctly.
# ----------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _fit_gb(G: int, V: int, Ob: int, itemsize: int,
            vmem_budget: int = 8 * 2**20) -> int:
    """Largest group-tile whose staged ``[Gb, V, Ob]`` table fits the budget
    and divides G (bf16 tables halve itemsize, doubling the groups staged)."""
    cap = max(1, vmem_budget // max(V * Ob * itemsize, 1))
    Gb = max(1, min(G, cap))
    while G % Gb:
        Gb -= 1
    return Gb


#: Per-grid-step scratch budget for the in-kernel one-hot (and, for the
#: shared kernels, the pool-space counts).  Deliberately looser than the
#: staged-table budget: scratch is transient VPU/VMEM working set, but a
#: tiling whose one-hot alone oversubscribes the chip can never compile —
#: generating it only to have ``tune`` compile-reject it is pure waste.
SCRATCH_BUDGET = 12 * 2**20


def _fit_scratch_gb(G: int, R: int, V: int, onehot_itemsize: int = 4,
                    fixed_bytes: int = 0,
                    budget: float = SCRATCH_BUDGET) -> int:
    """Largest group-tile whose per-grid-step scratch fits ``budget``.

    The analytic mirror of :func:`_fit_gb` for the *activation-side* scratch
    the kernels materialize each grid step: the ``[R, Gb, V]`` one-hot
    (``R`` = rows per step — ``Bb`` for GEMV, ``Hb*Wo`` for conv) in
    ``onehot_itemsize`` bytes, plus ``fixed_bytes`` of Gb-independent scratch
    (the shared kernels' ``[R, V, X]`` counts and staged ``[V, X, Ob]``
    pool).  Replaces try-compile pruning: candidates above the bound used to
    be generated anyway and relied on TPU compile-rejection inside ``tune``
    — every rejection a wasted compile.  Returns the largest ``Gb | G``
    admitted (>= 1, so degenerate budgets still yield a dispatchable tile).
    """
    avail = budget - fixed_bytes
    per_gb = max(R * V * onehot_itemsize, 1)
    if avail < per_gb:
        cap = 1
    elif math.isinf(avail):  # tests pass float('inf') to reproduce the
        cap = G              # old unbounded try-compile sweep
    else:
        cap = max(1, int(avail // per_gb))
    Gb = max(1, min(G, cap))
    while G % Gb:
        Gb -= 1
    return Gb


def gemv_candidates(B: int, G: int, V: int, O: int, itemsize: int = 4,
                    scratch_budget: float = SCRATCH_BUDGET
                    ) -> List[TileConfig]:
    """Tilings for the (fused) GEMV: vary Ob (lane blocks) and Gb (staging).

    Candidate 0 is always the VMEM-budget heuristic (the no-tune fallback).
    Later candidates trade staging footprint for fewer grid steps, up to
    "stage everything" — every ``Gb`` is pre-clamped by the analytic scratch
    bound (:func:`_fit_scratch_gb`: the fused kernel's ``[Bb, Gb*V]``
    one-hot), so no candidate relies on TPU compile-rejection to be pruned.
    On CPU (interpret mode, where per-grid-step overhead dominates) the
    largest admitted staging usually wins.
    """
    Bb = min(128, _round_up(max(B, 1), 8))
    O_full = _round_up(O, 128) if O >= 128 else O
    g_cap = _fit_scratch_gb(G, Bb, V, itemsize, budget=scratch_budget)
    out: List[TileConfig] = []
    seen = set()

    def add(gb: int, ob: int) -> None:
        gb = max(1, min(gb, g_cap))
        while G % gb:
            gb -= 1
        if (gb, ob) not in seen:
            seen.add((gb, ob))
            out.append(TileConfig(Bb=Bb, Gb=gb, Ob=ob))

    add(_fit_gb(G, V, min(128, O_full), itemsize), min(128, O_full))  # heuristic
    add(G, O_full)  # stage everything (scratch-clamped): fewest grid steps
    for Ob in (128, 256, 512, O_full):
        if Ob > O_full:
            continue
        Gb = _fit_gb(G, V, Ob, itemsize)
        add(Gb, Ob)
        add(max(1, Gb // 4), Ob)
    return out[:6]


def _row_tiles(R: int) -> List[int]:
    """R-aware row tiles for the stacked decode sweeps.

    ``R`` is the decode batch — the number of serving slots stepping
    together.  The first tile is the classic padded sublane tile (one grid
    row covers the whole batch); the rest are power-of-two sub-tiles that
    divide it, splitting the batch across grid rows.  Sub-tiles re-stage the
    layer's table tile once per row step but shrink the per-step one-hot
    scratch ``R``-fold — at R=32-64 with wide stagings that trade starts to
    matter, which is exactly what the sweep measures instead of guessing.
    """
    Bb = min(128, _round_up(max(R, 1), 8))
    out = [Bb]
    t = 8
    while t < Bb:
        if Bb % t == 0:
            out.append(t)
        t *= 2
    return out


def stacked_gemv_candidates(B: int, L: int, G: int, V: int, O: int,
                            itemsize: int = 4,
                            scratch_budget: float = SCRATCH_BUDGET
                            ) -> List[TileConfig]:
    """Tilings for the layer-stacked fused GEMV (``fused_gemv_stacked`` keys).

    The kernel stages the *per-layer slice*: its table tile is the scalar-
    prefetch-selected ``[1, Gb, V, Ob]`` block of the ``[L, G, V, O]``
    operand — byte-identical to the unstacked kernel's ``[Gb, V, Ob]`` tile
    at the same ``(Gb, Ob)``, and the in-kernel ``[Bb, Gb*V]`` one-hot
    scratch is unchanged, so both the staged-table budget (:func:`_fit_gb`)
    and the analytic scratch bound (:func:`_fit_scratch_gb`) carry over to
    the per-layer slice verbatim and the dense sweep is reused as the
    **prefix** (candidate 0 stays the no-tune heuristic fallback).  ``L``
    affects the shape key (a different stack is a different HBM-resident
    problem), never the candidate tiling space.

    The decode batch ``R`` (== ``B`` at dispatch: the serving slot count) is
    a tuned axis: after the dense sweep, :func:`_row_tiles` sub-tile
    variants split the batch across grid rows at the two lead stagings —
    each ``Gb`` re-clamped by the scratch bound at the *smaller* row count,
    which can admit stagings the full-batch tile could not.
    """
    del L  # enters the shape key, not the tiling space (per-layer staging)
    base = gemv_candidates(B, G, V, O, itemsize, scratch_budget=scratch_budget)
    out = list(base)
    seen = {(c.Bb, c.Gb, c.Ob) for c in base}
    for bb in _row_tiles(B)[1:]:
        for lead in base[:2]:  # heuristic + stage-everything stagings
            gb = min(lead.Gb,
                     _fit_scratch_gb(G, bb, V, itemsize,
                                     budget=scratch_budget))
            while G % gb:
                gb -= 1
            if (bb, gb, lead.Ob) not in seen:
                seen.add((bb, gb, lead.Ob))
                out.append(TileConfig(Bb=bb, Gb=gb, Ob=lead.Ob))
    return out[:8]


def _fit_paired_gb(G: int, R: int, Ob: int,
                   budget: float = SCRATCH_BUDGET) -> int:
    """Largest segment-tile whose per-grid-step *gather* scratch fits
    ``budget``: the f32 ``[Gb, R, Ob]`` fetched rows plus the ``[R, Gb]``
    pair-index plane.  The paired kernels fetch table rows with
    ``take_along_axis`` — they never build a one-hot — so unlike
    :func:`_fit_scratch_gb` there is **no V factor**: scratch scales with
    the output tile, not the table cardinality, which is exactly why the
    V→V² trade is free on the activation side.  Returns the largest
    ``Gb | G`` admitted (>= 1)."""
    per_gb = max(R * Ob * 4 + R * 4, 1)
    if math.isinf(budget):
        cap = G
    else:
        cap = max(1, int(budget // per_gb))
    Gb = max(1, min(G, cap))
    while G % Gb:
        Gb -= 1
    return Gb


def paired_gemv_candidates(B: int, G: int, V: int, O: int, itemsize: int = 4,
                           scratch_budget: float = SCRATCH_BUDGET
                           ) -> List[TileConfig]:
    """Tilings for the paired-table GEMV (``fused_gemv_paired`` keys).

    ``G`` and ``V`` are **paired-space**: ``G`` counts segment *pairs*
    (``ceil(G_dense / 2)``) and ``V`` is the squared cardinality
    (``V_dense**2``), matching the ``[G, V, O]`` operand the kernel stages.
    Candidate 0 is the staging heuristic (:func:`_fit_gb` keeps the
    ``[Gb, V, Ob]`` table tile under the 8 MiB budget — the no-tune
    fallback must never oversubscribe VMEM), later candidates trade staging
    for fewer grid steps up to the single-step ``(Gb=G, Ob=O)``
    configuration that usually wins on CPU interpret, and every ``Gb`` is
    clamped by the gather scratch bound (:func:`_fit_paired_gb` — no V
    factor, see there).  An exact-``B`` row tile rides along: batch-1
    decode pads to the sublane multiple otherwise, and on interpret the
    un-padded gather is measurably cheaper.
    """
    Bb = min(128, _round_up(max(B, 1), 8))
    B_exact = max(1, min(B, 128))
    O_full = _round_up(O, 128) if O >= 128 else O
    out: List[TileConfig] = []
    seen = set()

    def add(bb: int, gb: int, ob: int) -> None:
        gb = max(1, min(gb, _fit_paired_gb(G, bb, ob, budget=scratch_budget)))
        while G % gb:
            gb -= 1
        if (bb, gb, ob) not in seen:
            seen.add((bb, gb, ob))
            out.append(TileConfig(Bb=bb, Gb=gb, Ob=ob))

    add(Bb, _fit_gb(G, V, min(128, O_full), itemsize), min(128, O_full))
    add(Bb, G, O_full)        # single grid step (scratch-clamped)
    add(B_exact, G, O_full)   # un-padded rows, single step
    for Ob in (128, O_full):
        if Ob > O_full:
            continue
        add(Bb, _fit_gb(G, V, Ob, itemsize), Ob)
    return out[:6]


def paired_stacked_gemv_candidates(B: int, L: int, G: int, V: int, O: int,
                                   itemsize: int = 4,
                                   scratch_budget: float = SCRATCH_BUDGET
                                   ) -> List[TileConfig]:
    """Tilings for the seg-major layer-stacked paired GEMV
    (``fused_gemv_paired_stacked`` keys; ``[G, L, V, O]`` operand).

    Unlike the dense stacked kernel — which scalar-prefetch-selects a
    per-layer ``[1, Gb, V, Ob]`` slice — the seg-major kernel stages the
    **whole layer axis** for its segment tile (``[Gb, L, V, Ob]``: the
    layer index is folded into the flattened value axis so the row-gather's
    segment iota stays constant), so the staged-table budget acquires an
    ``L`` factor: the heuristic runs :func:`_fit_gb` at effective
    cardinality ``L*V``.  The gather scratch bound is L-independent
    (the fetched ``[Gb, Bb, Ob]`` rows and ``[Bb, Gb]`` indices don't
    grow with the stack), so :func:`_fit_paired_gb` carries over verbatim.

    Like the dense stacked sweep, the decode batch ``R`` (== ``B``: the
    serving slot count) is a tuned axis: :func:`_row_tiles` sub-tile
    variants ride along after the classic candidates, shrinking the
    per-step gather scratch ``R``-fold at the cost of re-staging the
    seg-major ``[Gb, L, V, Ob]`` block per row step.
    """
    Bb = min(128, _round_up(max(B, 1), 8))
    B_exact = max(1, min(B, 128))
    O_full = _round_up(O, 128) if O >= 128 else O
    out: List[TileConfig] = []
    seen = set()

    def add(bb: int, gb: int, ob: int) -> None:
        gb = max(1, min(gb, _fit_paired_gb(G, bb, ob, budget=scratch_budget)))
        while G % gb:
            gb -= 1
        if (bb, gb, ob) not in seen:
            seen.add((bb, gb, ob))
            out.append(TileConfig(Bb=bb, Gb=gb, Ob=ob))

    add(Bb, _fit_gb(G, L * V, min(128, O_full), itemsize), min(128, O_full))
    add(Bb, G, O_full)        # single grid step (scratch-clamped)
    add(B_exact, G, O_full)   # un-padded rows, single step
    for Ob in (128, O_full):
        if Ob > O_full:
            continue
        add(Bb, _fit_gb(G, L * V, Ob, itemsize), Ob)
    for bb in _row_tiles(B)[1:]:  # R sub-tiles: split the batch across rows
        add(bb, G, O_full)
        add(bb, _fit_gb(G, L * V, min(128, O_full), itemsize),
            min(128, O_full))
    return out[:8]


def conv2d_candidates(Ho: int, G: int, V: int, O: int, itemsize: int = 4,
                      Wo: int = 128,
                      scratch_budget: float = SCRATCH_BUDGET
                      ) -> List[TileConfig]:
    """Tilings for the (fused) conv2d: vary the row strip, table staging, and
    output blocking.  Same ordering contract as ``gemv_candidates``: the
    heuristic first, then progressively larger stagings — each ``Gb``
    pre-clamped by the analytic scratch bound at that candidate's row count
    ``R = row_tile * Wo`` (``Wo`` defaults conservatively to 128 for callers
    that don't know the output width)."""
    out: List[TileConfig] = []
    seen = set()
    O_full = _round_up(O, 128) if O >= 128 else O
    Ob0 = min(128, O_full)
    Gb = _fit_gb(G, V, Ob0, itemsize)

    def add(hb: int, gb: int, ob: int) -> None:
        hb = max(1, min(hb, Ho))
        while Ho % hb:
            hb -= 1
        gb = max(1, min(gb, _fit_scratch_gb(G, hb * max(Wo, 1), V, itemsize,
                                            budget=scratch_budget)))
        while G % gb:
            gb -= 1
        if (hb, gb, ob) not in seen:
            seen.add((hb, gb, ob))
            out.append(TileConfig(Bb=1, Gb=gb, Ob=ob, row_tile=hb))

    add(8, Gb, Ob0)  # heuristic
    add(Ho, G, O_full)  # stage everything: one grid step per batch element
    for rt in (8, 4, 2, Ho):
        add(rt, Gb, Ob0)
        add(rt, max(1, Gb // 4), Ob0)
    return out[:6]


def _div_down(x: int, cap: int) -> int:
    """Largest divisor of ``x`` that is ``<= cap`` (and ``>= 1``)."""
    d = max(1, min(x, cap))
    while x % d:
        d -= 1
    return d


def _shared_fixed_bytes(R: int, V: int, X: int, Ob: int, itemsize: int) -> int:
    """Gb-independent per-step scratch of the shared kernels: the f32
    ``[R, V, X]`` counts plus the staged (pre-transposed) ``[V, X, Ob]``
    pool tile."""
    return R * V * X * 4 + V * X * Ob * itemsize


def shared_gemv_candidates(B: int, G: int, V: int, O: int, X: int,
                           itemsize: int = 4,
                           scratch_budget: float = SCRATCH_BUDGET
                           ) -> List[TileConfig]:
    """Tilings for the shared-pool GEMV (``kernels/pcilt_shared.py``).

    The staged table operand is the deduped ``[X, V, Ob]`` pool — its VMEM
    footprint is *independent of Gb*, so unlike the dense kernels ``Gb`` only
    trades one-hot scratch / MXU contraction size against grid steps.  The
    dense sweep stays valid (its budget is just conservative), plus "stage
    as many groups as the scratch admits": the analytic bound
    (:func:`_fit_scratch_gb` over the f32 ``[Bb, Gb, V]`` one-hot with the
    ``[Bb, V, X]`` counts + pool tile as fixed bytes) replaces the old
    unconditional ``Gb=G`` candidates that relied on TPU compile-rejection —
    strictly fewer candidates whenever the bound bites, zero wasted tune
    compiles.  On CPU interpret (grid-step overhead dominates) the largest
    admitted staging usually wins, and small recorded problems admit
    ``Gb=G`` unchanged.
    """
    Bb = min(128, _round_up(max(B, 1), 8))

    def clamp(c: TileConfig) -> Optional[TileConfig]:
        # Re-clamp an inherited dense-sweep candidate against the *shared*
        # kernel's per-step scratch: its one-hot is f32 and the counts +
        # staged pool add Gb-independent fixed bytes the dense bound
        # doesn't know about.  A candidate whose fixed footprint alone
        # (counts + staged pool at this Ob) exceeds the budget is dropped —
        # no Gb can save it, and it's exactly the tiling the old sweep
        # wasted a compile-rejection on.
        fixed = _shared_fixed_bytes(c.Bb, V, X, c.Ob, itemsize)
        if fixed + c.Bb * V * 4 > scratch_budget:  # even Gb=1 won't fit
            return None
        gb = min(c.Gb, _fit_scratch_gb(G, c.Bb, V, 4, fixed,
                                       budget=scratch_budget))
        while G % gb:
            gb -= 1
        return dataclasses.replace(c, Gb=gb)

    out: List[TileConfig] = []
    for c in map(clamp, gemv_candidates(B, G, V, O, itemsize,
                                        scratch_budget=scratch_budget)):
        if c is not None and c not in out:
            out.append(c)
    O_full = _round_up(O, 128) if O >= 128 else O
    for ob in (min(128, O_full), O_full):
        cand = clamp(TileConfig(Bb=Bb, Gb=G, Ob=ob))
        if cand is not None and cand not in out:
            out.append(cand)
    if not out:  # degenerate budget: still emit one dispatchable tile
        out.append(TileConfig(Bb=Bb, Gb=1, Ob=min(128, O_full)))
    return out[:7]


def shared_conv2d_candidates(Ho: int, G: int, V: int, O: int, X: int,
                             itemsize: int = 4, Wo: int = 128,
                             scratch_budget: float = SCRATCH_BUDGET
                             ) -> List[TileConfig]:
    """Shared-pool conv2d tilings: the dense sweep plus the largest
    scratch-admitted "stage every group per row strip" configuration (see
    :func:`shared_gemv_candidates`; ``R = row_tile * Wo`` rows per step)."""
    def clamp(c: TileConfig) -> Optional[TileConfig]:
        R = c.row_tile * max(Wo, 1)
        fixed = _shared_fixed_bytes(R, V, X, c.Ob, itemsize)
        if fixed + R * V * 4 > scratch_budget:  # even Gb=1 won't fit
            return None
        gb = min(c.Gb, _fit_scratch_gb(G, R, V, 4, fixed,
                                       budget=scratch_budget))
        while G % gb:
            gb -= 1
        return dataclasses.replace(c, Gb=gb)

    out: List[TileConfig] = []
    for c in map(clamp, conv2d_candidates(Ho, G, V, O, itemsize, Wo=Wo,
                                          scratch_budget=scratch_budget)):
        if c is not None and c not in out:
            out.append(c)
    O_full = _round_up(O, 128) if O >= 128 else O
    Ob0 = min(128, O_full)
    for rt in (_div_down(Ho, 8), Ho):
        cand = clamp(TileConfig(Bb=1, Gb=G, Ob=Ob0, row_tile=rt))
        if cand is not None and cand not in out:
            out.append(cand)
    if not out:  # degenerate budget: still emit one dispatchable tile
        out.append(TileConfig(Bb=1, Gb=1, Ob=Ob0, row_tile=1))
    return out[:7]


def dwconv1d_candidates(T: int, C: int, V: int, k: int, itemsize: int = 4,
                        scratch_budget: float = SCRATCH_BUDGET
                        ) -> List[TileConfig]:
    """``(Tb, Cb)`` tilings for the fused depthwise conv1d
    (``kernels/pcilt_dwconv1d.py``), encoded as ``TileConfig(Bb=Tb, Ob=Cb)``.

    The kernel's per-step scratch is the *factored* two-level one-hot —
    ``Vl + Vh`` indicator lanes plus the ``[Cb, Vh, Tb]`` partial fetch
    (``V = Vl * Vh``, split at ``(bits*k)//2``) — so the analytic bound caps
    the *time* tile per channel block on ``Vl + 2*Vh`` effective lanes, not
    ``V`` (``T`` is the output length; the staged signal strip adds
    ``(T + k - 1) * Cb`` floats of fixed bytes, and the ``[Cb, V]`` table
    tile is Tb-independent)."""
    Cb = _div_down(C, 128)
    bw = max((V - 1).bit_length(), 1)
    Vl = 1 << (bw // 2)
    Vh = -(-V // Vl)
    v_eff = Vl + 2 * Vh
    out: List[TileConfig] = []
    seen = set()

    def add(tb: int, cb: int) -> None:
        fixed = (T + k - 1) * cb * 4 + cb * V * itemsize
        cap = _fit_scratch_gb(T, cb, v_eff, 4, fixed, budget=scratch_budget)
        tb = _div_down(T, max(1, min(tb, cap)))
        if (tb, cb) not in seen:
            seen.add((tb, cb))
            out.append(TileConfig(Bb=tb, Gb=1, Ob=cb))

    add(128, Cb)   # heuristic: sublane-friendly time tile
    add(T, Cb)     # stage the whole signal (scratch-clamped)
    add(8, Cb)
    if C > 128:
        add(128, _div_down(C, 256))
    return out[:5]
