"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the shape/dtype sweep tests in
``tests/test_kernels.py`` assert against (and double as readable statements of
each kernel's contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pcilt_gemv_ref", "pcilt_conv2d_ref", "pcilt_dwconv1d_ref"]


def pcilt_gemv_ref(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, G], tables [G, V, O] -> [B, O]: sum_g T[g, off[b,g], :]."""
    picked = jnp.take_along_axis(
        tables[None], offsets[:, :, None, None].astype(jnp.int32), axis=2
    )  # [B, G, 1, O]
    return jnp.sum(picked[:, :, 0, :], axis=1).astype(tables.dtype)


def pcilt_conv2d_ref(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, H, W, G], tables [G, V, O] -> [B, H, W, O]."""
    B, H, W, G = offsets.shape
    flat = pcilt_gemv_ref(offsets.reshape(-1, G), tables)
    return flat.reshape(B, H, W, tables.shape[-1])


def pcilt_dwconv1d_ref(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, T, C], tables [C, V] -> [B, T, C]: T[c, off[b,t,c]]."""
    B, T, C = offsets.shape
    return jnp.take_along_axis(
        jnp.broadcast_to(tables, (B, T) + tables.shape),
        offsets[..., None].astype(jnp.int32),
        axis=-1,
    )[..., 0]
