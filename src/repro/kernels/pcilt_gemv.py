"""Pallas TPU kernel: grouped PCILT GEMV/GEMM.

Computes ``out[b, o] = sum_g tables[g, offsets[b, g], o]`` — the paper's
fetch-and-add inner loop (Fig. 6), re-blocked for the TPU memory hierarchy:

* **tables live in VMEM**: each grid step stages a ``[Gb, V, Ob]`` table tile
  (the ASIC's "fast memory block ... situated next to the results adder"
  becomes a BlockSpec-tiled VMEM resident);
* **fetch = one-hot x MXU**: inside the kernel each group's fetch row is
  expressed as ``onehot(offsets) @ table`` so the systolic array performs the
  gather+add of ``Bb`` lanes at once — the TPU-native equivalent of the
  paper's per-PCILT address/data bus (DESIGN.md §2);
* **adder tree = grid accumulation**: the G grid axis is innermost and
  revisits the same output tile, accumulating partial sums in place.

VMEM budget per step (f32): ``Gb*V*Ob + Bb*V + Bb*Ob + Bb*Gb`` words.  The
default tile picks ``Ob=128`` (lane width), ``Bb=128`` (sublane-friendly), and
bounds ``Gb`` so the staged tables stay under ~8 MB, leaving headroom in the
~16 MB/core VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pcilt_gemv_pallas", "default_tiles"]


def default_tiles(B: int, G: int, V: int, O: int, vmem_budget: int = 8 * 2**20,
                  itemsize: int = 4):
    """Pick (Bb, Gb, Ob) tiles: MXU-aligned where possible, VMEM-bounded.

    ``itemsize`` reflects the table storage dtype — bf16 tables halve it and
    so double the groups staged per step under the same budget.
    """
    Ob = min(O, 128)
    Bb = min(B, 128)
    words = vmem_budget // itemsize
    gb_cap = max(1, (words - Bb * V - Bb * Ob) // max(V * Ob, 1))
    Gb = max(1, min(G, gb_cap))
    while G % Gb:  # grid needs an integral number of G tiles
        Gb -= 1
    return Bb, Gb, Ob


def _kernel(off_ref, tab_ref, out_ref, *, Gb: int, V: int):
    """One (Bb, Ob) output tile; accumulate over the Gb staged tables."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    Bb = off_ref.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (Bb, V), 1)

    def body(g, acc):
        # one-hot of this group's offsets: [Bb, V] — VPU compare ...
        oh = (off_ref[:, g][:, None] == lanes).astype(tab_ref.dtype)
        # ... then the "fetch" for all Bb rows at once on the MXU.
        return acc + jnp.dot(
            oh, tab_ref[g], preferred_element_type=jnp.float32
        )

    acc = jax.lax.fori_loop(
        0, Gb, body, jnp.zeros(out_ref.shape, jnp.float32)
    )
    out_ref[...] += acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def pcilt_gemv_pallas(
    offsets: jax.Array, tables: jax.Array, interpret: bool = False, tiles=None
) -> jax.Array:
    """offsets ``[B, G]`` int32, tables ``[G, V, O]`` -> ``[B, O]`` float.

    B, G, O are padded to tile multiples by the caller (see ``ops.py``).
    ``tiles`` is an optional ``(Bb, Gb, Ob)`` override — ``ops.py`` passes the
    winner from the persistent autotune lookup table when one is recorded;
    ``None`` falls back to the VMEM-budget heuristic.
    """
    B, G = offsets.shape
    G2, V, O = tables.shape
    if G != G2:
        raise ValueError(
            f"offsets segment dim {G} != tables segment dim {G2} "
            f"(offsets {offsets.shape}, tables {tables.shape})")
    Bb, Gb, Ob = tiles if tiles is not None else default_tiles(
        B, G, V, O, itemsize=tables.dtype.itemsize)
    Bb, Ob = min(Bb, B), min(Ob, O)
    while G % Gb:
        Gb -= 1
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_kernel, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, Gb), lambda i, j, k: (i, k)),
            pl.BlockSpec((Gb, V, Ob), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), tables.dtype),
        interpret=interpret,
    )(offsets, tables)
