"""Fused Pallas TPU kernels: quantize -> offset-pack -> table-fetch in VMEM.

The host-packed pipeline (``pcilt_gemv.py`` / ``pcilt_conv2d.py``) quantizes,
im2col-packs, and bit-packs offsets *on the host*, materializing a
``[..., G]`` int32 offset tensor in HBM that the kernel then re-reads — for a
conv that tensor is ``[B, Ho, Wo, kh*kw*Cin/group]`` and routinely larger than
the activations themselves.  The kernels here fuse the whole paper pipeline
(Fig. 6: quantize, shift/mask pack, fetch, adder tree) into one ``pallas_call``
over the *raw float activations*, so the offsets live only in VMEM/registers:

* **quantize** — ``clip(round(x / scale) + zero_point, 0, K-1)``, bit-exact
  with ``core.quantization.quantize`` (same round-half-even, same clip);
* **pack** — little-endian shift-or of ``group`` codes per segment, bit-exact
  with ``core.offsets.pack_offsets``;
* **fetch + adder tree** — one *flattened* one-hot contraction per staged
  table tile: instead of a ``fori_loop`` of ``Gb`` small ``[Bb,V] x [V,Ob]``
  dots, the one-hot is laid out as ``[Bb, Gb*V]`` (segment-major) and the
  staged tables reshaped to ``[Gb*V, Ob]``, so the MXU runs a single large
  contraction per grid step.  The adder tree over group tiles is grid
  accumulation on the revisited output block.

Tables may be stored **bf16** (pass ``tables.astype(jnp.bfloat16)``): the
one-hot is built in the table dtype, the contraction *and* the cross-tile
accumulation run in f32 (f32 ``preferred_element_type`` into an f32 output
block, cast to the table dtype once at the end), and the staged-tile VMEM
cost halves — doubling the groups per stage under the same ~8 MB budget
(``autotune._fit_gb`` is itemsize-aware).

Tiling is supplied by the caller (``ops.py``), which consults the persistent
autotune lookup table (``autotune.py``) — cache hit ⇒ zero-cost dispatch,
miss ⇒ the VMEM-budget heuristic, optionally tune-once-and-record.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pcilt_fused_gemv_pallas", "pcilt_fused_gemv_stacked_pallas",
           "pcilt_fused_gemv_paired_pallas",
           "pcilt_fused_gemv_paired_stacked_pallas",
           "pcilt_fused_gemv_plan_pallas",
           "pcilt_fused_conv2d_pallas"]


def _quantize(x, scale, *, bits: int, zero_point: int):
    """In-kernel mirror of ``core.quantization.quantize`` (-> int32 codes)."""
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)


def _quantize_sat(x, scale, *, bits: int, zero_point: int):
    """:func:`_quantize` plus the block's saturation stats the clip discards.

    In-kernel mirror of ``core.quantization.quantize_with_stats``: returns
    ``(codes, count, ratio)`` where ``count`` is the int32 number of elements
    whose *pre-clip* code ``round(x/scale) + zero_point`` fell outside
    ``[0, K)`` and ``ratio`` is f32 ``max(|x|)/scale``.  Same arithmetic,
    same dtype, so the count is exact (elements landing on the clip edge are
    in range) — this is the calibration-drift signal the serving sentinel
    reduces in VMEM alongside the adder tree.
    """
    q = jnp.round(x / scale) + zero_point
    sat = (q < 0) | (q > (1 << bits) - 1)
    codes = jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)
    count = jnp.sum(sat.astype(jnp.int32))
    ratio = (jnp.max(jnp.abs(x)) / scale).astype(jnp.float32)
    return codes, count, ratio


def _pack_flat(codes, *, bits: int, group: int, Gseg: int):
    """``[R, Gseg*group]`` codes -> ``[R, Gseg]`` little-endian offsets."""
    R = codes.shape[0]
    c = codes.reshape(R, Gseg, group)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, group), 2) * bits
    return jnp.sum(jnp.left_shift(c, shifts), axis=-1)  # [R, Gseg]


def _flat_onehot_dot(off, tab, *, V: int):
    """The flattened fetch: ``off [R, Gb]``, ``tab [Gb, V, Ob]`` -> f32 ``[R, Ob]``.

    ``onehot[r, g*V + v] = (off[r, g] == v)`` — one ``[R, Gb*V] x [Gb*V, Ob]``
    MXU contraction replaces the per-group loop of small dots.
    """
    R, Gb = off.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (R, Gb, V), 2)
    oh = (off[:, :, None] == lanes).astype(tab.dtype).reshape(R, Gb * V)
    return jnp.dot(oh, tab.reshape(Gb * V, tab.shape[-1]),
                   preferred_element_type=jnp.float32)


def _take_rows(off, tab):
    """The row-gather fetch: ``off [R, Gb]``, ``tab [Gb, Vt, Ob]`` -> f32
    ``[R, Ob]``.

    The paired-table fetch is literal fetch-and-add — the paper's
    hardware-regime execution model — rather than the dense path's one-hot
    contraction: at ``Vt = V**2`` lanes the one-hot matrix is ``V``-times
    wider than the dense kernel's and the MXU contraction cost explodes
    exactly where the table got cheaper.  ``take_along_axis`` with a
    *constant* segment index (the leading ``Gb`` axis is iota — never
    traced data) lowers to the backend's batched row-gather fast path; the
    adder tree is the f32 sum over the segment axis.  No dot, so bf16
    tables promote to f32 only at the accumulate.
    """
    fetched = jnp.take_along_axis(
        tab, off.T[:, :, None].astype(jnp.int32), axis=1)  # [Gb, R, Ob]
    return jnp.sum(fetched.astype(jnp.float32), axis=0)


# ----------------------------------------------------------------------------
# Fused GEMV
# ----------------------------------------------------------------------------


def _gemv_kernel(x_ref, scale_ref, tab_ref, out_ref, *,
                 bits: int, zero_point: int, group: int, Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*group]
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    # The output block is f32 regardless of table dtype, so the adder tree
    # over G tiles never rounds through bf16 (caller casts once at the end).
    out_ref[...] += _flat_onehot_dot(off, tab_ref[...], V=V)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "interpret"),
)
def pcilt_fused_gemv_pallas(
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, n]`` float, scale ``[1, 1]``, tables ``[G, V, O]`` -> ``[B, O]``.

    ``n == G * group``; B, O are padded to tile multiples by ``ops.py``;
    ``tiles`` is a ``(Bb, Gb, Ob)`` tuple with ``Gb | G``.
    """
    B, n = x.shape
    G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} "
            f"(x {x.shape}, tables {tables.shape})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_gemv_kernel, bits=bits, zero_point=zero_point,
                          group=group, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, Gb * group), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((Gb, V, Ob), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Paired (TL1-style multi-scalar) fused GEMV: two segments per fetch.
# ----------------------------------------------------------------------------


def _gemv_paired_kernel(x_ref, scale_ref, tab_ref, out_ref, *,
                        bits: int, zero_point: int, group: int, Gb: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*2*group]
    # Packing 2*group codes little-endian IS the paired index
    # off_even + off_odd * V (V = 2**(bits*group)) — the same arithmetic
    # `build_paired_tables` indexes its [G/2, V**2, O] entries by, so the
    # in-kernel pack emits the paired offset directly.
    off = _pack_flat(codes, bits=bits, group=2 * group, Gseg=Gb)  # [Bb, Gb]
    out_ref[...] += _take_rows(off, tab_ref[...])


def _gemv_paired_sat_kernel(x_ref, scale_ref, tab_ref,
                            out_ref, cnt_ref, ratio_ref, *,
                            bits: int, zero_point: int, group: int, Gb: int):
    """Counter-carrying :func:`_gemv_paired_kernel` (see
    :func:`_gemv_stacked_sat_kernel` for the dedup/zeroing discipline)."""
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _zero_stats():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ratio_ref[...] = jnp.zeros_like(ratio_ref)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes, cnt, ratio = _quantize_sat(x_ref[...], scale_ref[0, 0],
                                      bits=bits, zero_point=zero_point)

    @pl.when(j == 0)
    def _count():
        cnt_ref[0, 0] += cnt

    ratio_ref[0, 0] = jnp.maximum(ratio_ref[0, 0], ratio)
    off = _pack_flat(codes, bits=bits, group=2 * group, Gseg=Gb)  # [Bb, Gb]
    out_ref[...] += _take_rows(off, tab_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "counters",
                     "interpret"),
)
def pcilt_fused_gemv_paired_pallas(
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    counters: bool = False,
    interpret: bool = False,
):
    """x ``[B, n]`` float, scale ``[1, 1]``, paired tables ``[G2, V2, O]``
    (``V2 = (2**(bits*group))**2``) -> ``[B, O]``.

    The TL1-style multi-scalar variant of :func:`pcilt_fused_gemv_pallas`:
    each staged table row covers *two* adjacent ``group``-wide segments, so
    ``n == G2 * 2 * group`` (the caller zero-pads ``x`` over the phantom
    segment when the unpaired ``G`` was odd — its table column is exactly
    zero).  Half the fetches, half the adder-tree depth; the fetch itself is
    a batched row-gather (see :func:`_take_rows`), not a one-hot
    contraction.  ``tiles`` is ``(Bb, Gb, Ob)`` with ``Gb | G2``.

    ``counters=True`` (static opt-in) returns ``(out, count, ratio)``
    saturation stats — see :func:`pcilt_fused_gemv_stacked_pallas`.  The
    phantom-segment zero pad quantizes in range, so the count covers exactly
    the real activations.
    """
    B, n = x.shape
    G2, V2, O = tables.shape
    if n != G2 * 2 * group:
        raise ValueError(
            f"x trailing dim {n} != G2*2*group = {G2}*2*{group} "
            f"(x {x.shape}, paired tables {tables.shape})")
    if V2 != 1 << (2 * bits * group):
        raise ValueError(
            f"paired tables value axis {V2} != (2**(bits*group))**2 = "
            f"{1 << (2 * bits * group)} (tables {tables.shape}, bits={bits}, "
            f"group={group})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G2 // Gb)
    in_specs = [
        pl.BlockSpec((Bb, Gb * 2 * group), lambda i, j, k: (i, k)),
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        pl.BlockSpec((Gb, V2, Ob), lambda i, j, k: (k, 0, j)),
    ]
    out_spec = pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j))
    if counters:
        out, cnt, ratio = pl.pallas_call(
            functools.partial(_gemv_paired_sat_kernel, bits=bits,
                              zero_point=zero_point, group=group, Gb=Gb),
            grid=grid,
            in_specs=in_specs,
            out_specs=(
                out_spec,
                pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            ),
            out_shape=(
                jax.ShapeDtypeStruct((B, O), jnp.float32),
                jax.ShapeDtypeStruct((1, 1), jnp.int32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
            ),
            interpret=interpret,
        )(x, scale, tables)
        return out.astype(tables.dtype), cnt[0, 0], ratio[0, 0]
    return pl.pallas_call(
        functools.partial(_gemv_paired_kernel, bits=bits,
                          zero_point=zero_point, group=group, Gb=Gb),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Layer-stacked fused GEMV (LM decode: one kernel per projection per layer,
# tables for every layer resident in one [L, G, V, O] array)
# ----------------------------------------------------------------------------


def _gemv_stacked_kernel(layer_ref, x_ref, scale_ref, tab_ref, out_ref, *,
                         bits: int, zero_point: int, group: int,
                         Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*group]
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    # tab_ref's block is the current layer's [1, Gb, V, Ob] slice — the
    # scalar-prefetched layer index already selected it in the index map,
    # so the kernel body is the plain fused fetch.
    out_ref[...] += _flat_onehot_dot(off, tab_ref[0], V=V)


def _gemv_stacked_sat_kernel(layer_ref, x_ref, scale_ref, tab_ref,
                             out_ref, cnt_ref, ratio_ref, *,
                             bits: int, zero_point: int, group: int,
                             Gb: int, V: int):
    """The counter-carrying variant of :func:`_gemv_stacked_kernel`.

    Two extra ``[1, 1]`` outputs ride the call, block-resident across the
    whole grid (constant index maps): the int32 saturation count and the f32
    running ``max(|x|)/scale`` ratio.  The x block at ``(i, k)`` is revisited
    once per output tile ``j``, so the count accumulates only on ``j == 0``
    — every activation element counted exactly once; ``max`` is idempotent,
    so the ratio accumulates on every step.  Zero-padded rows (the batch
    pad) quantize to the in-range zero_point and contribute nothing.
    """
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _zero_stats():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ratio_ref[...] = jnp.zeros_like(ratio_ref)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes, cnt, ratio = _quantize_sat(x_ref[...], scale_ref[0, 0],
                                      bits=bits, zero_point=zero_point)

    @pl.when(j == 0)
    def _count():
        cnt_ref[0, 0] += cnt

    ratio_ref[0, 0] = jnp.maximum(ratio_ref[0, 0], ratio)
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    out_ref[...] += _flat_onehot_dot(off, tab_ref[0], V=V)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "counters",
                     "interpret"),
)
def pcilt_fused_gemv_stacked_pallas(
    layer: jax.Array,
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    counters: bool = False,
    interpret: bool = False,
):
    """layer ``[1]`` int32, x ``[B, n]`` float, scale ``[1, 1]``,
    tables ``[L, G, V, O]`` -> ``[B, O]``.

    The layer-scanned decode variant of :func:`pcilt_fused_gemv_pallas`:
    the per-layer tables of a whole network stack live in one ``[L, G, V, O]``
    array that never moves, and the (traced) ``layer`` operand is
    **scalar-prefetched** so the BlockSpec index map stages exactly that
    layer's ``[1, Gb, V, Ob]`` tiles — per grid step the staged bytes equal
    the unstacked kernel's, and the ``lax.scan`` over layers never pays the
    HBM copy a per-iteration ``dynamic_slice`` of the stacked tables would
    materialize.  ``n == G * group``; ``tiles`` is ``(Bb, Gb, Ob)`` with
    ``Gb | G``.

    With ``counters=True`` (a static opt-in: the default trace is
    byte-identical to before the counters existed) the call returns
    ``(out, count, ratio)`` — the int32 number of activations the quantizer
    clipped and the f32 ``max(|x|)/scale`` overshoot, reduced in VMEM by
    :func:`_gemv_stacked_sat_kernel`.
    """
    B, n = x.shape
    L, G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} "
            f"(x {x.shape}, stacked tables {tables.shape})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    in_specs = [
        pl.BlockSpec((Bb, Gb * group), lambda i, j, k, l: (i, k)),
        pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
        pl.BlockSpec((1, Gb, V, Ob), lambda i, j, k, l: (l[0], k, 0, j)),
    ]
    out_spec = pl.BlockSpec((Bb, Ob), lambda i, j, k, l: (i, j))
    if counters:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=(
                out_spec,
                pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
            ),
        )
        out, cnt, ratio = pl.pallas_call(
            functools.partial(_gemv_stacked_sat_kernel, bits=bits,
                              zero_point=zero_point, group=group, Gb=Gb, V=V),
            grid_spec=grid_spec,
            out_shape=(
                jax.ShapeDtypeStruct((B, O), jnp.float32),
                jax.ShapeDtypeStruct((1, 1), jnp.int32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
            ),
            interpret=interpret,
        )(layer, x, scale, tables)
        return out.astype(tables.dtype), cnt[0, 0], ratio[0, 0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_gemv_stacked_kernel, bits=bits,
                          zero_point=zero_point, group=group, Gb=Gb, V=V),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(layer, x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Layer-stacked paired GEMV (the paired decode path): segment-major tables,
# layer folded into the fetch's value axis.
# ----------------------------------------------------------------------------


def _gemv_paired_stacked_kernel(layer_ref, x_ref, scale_ref, tab_ref,
                                out_ref, *, bits: int, zero_point: int,
                                group: int, Gb: int, V2: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*2*group]
    off = _pack_flat(codes, bits=bits, group=2 * group, Gseg=Gb)  # [Bb, Gb]
    # The staged block is [Gb, L, V2, Ob] with a *constant* layer index in
    # the BlockSpec map; folding L into the value axis keeps the segment
    # index of the gather a constant iota (the batched-row-gather fast path)
    # and moves the traced layer into the gathered *row* — the layout that
    # makes the traced layer free instead of forcing a general gather.
    Gb_, L, _, Ob = tab_ref.shape
    tab = tab_ref[...].reshape(Gb_, L * V2, Ob)
    out_ref[...] += _take_rows(off + layer_ref[0] * V2, tab)


def _gemv_paired_stacked_sat_kernel(layer_ref, x_ref, scale_ref, tab_ref,
                                    out_ref, cnt_ref, ratio_ref, *,
                                    bits: int, zero_point: int,
                                    group: int, Gb: int, V2: int):
    """Counter-carrying :func:`_gemv_paired_stacked_kernel` (see
    :func:`_gemv_stacked_sat_kernel` for the dedup/zeroing discipline)."""
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _zero_stats():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ratio_ref[...] = jnp.zeros_like(ratio_ref)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes, cnt, ratio = _quantize_sat(x_ref[...], scale_ref[0, 0],
                                      bits=bits, zero_point=zero_point)

    @pl.when(j == 0)
    def _count():
        cnt_ref[0, 0] += cnt

    ratio_ref[0, 0] = jnp.maximum(ratio_ref[0, 0], ratio)
    off = _pack_flat(codes, bits=bits, group=2 * group, Gseg=Gb)  # [Bb, Gb]
    Gb_, L, _, Ob = tab_ref.shape
    tab = tab_ref[...].reshape(Gb_, L * V2, Ob)
    out_ref[...] += _take_rows(off + layer_ref[0] * V2, tab)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "counters",
                     "interpret"),
)
def pcilt_fused_gemv_paired_stacked_pallas(
    layer: jax.Array,
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    counters: bool = False,
    interpret: bool = False,
):
    """layer ``[1]`` int32, x ``[B, n]`` float, scale ``[1, 1]``,
    **segment-major** paired tables ``[G2, L, V2, O]`` -> ``[B, O]``.

    The layer-scanned decode variant of
    :func:`pcilt_fused_gemv_paired_pallas`.  The stack is segment-major
    (``build_paired_stacked_tables``) so each grid step stages a
    ``[Gb, L, V2, Ob]`` block whose index map is constant in the
    scalar-prefetched layer; the kernel reshapes it to ``[Gb, L*V2, Ob]``
    (adjacent contiguous axes — free) and fetches row ``l*V2 + off``.  The
    traced layer index thus rides the gather's *value* coordinate while the
    segment coordinate stays a constant iota — XLA's batched row-gather fast
    path, where a traced segment index would fall off onto the slow general
    gather.  ``n == G2 * 2 * group``; ``tiles`` is ``(Bb, Gb, Ob)`` with
    ``Gb | G2``.

    ``counters=True`` (static opt-in) returns ``(out, count, ratio)``
    saturation stats — see :func:`pcilt_fused_gemv_stacked_pallas`.
    """
    B, n = x.shape
    G2, L, V2, O = tables.shape
    if n != G2 * 2 * group:
        raise ValueError(
            f"x trailing dim {n} != G2*2*group = {G2}*2*{group} "
            f"(x {x.shape}, stacked paired tables {tables.shape})")
    if V2 != 1 << (2 * bits * group):
        raise ValueError(
            f"paired tables value axis {V2} != (2**(bits*group))**2 = "
            f"{1 << (2 * bits * group)} (tables {tables.shape}, bits={bits}, "
            f"group={group})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G2 // Gb)
    in_specs = [
        pl.BlockSpec((Bb, Gb * 2 * group), lambda i, j, k, l: (i, k)),
        pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
        pl.BlockSpec((Gb, L, V2, Ob), lambda i, j, k, l: (k, 0, 0, j)),
    ]
    out_spec = pl.BlockSpec((Bb, Ob), lambda i, j, k, l: (i, j))
    if counters:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=(
                out_spec,
                pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
            ),
        )
        out, cnt, ratio = pl.pallas_call(
            functools.partial(_gemv_paired_stacked_sat_kernel, bits=bits,
                              zero_point=zero_point, group=group, Gb=Gb,
                              V2=V2),
            grid_spec=grid_spec,
            out_shape=(
                jax.ShapeDtypeStruct((B, O), jnp.float32),
                jax.ShapeDtypeStruct((1, 1), jnp.int32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
            ),
            interpret=interpret,
        )(layer, x, scale, tables)
        return out.astype(tables.dtype), cnt[0, 0], ratio[0, 0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_gemv_paired_stacked_kernel, bits=bits,
                          zero_point=zero_point, group=group, Gb=Gb, V2=V2),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(layer, x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Plan-gather fused GEMV: generalized (non-contiguous) SegmentPlans run
# fused via an in-VMEM gather of the plan index.
# ----------------------------------------------------------------------------


def _gemv_plan_kernel(x_ref, scale_ref, plan_ref, tab_ref, out_ref, *,
                      bits: int, zero_point: int, group: int,
                      Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    pidx = plan_ref[...].reshape(Gb * group)  # int32, -1 = unused slot
    # In-VMEM gather of the plan's source positions; unused (-1) slots clamp
    # to 0 and are zeroed — their table rows were built from zero weights
    # (SegmentPlan.gather_weights), so any code fetches exactly 0, but
    # forcing x=0 keeps the packed offset deterministic.
    xg = jnp.take(x_ref[...], jnp.maximum(pidx, 0), axis=1)  # [Bb, Gb*group]
    xg = jnp.where((pidx < 0)[None, :], jnp.zeros_like(xg), xg)
    codes = _quantize(xg, scale_ref[0, 0], bits=bits, zero_point=zero_point)
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    out_ref[...] += _flat_onehot_dot(off, tab_ref[...], V=V)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "interpret"),
)
def pcilt_fused_gemv_plan_pallas(
    x: jax.Array,
    scale: jax.Array,
    plan_idx: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, n]`` float, scale ``[1, 1]``, plan_idx ``[G, group]`` int32
    (``-1`` = unused slot), tables ``[G, V, O]`` -> ``[B, O]``.

    The generalized-:class:`~repro.core.offsets.SegmentPlan` variant of
    :func:`pcilt_fused_gemv_pallas`: segments may skip or reuse arbitrary
    source positions, so the *whole* activation row is staged (the x
    BlockSpec is constant in the segment grid axis) and each grid step
    gathers its ``[Gb, group]`` plan block's positions in VMEM before the
    standard quantize→pack→fetch.  ``tiles`` is ``(Bb, Gb, Ob)`` with
    ``Gb | G``; ``B`` and ``O`` are padded by ``ops.py`` as usual.
    """
    B, n = x.shape
    G, V, O = tables.shape
    if plan_idx.shape != (G, group):
        raise ValueError(
            f"plan_idx shape {plan_idx.shape} != (G, group) = "
            f"({G}, {group}) (tables {tables.shape})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_gemv_plan_kernel, bits=bits,
                          zero_point=zero_point, group=group, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, n), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((Gb, group), lambda i, j, k: (k, 0)),
            pl.BlockSpec((Gb, V, Ob), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(x, scale, plan_idx, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Fused conv2d
# ----------------------------------------------------------------------------


def _strip_offsets(x_ref, scale_ref, seg_ref, *, bits: int, zero_point: int,
                   group: int, kh: int, kw: int, stride: int,
                   Gb: int, Hb: int, n_pad: int):
    """Quantize this grid step's row strip, im2col it in VMEM, slice the
    current group range, and pack offsets -> ``[Hb*Wo, Gb]``.

    ``seg_ref`` holds the segment offset of this device's table shard in the
    *global* segment space (``[1, 1]`` int32, 0 when unsharded): under
    ``shard_map`` every device stages the full (replicated) activation image,
    rebuilds the full patch in VMEM, and slices out the column range its local
    ``[G/D, V, O]`` table shard covers — the in-VMEM im2col never leaves the
    device even when the tables are tensor-parallel.

    Shared between the dense-fused conv kernel and the shared-pool conv
    kernel (``pcilt_shared.py``) — the activation side of the pipeline is
    identical; only the table operand differs.
    """
    _, Hp, Wp, C = x_ref.shape
    Wo = (Wp - kw) // stride + 1
    strip_h = (Hb - 1) * stride + kh
    row0 = pl.program_id(1) * (Hb * stride)
    strip = x_ref[0, pl.ds(row0, strip_h), :, :]  # [strip_h, Wp, C] from VMEM
    codes = _quantize(strip, scale_ref[0, 0], bits=bits, zero_point=zero_point)

    # In-VMEM im2col over the strip: static kh*kw slice loop (matches the
    # [kh, kw, C] patch flattening of core.lut_layers.im2col).  The full
    # patch is rebuilt per (output, group) grid step and sliced — VPU work
    # that is redundant when Gb < G or Ob < O, but small next to the MXU
    # contraction; building only the k-th segment's columns is a follow-on.
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(codes[i:i + (Hb - 1) * stride + 1:stride,
                              j:j + (Wo - 1) * stride + 1:stride, :])
    patch = jnp.concatenate(cols, axis=-1).reshape(Hb * Wo, kh * kw * C)
    if n_pad:
        # Group-alignment slots: the table rows for these slots were built
        # from zero weights, so any code value contributes exactly zero.
        patch = jnp.pad(patch, ((0, 0), (0, n_pad)))

    # This grid step's group range in global segment space:
    # [seg0 + k*Gb, seg0 + (k+1)*Gb) — seg0 is the shard's segment offset.
    col0 = (seg_ref[0, 0] + pl.program_id(3) * Gb) * group
    seg = jax.lax.dynamic_slice(patch, (0, col0), (Hb * Wo, Gb * group))
    return _pack_flat(seg, bits=bits, group=group, Gseg=Gb)  # [Hb*Wo, Gb]


def _conv_kernel(x_ref, scale_ref, seg_ref, tab_ref, out_ref, *,
                 bits: int, zero_point: int, group: int,
                 kh: int, kw: int, stride: int,
                 Gb: int, V: int, Hb: int, n_pad: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    off = _strip_offsets(x_ref, scale_ref, seg_ref,
                         bits=bits, zero_point=zero_point,
                         group=group, kh=kh, kw=kw, stride=stride,
                         Gb=Gb, Hb=Hb, n_pad=n_pad)
    acc = _flat_onehot_dot(off, tab_ref[...], V=V)  # [Hb*Wo, Ob] f32
    out_ref[...] += acc.reshape(out_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "kh", "kw", "stride",
                     "n_total", "tiles", "interpret"),
)
def pcilt_fused_conv2d_pallas(
    x: jax.Array,
    scale: jax.Array,
    seg_offset: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    kh: int,
    kw: int,
    stride: int = 1,
    n_total: int = 0,
    tiles=None,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, Hp, Wp, C]`` float (already spatially padded for the conv),
    scale ``[1, 1]``, seg_offset ``[1, 1]`` int32, tables ``[G, V, O]``
    -> ``[B, Ho, Wo, O]``.

    The whole (small) image is staged in VMEM once per batch element and
    revisited across row/output/group tiles; each grid step quantizes a row
    strip, extracts patches, packs offsets, and fetches — the int32 offsets
    never exist outside VMEM.  ``tiles`` is ``(Hb, Gb, Ob)`` with ``Gb | G``
    and ``Hb | Ho``.

    ``n_total`` is the *global* padded reduction length (``>= kh*kw*C``;
    defaults to ``G * group``, the unsharded case).  Under ``shard_map`` the
    tables operand is one device's ``[G/D, V, O]`` shard and ``seg_offset``
    carries the shard's first segment in global segment space, so the
    in-VMEM im2col slices exactly the patch columns the local shard covers
    (``n_total`` stays the global length; ``G * group`` is only the local
    slice width).
    """
    B, Hp, Wp, C = x.shape
    G, V, O = tables.shape
    n = kh * kw * C
    n_tot = n_total or G * group
    if n_tot < max(n, G * group):
        raise ValueError(
            f"n_total {n_tot} must cover the patch length kh*kw*C = {n} "
            f"and the table span G*group = {G}*{group} "
            f"(x {x.shape}, tables {tables.shape})")
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    Hb, Gb, Ob = tiles
    grid = (B, Ho // Hb, pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_conv_kernel, bits=bits, zero_point=zero_point,
                          group=group, kh=kh, kw=kw, stride=stride,
                          Gb=Gb, V=V, Hb=Hb, n_pad=n_tot - n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, r, j, k: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((Gb, V, Ob), lambda b, r, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, Hb, Wo, Ob), lambda b, r, j, k: (b, r, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, O), jnp.float32),
        interpret=interpret,
    )(x, scale, seg_offset, tables).astype(tables.dtype)
