"""Fused Pallas TPU kernels: quantize -> offset-pack -> table-fetch in VMEM.

The host-packed pipeline (``pcilt_gemv.py`` / ``pcilt_conv2d.py``) quantizes,
im2col-packs, and bit-packs offsets *on the host*, materializing a
``[..., G]`` int32 offset tensor in HBM that the kernel then re-reads — for a
conv that tensor is ``[B, Ho, Wo, kh*kw*Cin/group]`` and routinely larger than
the activations themselves.  The kernels here fuse the whole paper pipeline
(Fig. 6: quantize, shift/mask pack, fetch, adder tree) into one ``pallas_call``
over the *raw float activations*, so the offsets live only in VMEM/registers:

* **quantize** — ``clip(round(x / scale) + zero_point, 0, K-1)``, bit-exact
  with ``core.quantization.quantize`` (same round-half-even, same clip);
* **pack** — little-endian shift-or of ``group`` codes per segment, bit-exact
  with ``core.offsets.pack_offsets``;
* **fetch + adder tree** — one *flattened* one-hot contraction per staged
  table tile: instead of a ``fori_loop`` of ``Gb`` small ``[Bb,V] x [V,Ob]``
  dots, the one-hot is laid out as ``[Bb, Gb*V]`` (segment-major) and the
  staged tables reshaped to ``[Gb*V, Ob]``, so the MXU runs a single large
  contraction per grid step.  The adder tree over group tiles is grid
  accumulation on the revisited output block.

Tables may be stored **bf16** (pass ``tables.astype(jnp.bfloat16)``): the
one-hot is built in the table dtype, the contraction *and* the cross-tile
accumulation run in f32 (f32 ``preferred_element_type`` into an f32 output
block, cast to the table dtype once at the end), and the staged-tile VMEM
cost halves — doubling the groups per stage under the same ~8 MB budget
(``autotune._fit_gb`` is itemsize-aware).

Tiling is supplied by the caller (``ops.py``), which consults the persistent
autotune lookup table (``autotune.py``) — cache hit ⇒ zero-cost dispatch,
miss ⇒ the VMEM-budget heuristic, optionally tune-once-and-record.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pcilt_fused_gemv_pallas", "pcilt_fused_gemv_stacked_pallas",
           "pcilt_fused_conv2d_pallas"]


def _quantize(x, scale, *, bits: int, zero_point: int):
    """In-kernel mirror of ``core.quantization.quantize`` (-> int32 codes)."""
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)


def _pack_flat(codes, *, bits: int, group: int, Gseg: int):
    """``[R, Gseg*group]`` codes -> ``[R, Gseg]`` little-endian offsets."""
    R = codes.shape[0]
    c = codes.reshape(R, Gseg, group)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, group), 2) * bits
    return jnp.sum(jnp.left_shift(c, shifts), axis=-1)  # [R, Gseg]


def _flat_onehot_dot(off, tab, *, V: int):
    """The flattened fetch: ``off [R, Gb]``, ``tab [Gb, V, Ob]`` -> f32 ``[R, Ob]``.

    ``onehot[r, g*V + v] = (off[r, g] == v)`` — one ``[R, Gb*V] x [Gb*V, Ob]``
    MXU contraction replaces the per-group loop of small dots.
    """
    R, Gb = off.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (R, Gb, V), 2)
    oh = (off[:, :, None] == lanes).astype(tab.dtype).reshape(R, Gb * V)
    return jnp.dot(oh, tab.reshape(Gb * V, tab.shape[-1]),
                   preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------------
# Fused GEMV
# ----------------------------------------------------------------------------


def _gemv_kernel(x_ref, scale_ref, tab_ref, out_ref, *,
                 bits: int, zero_point: int, group: int, Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*group]
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    # The output block is f32 regardless of table dtype, so the adder tree
    # over G tiles never rounds through bf16 (caller casts once at the end).
    out_ref[...] += _flat_onehot_dot(off, tab_ref[...], V=V)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "interpret"),
)
def pcilt_fused_gemv_pallas(
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, n]`` float, scale ``[1, 1]``, tables ``[G, V, O]`` -> ``[B, O]``.

    ``n == G * group``; B, O are padded to tile multiples by ``ops.py``;
    ``tiles`` is a ``(Bb, Gb, Ob)`` tuple with ``Gb | G``.
    """
    B, n = x.shape
    G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} "
            f"(x {x.shape}, tables {tables.shape})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_gemv_kernel, bits=bits, zero_point=zero_point,
                          group=group, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, Gb * group), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((Gb, V, Ob), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Layer-stacked fused GEMV (LM decode: one kernel per projection per layer,
# tables for every layer resident in one [L, G, V, O] array)
# ----------------------------------------------------------------------------


def _gemv_stacked_kernel(layer_ref, x_ref, scale_ref, tab_ref, out_ref, *,
                         bits: int, zero_point: int, group: int,
                         Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = _quantize(x_ref[...], scale_ref[0, 0],
                      bits=bits, zero_point=zero_point)  # [Bb, Gb*group]
    off = _pack_flat(codes, bits=bits, group=group, Gseg=Gb)  # [Bb, Gb]
    # tab_ref's block is the current layer's [1, Gb, V, Ob] slice — the
    # scalar-prefetched layer index already selected it in the index map,
    # so the kernel body is the plain fused fetch.
    out_ref[...] += _flat_onehot_dot(off, tab_ref[0], V=V)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "tiles", "interpret"),
)
def pcilt_fused_gemv_stacked_pallas(
    layer: jax.Array,
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    tiles,
    interpret: bool = False,
) -> jax.Array:
    """layer ``[1]`` int32, x ``[B, n]`` float, scale ``[1, 1]``,
    tables ``[L, G, V, O]`` -> ``[B, O]``.

    The layer-scanned decode variant of :func:`pcilt_fused_gemv_pallas`:
    the per-layer tables of a whole network stack live in one ``[L, G, V, O]``
    array that never moves, and the (traced) ``layer`` operand is
    **scalar-prefetched** so the BlockSpec index map stages exactly that
    layer's ``[1, Gb, V, Ob]`` tiles — per grid step the staged bytes equal
    the unstacked kernel's, and the ``lax.scan`` over layers never pays the
    HBM copy a per-iteration ``dynamic_slice`` of the stacked tables would
    materialize.  ``n == G * group``; ``tiles`` is ``(Bb, Gb, Ob)`` with
    ``Gb | G``.
    """
    B, n = x.shape
    L, G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} "
            f"(x {x.shape}, stacked tables {tables.shape})")
    Bb, Gb, Ob = tiles
    grid = (pl.cdiv(B, Bb), pl.cdiv(O, Ob), G // Gb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bb, Gb * group), lambda i, j, k, l: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k, l: (0, 0)),
            pl.BlockSpec((1, Gb, V, Ob), lambda i, j, k, l: (l[0], k, 0, j)),
        ],
        out_specs=pl.BlockSpec((Bb, Ob), lambda i, j, k, l: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_gemv_stacked_kernel, bits=bits,
                          zero_point=zero_point, group=group, Gb=Gb, V=V),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=interpret,
    )(layer, x, scale, tables).astype(tables.dtype)


# ----------------------------------------------------------------------------
# Fused conv2d
# ----------------------------------------------------------------------------


def _strip_offsets(x_ref, scale_ref, seg_ref, *, bits: int, zero_point: int,
                   group: int, kh: int, kw: int, stride: int,
                   Gb: int, Hb: int, n_pad: int):
    """Quantize this grid step's row strip, im2col it in VMEM, slice the
    current group range, and pack offsets -> ``[Hb*Wo, Gb]``.

    ``seg_ref`` holds the segment offset of this device's table shard in the
    *global* segment space (``[1, 1]`` int32, 0 when unsharded): under
    ``shard_map`` every device stages the full (replicated) activation image,
    rebuilds the full patch in VMEM, and slices out the column range its local
    ``[G/D, V, O]`` table shard covers — the in-VMEM im2col never leaves the
    device even when the tables are tensor-parallel.

    Shared between the dense-fused conv kernel and the shared-pool conv
    kernel (``pcilt_shared.py``) — the activation side of the pipeline is
    identical; only the table operand differs.
    """
    _, Hp, Wp, C = x_ref.shape
    Wo = (Wp - kw) // stride + 1
    strip_h = (Hb - 1) * stride + kh
    row0 = pl.program_id(1) * (Hb * stride)
    strip = x_ref[0, pl.ds(row0, strip_h), :, :]  # [strip_h, Wp, C] from VMEM
    codes = _quantize(strip, scale_ref[0, 0], bits=bits, zero_point=zero_point)

    # In-VMEM im2col over the strip: static kh*kw slice loop (matches the
    # [kh, kw, C] patch flattening of core.lut_layers.im2col).  The full
    # patch is rebuilt per (output, group) grid step and sliced — VPU work
    # that is redundant when Gb < G or Ob < O, but small next to the MXU
    # contraction; building only the k-th segment's columns is a follow-on.
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(codes[i:i + (Hb - 1) * stride + 1:stride,
                              j:j + (Wo - 1) * stride + 1:stride, :])
    patch = jnp.concatenate(cols, axis=-1).reshape(Hb * Wo, kh * kw * C)
    if n_pad:
        # Group-alignment slots: the table rows for these slots were built
        # from zero weights, so any code value contributes exactly zero.
        patch = jnp.pad(patch, ((0, 0), (0, n_pad)))

    # This grid step's group range in global segment space:
    # [seg0 + k*Gb, seg0 + (k+1)*Gb) — seg0 is the shard's segment offset.
    col0 = (seg_ref[0, 0] + pl.program_id(3) * Gb) * group
    seg = jax.lax.dynamic_slice(patch, (0, col0), (Hb * Wo, Gb * group))
    return _pack_flat(seg, bits=bits, group=group, Gseg=Gb)  # [Hb*Wo, Gb]


def _conv_kernel(x_ref, scale_ref, seg_ref, tab_ref, out_ref, *,
                 bits: int, zero_point: int, group: int,
                 kh: int, kw: int, stride: int,
                 Gb: int, V: int, Hb: int, n_pad: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    off = _strip_offsets(x_ref, scale_ref, seg_ref,
                         bits=bits, zero_point=zero_point,
                         group=group, kh=kh, kw=kw, stride=stride,
                         Gb=Gb, Hb=Hb, n_pad=n_pad)
    acc = _flat_onehot_dot(off, tab_ref[...], V=V)  # [Hb*Wo, Ob] f32
    out_ref[...] += acc.reshape(out_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "zero_point", "group", "kh", "kw", "stride",
                     "n_total", "tiles", "interpret"),
)
def pcilt_fused_conv2d_pallas(
    x: jax.Array,
    scale: jax.Array,
    seg_offset: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    group: int,
    kh: int,
    kw: int,
    stride: int = 1,
    n_total: int = 0,
    tiles=None,
    interpret: bool = False,
) -> jax.Array:
    """x ``[B, Hp, Wp, C]`` float (already spatially padded for the conv),
    scale ``[1, 1]``, seg_offset ``[1, 1]`` int32, tables ``[G, V, O]``
    -> ``[B, Ho, Wo, O]``.

    The whole (small) image is staged in VMEM once per batch element and
    revisited across row/output/group tiles; each grid step quantizes a row
    strip, extracts patches, packs offsets, and fetches — the int32 offsets
    never exist outside VMEM.  ``tiles`` is ``(Hb, Gb, Ob)`` with ``Gb | G``
    and ``Hb | Ho``.

    ``n_total`` is the *global* padded reduction length (``>= kh*kw*C``;
    defaults to ``G * group``, the unsharded case).  Under ``shard_map`` the
    tables operand is one device's ``[G/D, V, O]`` shard and ``seg_offset``
    carries the shard's first segment in global segment space, so the
    in-VMEM im2col slices exactly the patch columns the local shard covers
    (``n_total`` stays the global length; ``G * group`` is only the local
    slice width).
    """
    B, Hp, Wp, C = x.shape
    G, V, O = tables.shape
    n = kh * kw * C
    n_tot = n_total or G * group
    if n_tot < max(n, G * group):
        raise ValueError(
            f"n_total {n_tot} must cover the patch length kh*kw*C = {n} "
            f"and the table span G*group = {G}*{group} "
            f"(x {x.shape}, tables {tables.shape})")
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    Hb, Gb, Ob = tiles
    grid = (B, Ho // Hb, pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_conv_kernel, bits=bits, zero_point=zero_point,
                          group=group, kh=kh, kw=kw, stride=stride,
                          Gb=Gb, V=V, Hb=Hb, n_pad=n_tot - n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, r, j, k: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, r, j, k: (0, 0)),
            pl.BlockSpec((Gb, V, Ob), lambda b, r, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, Hb, Wo, Ob), lambda b, r, j, k: (b, r, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, O), jnp.float32),
        interpret=interpret,
    )(x, scale, seg_offset, tables).astype(tables.dtype)
