"""Pallas TPU kernel: PCILT conv2d over pre-packed patch offsets.

The host side (``ops.py``) quantizes and im2col-packs the image into offsets
``[B, Ho, Wo, G]`` (the paper's pre-processing circuitry, §Extensions); this
kernel performs the fetch-and-add over spatial tiles:

    out[b, y, x, o] = sum_g tables[g, offsets[b, y, x, g], o]

(The *fused* sibling in ``pcilt_fused.py`` skips the host pre-processing
entirely — raw floats in, offsets only ever in VMEM — and is the faster
deployment path; this kernel remains for callers that hold pre-packed
offsets, e.g. generalized ``SegmentPlan`` packings.)

Blocking: the grid walks (batch, row-tile, output-tile, table-stage); each
step stages a ``[Gb, V, Ob]`` table slice in VMEM and processes a ``[Hb, Wo]``
strip of the image, so the same staged tables are reused across the whole
strip — the conv-specific win the paper leans on (small filter, large data ⇒
the table is read once and hit many times).  Tiling ``(Hb, Gb, Ob)`` comes
from the caller, which consults the persistent autotune lookup table
(``autotune.py``); ``None`` falls back to the stage-everything heuristic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pcilt_conv2d_pallas"]


def _kernel(off_ref, tab_ref, out_ref, *, Gb: int, V: int):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    _, Hb, W, _ = off_ref.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (Hb * W, V), 1)

    def body(g, acc):
        oh = (off_ref[0, :, :, g].reshape(Hb * W)[:, None] == lanes).astype(
            tab_ref.dtype
        )
        return acc + jnp.dot(oh, tab_ref[g], preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, Gb, body, jnp.zeros((Hb * W, out_ref.shape[-1]), jnp.float32)
    )
    out_ref[...] += acc.reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret", "tiles"))
def pcilt_conv2d_pallas(
    offsets: jax.Array,
    tables: jax.Array,
    row_tile: int = 8,
    interpret: bool = False,
    tiles=None,
) -> jax.Array:
    """offsets ``[B, Ho, Wo, G]`` int32, tables ``[G, V, O]`` -> ``[B, Ho, Wo, O]``.

    Wo and O are padded to tile multiples by the caller (``ops.py``).
    ``tiles`` is ``(Hb, Gb, Ob)``; ``None`` picks ``Hb = row_tile``, stages all
    G tables when they fit ~8 MB, and keeps O unsplit.
    """
    B, H, W, G = offsets.shape
    G2, V, O = tables.shape
    if G != G2:
        raise ValueError(
            f"offsets segment dim {G} != tables segment dim {G2} "
            f"(offsets {offsets.shape}, tables {tables.shape})")
    if tiles is None:
        Hb = min(row_tile, H)
        Gb = G if G * V * O * tables.dtype.itemsize <= 8 * 2**20 else 1
        Ob = O
    else:
        Hb, Gb, Ob = tiles
        Hb, Ob = min(Hb, H), min(Ob, O)
    while H % Hb:
        Hb -= 1
    while G % Gb:
        Gb -= 1
    grid = (B, H // Hb, pl.cdiv(O, Ob), G // Gb)
    return pl.pallas_call(
        functools.partial(_kernel, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hb, W, Gb), lambda b, i, j, k: (b, i, 0, k)),
            pl.BlockSpec((Gb, V, Ob), lambda b, i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, Hb, W, Ob), lambda b, i, j, k: (b, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, O), tables.dtype),
        interpret=interpret,
    )(offsets, tables)
