"""Pallas TPU kernel: PCILT conv2d over pre-packed patch offsets.

The host side (``ops.py``) quantizes and im2col-packs the image into offsets
``[B, Ho, Wo, G]`` (the paper's pre-processing circuitry, §Extensions); this
kernel performs the fetch-and-add over spatial tiles:

    out[b, y, x, o] = sum_g tables[g, offsets[b, y, x, g], o]

Blocking: the grid walks (batch, row-tile, table-stage); each step stages a
``[Gb, V, Ob]`` table slice in VMEM and processes a ``[Hb, Wo]`` strip of the
image, so the same staged tables are reused across the whole strip — the
conv-specific win the paper leans on (small filter, large data ⇒ the table is
read once and hit many times).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pcilt_conv2d_pallas"]


def _kernel(off_ref, tab_ref, out_ref, *, Gb: int, V: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    _, Hb, W, _ = off_ref.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (Hb * W, V), 1)

    def body(g, acc):
        oh = (off_ref[0, :, :, g].reshape(Hb * W)[:, None] == lanes).astype(
            tab_ref.dtype
        )
        return acc + jnp.dot(oh, tab_ref[g], preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, Gb, body, jnp.zeros((Hb * W, out_ref.shape[-1]), jnp.float32)
    )
    out_ref[...] += acc.reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def pcilt_conv2d_pallas(
    offsets: jax.Array,
    tables: jax.Array,
    row_tile: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """offsets ``[B, Ho, Wo, G]`` int32, tables ``[G, V, O]`` -> ``[B, Ho, Wo, O]``."""
    B, H, W, G = offsets.shape
    G2, V, O = tables.shape
    assert G == G2
    Hb = min(row_tile, H)
    while H % Hb:
        Hb -= 1
    # Stage all G tables when they fit (~8MB), else one group at a time.
    Gb = G if G * V * O * 4 <= 8 * 2**20 else 1
    while G % Gb:
        Gb -= 1
    grid = (B, H // Hb, G // Gb)
    return pl.pallas_call(
        functools.partial(_kernel, Gb=Gb, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hb, W, Gb), lambda b, i, k: (b, i, 0, k)),
            pl.BlockSpec((Gb, V, O), lambda b, i, k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hb, W, O), lambda b, i, k: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, O), tables.dtype),
        interpret=interpret,
    )(offsets, tables)
