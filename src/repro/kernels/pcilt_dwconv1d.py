"""Pallas TPU kernel: PCILT depthwise conv1d — one fetch per output element.

For a k-tap causal depthwise conv with activation cardinality K, the k input
codes of a channel pack into one offset and the whole tap-dot is a single
table cell:  ``out[b, t, c] = tables[c, offsets[b, t, c]]``.

This is the purest PCILT case on TPU (Mamba2 / Zamba2 conv frontends, k=4):
there is no reduction left — the kernel is a blocked masked-sum "gather"
executed on the VPU, with the per-channel tables staged in VMEM and reused
across the entire time axis (small filter × long signal, the paper's sweet
spot).  Channels ride the 128-lane axis; time rides sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pcilt_dwconv1d_pallas"]


def _kernel(off_ref, tab_ref, out_ref, *, V: int):
    _, Tb, Cb = off_ref.shape
    # For every offset value v: mask where off == v, add T[c, v].
    # Expressed as a V-step accumulation entirely on the VPU; V is small for
    # the depthwise case (K**k with K<=4, k=4 ⇒ V<=256).  Accumulate f32 and
    # cast once at the end — bf16 tables must not round through bf16 on every
    # loop step (same contract as the gemv/conv kernels).
    def body(v, acc):
        hit = (off_ref[0] == v).astype(jnp.float32)  # [Tb, Cb]
        return acc + hit * tab_ref[:, v][None, :].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, V, body, jnp.zeros((Tb, Cb), jnp.float32))
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("time_tile", "interpret"))
def pcilt_dwconv1d_pallas(
    offsets: jax.Array,
    tables: jax.Array,
    time_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """offsets ``[B, T, C]`` int32, tables ``[C, V]`` -> out ``[B, T, C]``."""
    B, T, C = offsets.shape
    C2, V = tables.shape
    assert C == C2
    Tb = min(time_tile, T)
    while T % Tb:
        Tb -= 1
    Cb = min(C, 128)
    while C % Cb:
        Cb -= 1
    grid = (B, T // Tb, C // Cb)
    return pl.pallas_call(
        functools.partial(_kernel, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Tb, Cb), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((Cb, V), lambda b, i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tb, Cb), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, C), tables.dtype),
        interpret=interpret,
    )(offsets, tables)
