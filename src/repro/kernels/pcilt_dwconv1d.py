"""Pallas TPU kernels: PCILT depthwise conv1d — one fetch per output element.

For a k-tap causal depthwise conv with activation cardinality K, the k input
codes of a channel pack into one offset and the whole tap-dot is a single
table cell:  ``out[b, t, c] = tables[c, offsets[b, t, c]]``.

This is the purest PCILT case on TPU (Mamba2 / Zamba2 conv frontends, k=4):
there is no reduction left.  Channels ride the 128-lane axis; time rides
sublanes.  Two kernels implement it:

* **host-packed** (``pcilt_dwconv1d_pallas``): the caller quantizes, stacks
  the causal tap window, and shift-or packs offsets on the host; the kernel
  is a blocked masked-sum "gather" (a ``fori_loop`` over the ``V`` table
  entries) with the per-channel tables staged in VMEM.
* **fused** (``pcilt_fused_dwconv1d_pallas``): raw float activations in —
  quantize, causal tap-stack (a static ``k``-slice loop over the staged
  signal strip), and little-endian shift-or pack all run in VMEM, so the
  ``[B, T, C]`` int32 offset tensor (as large as the activations themselves)
  never touches HBM.  The fetch is one batched one-hot contraction
  ``[Cb, Tb, V] x [Cb, V] -> [Cb, Tb]`` instead of the ``V``-step masked
  sum: exactly one one-hot term is nonzero per output, so f32 accumulation
  reproduces the table cell bit-exactly even for bf16 tables (same contract
  as the host-packed kernel's f32 accumulation).

The fused kernel stages the whole (padded) signal per channel block —
``[Tp, Cb]`` floats — and revisits it across time tiles, mirroring how the
fused conv2d kernel stages the image; the ``(Tb, Cb)`` tiling is dispatched
through the persistent autotune table under ``fused_dwconv1d`` keys
(``ops.py`` / ``autotune.dwconv1d_candidates``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pcilt_fused import _quantize

__all__ = ["pcilt_dwconv1d_pallas", "pcilt_fused_dwconv1d_pallas"]


def _kernel(off_ref, tab_ref, out_ref, *, V: int):
    _, Tb, Cb = off_ref.shape
    # For every offset value v: mask where off == v, add T[c, v].
    # Expressed as a V-step accumulation entirely on the VPU; V is small for
    # the depthwise case (K**k with K<=4, k=4 ⇒ V<=256).  Accumulate f32 and
    # cast once at the end — bf16 tables must not round through bf16 on every
    # loop step (same contract as the gemv/conv kernels).
    def body(v, acc):
        hit = (off_ref[0] == v).astype(jnp.float32)  # [Tb, Cb]
        return acc + hit * tab_ref[:, v][None, :].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, V, body, jnp.zeros((Tb, Cb), jnp.float32))
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("time_tile", "interpret"))
def pcilt_dwconv1d_pallas(
    offsets: jax.Array,
    tables: jax.Array,
    time_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """offsets ``[B, T, C]`` int32, tables ``[C, V]`` -> out ``[B, T, C]``."""
    B, T, C = offsets.shape
    C2, V = tables.shape
    if C != C2:
        raise ValueError(
            f"offsets channel dim {C} != tables channel dim {C2} "
            f"(offsets {offsets.shape}, tables {tables.shape})")
    Tb = min(time_tile, T)
    while T % Tb:
        Tb -= 1
    Cb = min(C, 128)
    while C % Cb:
        Cb -= 1
    grid = (B, T // Tb, C // Cb)
    return pl.pallas_call(
        functools.partial(_kernel, V=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Tb, Cb), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((Cb, V), lambda b, i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tb, Cb), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, C), tables.dtype),
        interpret=interpret,
    )(offsets, tables)


# ----------------------------------------------------------------------------
# Fused pipeline: quantize + causal tap-stack + pack + fetch in VMEM
# ----------------------------------------------------------------------------


def _pack_taps(codes, *, bits: int, k: int, Tb: int):
    """``[Tb+k-1, Cb]`` strip codes -> ``[Tb, Cb]`` packed tap offsets via a
    static k-slice loop: the little-endian shift-or of
    ``core.offsets.pack_offsets``, built without the ``[B, T, C, k]`` tap
    tensor ever existing."""
    off = codes[0:Tb]
    for j in range(1, k):
        off = off + (codes[j:j + Tb] << (j * bits))  # [Tb, Cb] int32
    return off


def _factored_fetch(off, tab_ref, *, bits: int, k: int, V: int, Tb: int,
                    Cb: int):
    """Factored two-level one-hot fetch: ``off [Tb, Cb]`` -> f32 ``[Tb, Cb]``.

    A flat [Tb, Cb, V] one-hot costs V compares per output and a V-wide
    intermediate; splitting the offset into hi/lo halves (V = Vh * Vl)
    exploits ``1[off==v] = 1[off_hi==vh] * 1[off_lo==vl]``: the one-hots
    shrink to Vl + Vh lanes and the fetch becomes two small per-channel
    contractions, with the largest intermediate only [Cb, Vh, Tb].  Every
    product chain still has exactly one nonzero term per output, so f32
    accumulation returns the table cell bit-exactly (bf16 tables included —
    same contract as the host-packed kernel's fori_loop).
    """
    h = (bits * k) // 2
    Vl, Vh = 1 << h, V >> h
    off_t = jnp.transpose(off)  # [Cb, Tb]
    lanes_l = jax.lax.broadcasted_iota(jnp.int32, (Cb, Tb, Vl), 2)
    lanes_h = jax.lax.broadcasted_iota(jnp.int32, (Cb, Tb, Vh), 2)
    ohl = ((off_t & (Vl - 1))[:, :, None] == lanes_l).astype(jnp.float32)
    ohh = ((off_t >> h)[:, :, None] == lanes_h).astype(jnp.float32)
    tab3 = tab_ref[...].astype(jnp.float32).reshape(Cb, Vh, Vl)
    # m[c, vh, t] = sum_vl tab3[c, vh, vl] * ohl[c, t, vl]
    m = jax.lax.dot_general(
        tab3, ohl, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)  # [Cb, Vh, Tb]
    acc = jnp.sum(m * jnp.transpose(ohh, (0, 2, 1)), axis=1)  # [Cb, Tb]
    return jnp.transpose(acc)  # [Tb, Cb]


def _fused_kernel(x_ref, scale_ref, tab_ref, out_ref, *,
                  bits: int, zero_point: int, k: int, V: int, Tb: int):
    _, _, Cb = x_ref.shape
    # Quantize this time tile's strip (Tb outputs need Tb + k - 1 padded
    # inputs — the caller left-pads the raw signal, so tap j of output t is
    # padded row t + j) and tap-stack/pack in VMEM.
    t0 = pl.program_id(1) * Tb
    strip = x_ref[0, pl.ds(t0, Tb + k - 1), :]  # [Tb+k-1, Cb] from VMEM
    codes = _quantize(strip, scale_ref[0, 0], bits=bits, zero_point=zero_point)
    off = _pack_taps(codes, bits=bits, k=k, Tb=Tb)
    acc = _factored_fetch(off, tab_ref, bits=bits, k=k, V=V, Tb=Tb, Cb=Cb)
    out_ref[0] = acc.astype(out_ref.dtype)


def _fused_sat_kernel(x_ref, scale_ref, tab_ref, out_ref, cnt_ref, ratio_ref,
                      *, bits: int, zero_point: int, k: int, V: int, Tb: int):
    """Counter-carrying :func:`_fused_kernel`: two extra ``[1, 1]`` outputs
    (int32 saturation count, f32 running ``max(|x|)/scale``) reduced across
    the grid, block-resident via constant index maps.

    Adjacent time tiles overlap by ``k - 1`` strip rows, so the count keeps
    the overlap rows only on the first time tile — every row of the padded
    signal is counted exactly once (the caller's zero time/channel pads
    quantize to the in-range zero_point and contribute nothing, so the
    total equals the host count over the unpadded signal).  ``max`` is
    idempotent; the ratio accumulates every step.
    """
    _, _, Cb = x_ref.shape
    b, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((b == 0) & (i == 0) & (j == 0))
    def _zero_stats():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ratio_ref[...] = jnp.zeros_like(ratio_ref)

    t0 = i * Tb
    strip = x_ref[0, pl.ds(t0, Tb + k - 1), :]  # [Tb+k-1, Cb] from VMEM
    q = jnp.round(strip / scale_ref[0, 0]) + zero_point
    sat = ((q < 0) | (q > (1 << bits) - 1)).astype(jnp.int32)
    rows = jax.lax.broadcasted_iota(jnp.int32, sat.shape, 0)
    keep = (rows >= k - 1) | (i == 0)
    cnt_ref[0, 0] += jnp.sum(jnp.where(keep, sat, 0))
    ratio_ref[0, 0] = jnp.maximum(
        ratio_ref[0, 0],
        (jnp.max(jnp.abs(strip)) / scale_ref[0, 0]).astype(jnp.float32))
    codes = jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.int32)
    off = _pack_taps(codes, bits=bits, k=k, Tb=Tb)
    acc = _factored_fetch(off, tab_ref, bits=bits, k=k, V=V, Tb=Tb, Cb=Cb)
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "zero_point", "k",
                                             "tiles", "counters", "interpret"))
def pcilt_fused_dwconv1d_pallas(
    x: jax.Array,
    scale: jax.Array,
    tables: jax.Array,
    *,
    bits: int,
    zero_point: int,
    k: int,
    tiles,
    counters: bool = False,
    interpret: bool = False,
):
    """x ``[B, Tp, C]`` float (already time-padded: ``Tp = To + k - 1``),
    scale ``[1, 1]``, tables ``[C, V]`` (``V = 2**(bits*k)``) -> ``[B, To, C]``.

    The whole padded signal is staged per channel block and revisited across
    time tiles; each grid step quantizes its strip, packs the k causal taps,
    and fetches — offsets never exist outside VMEM.  ``tiles`` is a
    ``(Tb, Cb)`` tuple with ``Tb | To`` and ``Cb | C``.

    ``counters=True`` (a static opt-in: the default trace is unchanged)
    returns ``(out, count, ratio)`` — the int32 number of signal elements
    the quantizer clipped and the f32 ``max(|x|)/scale`` overshoot, reduced
    in VMEM by :func:`_fused_sat_kernel`.
    """
    B, Tp, C = x.shape
    C2, V = tables.shape
    if C != C2:
        raise ValueError(
            f"x channel dim {C} != tables channel dim {C2} "
            f"(x {x.shape}, tables {tables.shape})")
    To = Tp - k + 1
    Tb, Cb = tiles
    grid = (B, To // Tb, C // Cb)
    in_specs = [
        pl.BlockSpec((1, Tp, Cb), lambda b, i, j: (b, 0, j)),
        pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
        pl.BlockSpec((Cb, V), lambda b, i, j: (j, 0)),
    ]
    out_spec = pl.BlockSpec((1, Tb, Cb), lambda b, i, j: (b, i, j))
    if counters:
        out, cnt, ratio = pl.pallas_call(
            functools.partial(_fused_sat_kernel, bits=bits,
                              zero_point=zero_point, k=k, V=V, Tb=Tb),
            grid=grid,
            in_specs=in_specs,
            out_specs=(
                out_spec,
                pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
                pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            ),
            out_shape=(
                jax.ShapeDtypeStruct((B, To, C), tables.dtype),
                jax.ShapeDtypeStruct((1, 1), jnp.int32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
            ),
            interpret=interpret,
        )(x, scale, tables)
        return out, cnt[0, 0], ratio[0, 0]
    return pl.pallas_call(
        functools.partial(_fused_kernel, bits=bits, zero_point=zero_point,
                          k=k, V=V, Tb=Tb),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, To, C), tables.dtype),
        interpret=interpret,
    )(x, scale, tables)
