"""Jit'd dispatch wrappers for the PCILT Pallas kernels.

Handles platform selection (compiled Pallas on TPU, ``interpret=True``
elsewhere so the exact kernel body is validated on CPU), padding to tile
multiples, unpadding — and **tile dispatch through the persistent autotune
lookup table** (``autotune.py``, Inductor-style):

* every wrapper builds a shape key ``(kernel, B, G, V, O, dtype, backend)``
  and consults the JSON-backed cache; a hit dispatches the recorded tiles at
  zero cost (a dict probe, no timing, no extra compile);
* a miss falls back to the VMEM-budget heuristic — unless tuning is requested
  (``autotune=True`` per call, or ``REPRO_PCILT_AUTOTUNE=1`` ambient) *and*
  the inputs are concrete (never under a ``jit`` trace), in which case the
  candidate tilings are timed once and the winner recorded for every later
  process.

Three pipelines are exposed per op:

* **host-packed** (``pcilt_gemv`` / ``pcilt_conv2d`` / ``pcilt_dwconv1d``):
  caller quantizes + packs offsets on the host; kernels fetch-and-add.
* **fused** (``pcilt_fused_gemv`` / ``pcilt_fused_conv2d`` /
  ``pcilt_fused_dwconv1d``): raw float activations in; quantize → pack →
  fetch → adder-tree run entirely in VMEM (``pcilt_fused.py``,
  ``pcilt_dwconv1d.py``), so the int32 offset tensor never touches HBM.
* **shared-pool fused** (``pcilt_shared_gemv`` / ``pcilt_shared_conv2d``):
  the extension-3 weight-deduped configuration — a ``[X, V, O]`` pool of
  unique segment tables plus ``[G]`` int pointers — executed at fused speed;
  the pointer indirection is resolved inside the kernel
  (``pcilt_shared.py``) and the dense ``[G, V, O]`` tables are never
  materialized in HBM.  Shape keys carry the pool cardinality ``X``.

Mesh execution (``core.lut_layers`` ``mesh=``) calls these same wrappers
from inside ``shard_map``: the table operand arrives as one device's
``[G/D, V, O]`` shard (``PartitionSpec("model", None, None)`` — only the
segment axis shards) or its local ext.-3 pool (``ShardedSharedPool``:
``[Xmax, V, O]`` with ``Xmax = max_d X_d`` the largest *local* pool
cardinality, so staged bytes follow local X, not global G or X), and the
wrapper's output is that shard's partial adder-tree sum — the ``psum`` over
the model axis lives one level up, in ``lut_layers``, never in a kernel.
The conv wrappers additionally take ``seg_offset`` / ``n_total`` so a
shard's kernel can im2col the full replicated image **in VMEM** and slice
exactly its own patch columns — no host-side im2col even under a mesh.
Consequently the autotune shape keys are built from the **local** shapes
(``G/D``, local ``X``): tunings recorded at different device counts occupy
different keys, and two deployments whose local problems coincide share one
entry on purpose.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
# Single sources of truth for padding — the host-packed reference paths and
# the fused kernel wrappers must pad identically: the XLA-conformant
# stride-aware "SAME" split for conv2d, and the CAUSAL/SAME/VALID time pads
# for the depthwise conv1d.
from repro.core.lut_layers import conv_same_pads as _conv_same_pads
from repro.core.lut_layers import _dwconv_pads

from . import autotune as atn
from .pcilt_gemv import pcilt_gemv_pallas, default_tiles
from .pcilt_conv2d import pcilt_conv2d_pallas
from .pcilt_dwconv1d import pcilt_dwconv1d_pallas, pcilt_fused_dwconv1d_pallas
from .pcilt_fused import (pcilt_fused_gemv_pallas,
                          pcilt_fused_gemv_stacked_pallas,
                          pcilt_fused_gemv_paired_pallas,
                          pcilt_fused_gemv_paired_stacked_pallas,
                          pcilt_fused_gemv_plan_pallas,
                          pcilt_fused_conv2d_pallas)
from .pcilt_shared import (pcilt_shared_gemv_pallas,
                           pcilt_shared_conv2d_pallas)

__all__ = [
    "pcilt_gemv",
    "pcilt_conv2d",
    "pcilt_dwconv1d",
    "pcilt_fused_gemv",
    "pcilt_fused_gemv_stacked",
    "pcilt_fused_gemv_paired",
    "pcilt_fused_gemv_paired_stacked",
    "pcilt_fused_gemv_plan",
    "pcilt_fused_conv2d",
    "pcilt_fused_dwconv1d",
    "pcilt_shared_gemv",
    "pcilt_shared_conv2d",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _is_concrete(*xs) -> bool:
    return not any(compat.is_tracer(x) for x in xs)


_round_up = atn._round_up


def _pad_axis(x: jax.Array, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _scale_2d(scale, dtype) -> jax.Array:
    """Per-tensor scale as the ``[1, 1]`` operand the fused kernels stage."""
    s = jnp.asarray(scale, dtype)
    if s.size != 1:
        raise ValueError(
            f"fused kernels take a per-tensor (scalar) scale, got shape {s.shape}"
        )
    return s.reshape(1, 1)


def _fit_tiles(tiles, B: int, G: int, O: int) -> tuple:
    """Clamp a (Bb, Gb, Ob) tiling to the problem and force ``Gb | G``."""
    Bb, Gb, Ob = tiles
    Bb, Gb, Ob = min(Bb, _round_up(B, 8)), min(Gb, G), min(Ob, O)
    while G % Gb:
        Gb -= 1
    return Bb, Gb, Ob


def _fit_conv_tiles(tiles, Ho: int, G: int, O: int) -> tuple:
    """Clamp a (Hb, Gb, Ob) conv tiling: ``Hb | Ho`` and ``Gb | G``."""
    Hb, Gb, Ob = tiles
    Hb, Gb, Ob = min(Hb, Ho), min(Gb, G), min(Ob, O)
    while Ho % Hb:
        Hb -= 1
    while G % Gb:
        Gb -= 1
    return Hb, Gb, Ob


# ----------------------------------------------------------------------------
# Host-packed pipeline
# ----------------------------------------------------------------------------


def pcilt_gemv(
    offsets: jax.Array,
    tables: jax.Array,
    tiles=None,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """offsets [B, G] int32, tables [G, V, O] -> [B, O]."""
    B, O = offsets.shape[0], tables.shape[-1]
    G, V = tables.shape[0], tables.shape[1]
    key = atn.shape_key("gemv_host", dtype=tables.dtype,
                        backend=jax.default_backend(), B=B, G=G, V=V, O=O)
    if tiles is None:
        tiles = atn.lookup(key)
        if tiles is not None:
            tiles = (tiles.Bb, tiles.Gb, tiles.Ob)
        elif atn.autotune_enabled(autotune) and _is_concrete(offsets, tables):
            cfg = atn.tune(
                key,
                atn.gemv_candidates(B, G, V, O, tables.dtype.itemsize),
                lambda c: _host_gemv_bench(offsets, tables, c),
            )
            tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
    if tiles is not None:
        tiles = _fit_tiles(tiles, B, G, O)
    offsets, _ = _pad_axis(offsets, 0, tiles[0] if tiles else 8)
    tables, _ = _pad_axis(
        tables, 2, (tiles[2] if tiles else 128) if O >= 128 else 1)
    out = pcilt_gemv_pallas(offsets, tables, interpret=not on_tpu(), tiles=tiles)
    return out[:B, :O]


def _host_gemv_bench(offsets, tables, cfg):
    B, O = offsets.shape[0], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, tables.shape[0], O)
    off_p, _ = _pad_axis(offsets, 0, tiles[0])
    tab_p, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    return lambda: pcilt_gemv_pallas(
        off_p, tab_p, interpret=not on_tpu(), tiles=tiles
    ).block_until_ready()


def pcilt_conv2d(
    offsets: jax.Array,
    tables: jax.Array,
    tiles=None,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """offsets [B, Ho, Wo, G] int32, tables [G, V, O] -> [B, Ho, Wo, O].

    Pads Wo to a sublane multiple and O to a lane multiple (mirroring the
    gemv wrapper), then unpads — non-128-multiple channel counts and ragged
    widths are the caller's problem no longer.
    """
    B, Ho, Wo, G = offsets.shape
    V, O = tables.shape[1], tables.shape[-1]
    key = atn.shape_key("conv2d_host", dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, Ho=Ho, Wo=Wo, G=G, V=V, O=O)
    cfg = None
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                offsets, tables):
            cfg = atn.tune(
                key,
                atn.conv2d_candidates(Ho, G, V, O, tables.dtype.itemsize),
                lambda c: _host_conv2d_bench(offsets, tables, c),
            )
        if cfg is not None:
            tiles = (cfg.row_tile, cfg.Gb, cfg.Ob)
    if tiles is not None:
        # Same clamp the fused path applies: a hand-edited or cross-version
        # cache entry with Gb ∤ G (or oversized Hb/Ob) must never reach the
        # kernel unclamped.
        tiles = _fit_conv_tiles(tiles, Ho, G, O)
    # Padded-Wo offsets index table row 0; the fetched garbage is sliced off.
    offsets, _ = _pad_axis(offsets, 2, 8 if Wo >= 8 else 1)
    tables, _ = _pad_axis(
        tables, 2, (tiles[2] if tiles else 128) if O >= 128 else 1)
    out = pcilt_conv2d_pallas(offsets, tables, interpret=not on_tpu(),
                              tiles=tiles)
    return out[:, :, :Wo, :O]


def _host_conv2d_bench(offsets, tables, cfg):
    Wo, O = offsets.shape[2], tables.shape[-1]
    off_p, _ = _pad_axis(offsets, 2, 8 if Wo >= 8 else 1)
    tab_p, _ = _pad_axis(tables, 2, cfg.Ob if O >= 128 else 1)
    tiles = (cfg.row_tile, cfg.Gb, min(cfg.Ob, tab_p.shape[-1]))
    return lambda: pcilt_conv2d_pallas(
        off_p, tab_p, interpret=not on_tpu(), tiles=tiles
    ).block_until_ready()


def pcilt_dwconv1d(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, T, C] int32, tables [C, V] -> [B, T, C]."""
    C = offsets.shape[-1]
    offsets, padc = _pad_axis(offsets, 2, 128 if C >= 128 else 1)
    tables, _ = _pad_axis(tables, 0, 128 if C >= 128 else 1)
    out = pcilt_dwconv1d_pallas(offsets, tables, interpret=not on_tpu())
    return out[..., :C]


def pcilt_fused_dwconv1d(
    x: jax.Array,
    tables: jax.Array,
    spec,
    scale,
    k: int,
    padding: str = "CAUSAL",
    tiles=None,
    autotune: Optional[bool] = None,
    with_stats: bool = False,
):
    """x [B, T, C] float, tables [C, V] (``V = 2**(bits*k)``) -> [B, To, C].

    The fused depthwise pipeline: the only host-side work is the time
    zero-pad of the raw signal; quantize, causal tap-stack, little-endian
    pack, and the one-fetch-per-output table lookup all run in VMEM
    (``pcilt_fused_dwconv1d_pallas``), so the ``[B, T, C]`` int32 offset
    tensor of the host-packed path never exists in HBM.  ``padding``:
    ``"CAUSAL"`` (``To = T``, taps ``t-k+1..t`` — the Mamba/SSM decode
    frontend), ``"SAME"`` (centered), or ``"VALID"`` (``To = T - k + 1`` —
    e.g. a pre-assembled ``[B, k, C]`` decode window yielding one output).

    ``with_stats=True`` runs the counter-carrying kernel variant and
    returns ``(out, count, ratio)`` saturation stats (the count covers the
    raw ``[B, T, C]`` signal exactly — time/channel pads quantize in
    range).  Counted and uncounted timings never share an autotune entry:
    stats dispatch records under the ``fused_dwconv1d_sat`` key family.
    """
    B, T, C = x.shape
    C2, V = tables.shape
    if C != C2:
        raise ValueError(
            f"x channel dim {C} != tables channel dim {C2} "
            f"(x {x.shape}, tables {tables.shape})")
    x = jnp.pad(x, ((0, 0), _dwconv_pads(k, padding), (0, 0)))
    To = x.shape[1] - k + 1
    kname = "fused_dwconv1d_sat" if with_stats else "fused_dwconv1d"
    key = atn.shape_key(kname, dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, T=To, C=C, V=V, k=k, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, k=k,
              counters=with_stats, interpret=not on_tpu())
    xp, _ = _pad_axis(x, 2, 128 if C >= 128 else 1)
    tp, _ = _pad_axis(tables, 0, 128 if C >= 128 else 1)
    Cp = xp.shape[-1]
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                xp, s2, tp):
            cfg = atn.tune(
                key,
                atn.dwconv1d_candidates(To, Cp, V, k, tables.dtype.itemsize),
                lambda c: _fused_dwconv1d_bench(xp, s2, tp, c, kw, To),
            )
        if cfg is None:
            cfg = atn.dwconv1d_candidates(To, Cp, V, k,
                                          tables.dtype.itemsize)[0]
        tiles = (cfg.Bb, cfg.Ob)
    tiles = (atn._div_down(To, max(1, tiles[0])),
             atn._div_down(Cp, max(1, tiles[1])))
    if with_stats:
        out, cnt, ratio = pcilt_fused_dwconv1d_pallas(xp, s2, tp,
                                                      tiles=tiles, **kw)
        return out[..., :C], cnt, ratio
    out = pcilt_fused_dwconv1d_pallas(xp, s2, tp, tiles=tiles, **kw)
    return out[..., :C]


def _fused_dwconv1d_bench(xp, s2, tp, cfg, kw, To):
    tiles = (atn._div_down(To, max(1, cfg.Bb)),
             atn._div_down(xp.shape[-1], max(1, cfg.Ob)))
    return lambda: jax.block_until_ready(pcilt_fused_dwconv1d_pallas(
        xp, s2, tp, tiles=tiles, **kw
    ))


# ----------------------------------------------------------------------------
# Fused pipeline: raw floats in, quantize/pack/fetch in VMEM
# ----------------------------------------------------------------------------


def pcilt_fused_gemv(
    x: jax.Array,
    tables: jax.Array,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """x [B, n] float, tables [G, V, O] (``n == G * group``) -> [B, O].

    Fuses ``quantize(x, spec, scale)`` + ``pack_offsets`` + fetch into one
    Pallas call; ``spec`` is a ``core.QuantSpec`` (only ``bits`` and
    ``zero_point`` cross into the kernel, both static).
    """
    B, n = x.shape
    G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} (the fused kernel "
            f"packs contiguous segments; generalized SegmentPlans are "
            f"rejected upstream at the core.lut_layers dispatch boundary)")
    key = atn.shape_key("fused_gemv", dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, G=G, V=V, O=O, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, tables):
            cfg = atn.tune(
                key,
                atn.gemv_candidates(B, G, V, O, tables.dtype.itemsize),
                lambda c: _fused_gemv_bench(x, s2, tables, c, kw),
            )
        if cfg is not None:
            tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
        else:
            tiles = default_tiles(B, G, V, O, itemsize=tables.dtype.itemsize)
    tiles = _fit_tiles(tiles, B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    out = pcilt_fused_gemv_pallas(xp, s2, tp, tiles=tiles, **kw)
    return out[:B, :O]


def _fused_gemv_bench(x, s2, tables, cfg, kw):
    B, G, O = x.shape[0], tables.shape[0], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    return lambda: pcilt_fused_gemv_pallas(
        xp, s2, tp, tiles=tiles, **kw
    ).block_until_ready()




def pcilt_fused_gemv_stacked(
    x: jax.Array,
    tables: jax.Array,
    layer,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
    with_stats: bool = False,
):
    """x [B, n] float, tables [L, G, V, O] (``n == G * group``), layer a
    (possibly traced) int scalar -> [B, O].

    The layer-scanned decode dispatch: one ``[L, G, V, O]`` stack holds the
    tables of every layer of a network, the ``lax.scan`` over layers carries
    only the integer layer index, and the kernel's scalar-prefetched index
    map stages that layer's tiles straight out of the resident stack — no
    per-step ``dynamic_slice`` copy of a whole ``[G, V, O]`` table through
    HBM.  ``scale`` is this layer's per-tensor activation scale (callers
    slice it from their ``[L]`` calibration vector; a traced scalar is
    fine).  Tiles dispatch through ``fused_gemv_stacked`` shape keys, which
    carry ``L``, the decode-batch row count ``R`` (== ``B`` here: the
    serving slot count whose row-tile sweep the recorded winner came from —
    keyed explicitly so a future row-packing dispatch can tune at
    ``R != B`` without a key-grammar change), and — under a mesh, where
    this wrapper sees one device's ``[L, G/D, V, O]`` shard — the *local*
    ``G``.

    ``with_stats=True`` runs the counter-carrying kernel variant and
    returns ``(out, count, ratio)`` — the int32 saturation count and the
    f32 ``max(|x|)/scale`` overshoot of this call's quantization.  Stats
    dispatch records under the ``fused_gemv_stacked_sat`` key family (same
    dims), so counted and uncounted timings never share a cache entry.
    """
    B, n = x.shape
    L, G, V, O = tables.shape
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} (the stacked fused "
            f"kernel packs contiguous segments; generalized SegmentPlans are "
            f"rejected upstream at the core.lut_layers dispatch boundary)")
    kname = "fused_gemv_stacked_sat" if with_stats else "fused_gemv_stacked"
    key = atn.shape_key(kname, dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, R=B, L=L, G=G, V=V, O=O, g=group,
                        bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    l1 = jnp.asarray(layer, jnp.int32).reshape(1)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              counters=with_stats, interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, l1, tables):
            cfg = atn.tune(
                key,
                atn.stacked_gemv_candidates(B, L, G, V, O,
                                            tables.dtype.itemsize),
                lambda c: _fused_gemv_stacked_bench(l1, x, s2, tables, c, kw),
            )
        if cfg is not None:
            tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
        else:
            tiles = default_tiles(B, G, V, O, itemsize=tables.dtype.itemsize)
    tiles = _fit_tiles(tiles, B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    tp, _ = _pad_axis(tables, 3, tiles[2] if O >= 128 else 1)
    if with_stats:
        out, cnt, ratio = pcilt_fused_gemv_stacked_pallas(l1, xp, s2, tp,
                                                          tiles=tiles, **kw)
        return out[:B, :O], cnt, ratio
    out = pcilt_fused_gemv_stacked_pallas(l1, xp, s2, tp, tiles=tiles, **kw)
    return out[:B, :O]


def _fused_gemv_stacked_bench(l1, x, s2, tables, cfg, kw):
    B, G, O = x.shape[0], tables.shape[1], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    tp, _ = _pad_axis(tables, 3, tiles[2] if O >= 128 else 1)
    return lambda: jax.block_until_ready(pcilt_fused_gemv_stacked_pallas(
        l1, xp, s2, tp, tiles=tiles, **kw
    ))


def pcilt_fused_gemv_paired(
    x: jax.Array,
    tables: jax.Array,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
    with_stats: bool = False,
):
    """x [B, n] float, paired tables [G2, V2, O] (``n == G2 * 2 * group``,
    ``V2 = (2**(bits*group))**2``) -> [B, O].

    The TL1-style multi-scalar dispatch: each fetch covers two adjacent
    ``group``-wide segments (``core.pcilt.build_paired_tables``), halving
    the fetch count and adder-tree depth.  Keys record under
    ``fused_gemv_paired`` with **paired-space** ``G``/``V`` — the shapes
    the kernel actually stages.

    ``with_stats=True`` returns ``(out, count, ratio)`` saturation stats
    (see :func:`pcilt_fused_gemv_stacked`); keys record under
    ``fused_gemv_paired_sat``.
    """
    B, n = x.shape
    G2, V2, O = tables.shape
    if n != G2 * 2 * group:
        raise ValueError(
            f"x trailing dim {n} != G2*2*group = {G2}*2*{group} (pad x over "
            f"the phantom segment when the unpaired G was odd — "
            f"core.lut_layers does this for you)")
    kname = "fused_gemv_paired_sat" if with_stats else "fused_gemv_paired"
    key = atn.shape_key(kname, dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, G=G2, V=V2, O=O, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              counters=with_stats, interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, tables):
            cfg = atn.tune(
                key,
                atn.paired_gemv_candidates(B, G2, V2, O,
                                           tables.dtype.itemsize),
                lambda c: _fused_gemv_paired_bench(x, s2, tables, c, kw),
            )
        if cfg is None:
            # Candidate 0 keeps the staged [Gb, V2, Ob] tile under the VMEM
            # budget — the untuned fallback must never oversubscribe.
            cfg = atn.paired_gemv_candidates(B, G2, V2, O,
                                             tables.dtype.itemsize)[0]
        tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
    tiles = _fit_tiles(tiles, B, G2, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    if with_stats:
        out, cnt, ratio = pcilt_fused_gemv_paired_pallas(xp, s2, tp,
                                                         tiles=tiles, **kw)
        return out[:B, :O], cnt, ratio
    out = pcilt_fused_gemv_paired_pallas(xp, s2, tp, tiles=tiles, **kw)
    return out[:B, :O]


def _fused_gemv_paired_bench(x, s2, tables, cfg, kw):
    B, G2, O = x.shape[0], tables.shape[0], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G2, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    return lambda: jax.block_until_ready(pcilt_fused_gemv_paired_pallas(
        xp, s2, tp, tiles=tiles, **kw
    ))


def pcilt_fused_gemv_paired_stacked(
    x: jax.Array,
    tables: jax.Array,
    layer,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
    with_stats: bool = False,
):
    """x [B, n] float, **segment-major** paired tables [G2, L, V2, O]
    (``n == G2 * 2 * group``), layer a (possibly traced) int scalar
    -> [B, O].

    The paired decode dispatch: the whole network's paired tables live in
    one segment-major stack (``core.pcilt.build_paired_stacked_tables``)
    and the scan's layer index rides the fetch's value coordinate (the
    kernel folds L into the gathered row), so staging is layer-independent
    and the traced layer costs nothing.  Keys record under
    ``fused_gemv_paired_stacked`` with paired-space ``G``/``V`` plus ``L``
    and the decode-batch row count ``R`` (== ``B``: the serving slot count
    the row-tile sweep anchors on, keyed explicitly like the dense stacked
    family); under a mesh the wrapper sees one device's ``[G2/D, L, V2, O]``
    shard and keys carry the local ``G``.

    ``with_stats=True`` returns ``(out, count, ratio)`` saturation stats
    (see :func:`pcilt_fused_gemv_stacked`); keys record under
    ``fused_gemv_paired_stacked_sat``.
    """
    B, n = x.shape
    G2, L, V2, O = tables.shape
    if n != G2 * 2 * group:
        raise ValueError(
            f"x trailing dim {n} != G2*2*group = {G2}*2*{group} (pad x over "
            f"the phantom segment when the unpaired G was odd — "
            f"core.lut_layers does this for you)")
    kname = ("fused_gemv_paired_stacked_sat" if with_stats
             else "fused_gemv_paired_stacked")
    key = atn.shape_key(kname, dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, R=B, L=L, G=G2, V=V2, O=O, g=group,
                        bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    l1 = jnp.asarray(layer, jnp.int32).reshape(1)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              counters=with_stats, interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, l1, tables):
            cfg = atn.tune(
                key,
                atn.paired_stacked_gemv_candidates(B, L, G2, V2, O,
                                                   tables.dtype.itemsize),
                lambda c: _fused_gemv_paired_stacked_bench(
                    l1, x, s2, tables, c, kw),
            )
        if cfg is None:
            # Candidate 0's [Gb, L, V2, Ob] staging is budget-clamped with
            # the L factor (the seg-major kernel stages every layer of its
            # segment tile) — the untuned fallback stays VMEM-safe.
            cfg = atn.paired_stacked_gemv_candidates(
                B, L, G2, V2, O, tables.dtype.itemsize)[0]
        tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
    tiles = _fit_tiles(tiles, B, G2, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    tp, _ = _pad_axis(tables, 3, tiles[2] if O >= 128 else 1)
    if with_stats:
        out, cnt, ratio = pcilt_fused_gemv_paired_stacked_pallas(
            l1, xp, s2, tp, tiles=tiles, **kw)
        return out[:B, :O], cnt, ratio
    out = pcilt_fused_gemv_paired_stacked_pallas(l1, xp, s2, tp, tiles=tiles,
                                                 **kw)
    return out[:B, :O]


def _fused_gemv_paired_stacked_bench(l1, x, s2, tables, cfg, kw):
    B, G2, O = x.shape[0], tables.shape[0], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G2, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    tp, _ = _pad_axis(tables, 3, tiles[2] if O >= 128 else 1)
    return lambda: jax.block_until_ready(
        pcilt_fused_gemv_paired_stacked_pallas(
            l1, xp, s2, tp, tiles=tiles, **kw
        ))


def pcilt_fused_gemv_plan(
    x: jax.Array,
    tables: jax.Array,
    plan_idx: jax.Array,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """x [B, n] float, tables [G, V, O], plan_idx [G, group] int32
    (``-1`` = unused slot) -> [B, O].

    The generalized-``SegmentPlan`` fused dispatch: segments may skip or
    reuse arbitrary positions of ``x``, resolved by an in-VMEM gather of
    the plan index before the standard quantize→pack→fetch — plan-built
    tables no longer fall back to the host gather path.  Keys record under
    ``fused_gemv_plan``; the tiling space is the dense GEMV's (the plan
    gather adds only a ``[Gb*group]`` index block per step).
    """
    B, n = x.shape
    G, V, O = tables.shape
    if plan_idx.shape != (G, group):
        raise ValueError(
            f"plan_idx shape {tuple(plan_idx.shape)} != (G, group) = "
            f"({G}, {group}) (tables {tables.shape})")
    key = atn.shape_key("fused_gemv_plan", dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, G=G, V=V, O=O, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    p2 = plan_idx.astype(jnp.int32)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, p2, tables):
            cfg = atn.tune(
                key,
                atn.gemv_candidates(B, G, V, O, tables.dtype.itemsize),
                lambda c: _fused_gemv_plan_bench(x, s2, p2, tables, c, kw),
            )
        if cfg is not None:
            tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
        else:
            tiles = default_tiles(B, G, V, O, itemsize=tables.dtype.itemsize)
    tiles = _fit_tiles(tiles, B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    out = pcilt_fused_gemv_plan_pallas(xp, s2, p2, tp, tiles=tiles, **kw)
    return out[:B, :O]


def _fused_gemv_plan_bench(x, s2, p2, tables, cfg, kw):
    B, G, O = x.shape[0], tables.shape[0], tables.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    tp, _ = _pad_axis(tables, 2, tiles[2] if O >= 128 else 1)
    return lambda: pcilt_fused_gemv_plan_pallas(
        xp, s2, p2, tp, tiles=tiles, **kw
    ).block_until_ready()


def _seg_2d(seg_offset) -> jax.Array:
    """Segment offset as the ``[1, 1]`` int32 operand the conv kernels stage
    (0 when unsharded; the shard's first global segment under ``shard_map``)."""
    if seg_offset is None:
        return jnp.zeros((1, 1), jnp.int32)
    return jnp.asarray(seg_offset, jnp.int32).reshape(1, 1)


def pcilt_fused_conv2d(
    x: jax.Array,
    tables: jax.Array,
    spec,
    scale,
    group: int,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    tiles=None,
    autotune: Optional[bool] = None,
    seg_offset=None,
    n_total: Optional[int] = None,
) -> jax.Array:
    """x [B, H, W, C] float NHWC, tables [G, V, O] -> [B, Ho, Wo, O].

    The only host-side work is the spatial zero-pad of the raw activations;
    im2col happens on quantized codes inside VMEM (``pcilt_fused.py``), so
    neither the ``[B, Ho, Wo, kh*kw*C]`` float patch tensor nor the
    ``[B, Ho, Wo, G]`` int32 offset tensor is ever materialized in HBM.
    Tables must cover ``n_total = G * group >= kh*kw*C`` (alignment slots
    built from zero weights, as ``core.lut_layers.pcilt_conv2d`` does).

    Under ``shard_map`` (``core.lut_layers`` ``mesh=`` conv route) ``tables``
    is one device's ``[G/D, V, O]`` shard: pass ``seg_offset`` (the shard's
    first segment in global segment space — typically
    ``axis_index * G_local``) and ``n_total`` (the *global* padded reduction
    length) so the in-VMEM im2col slices this shard's patch columns.  The
    autotune shape key carries the local ``G`` as usual.
    """
    if padding == "SAME":
        x = jnp.pad(x, _conv_same_pads(x.shape[1], x.shape[2], kh, kw, stride))
    B, Hp, Wp, C = x.shape
    G, V, O = tables.shape
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    key = atn.shape_key("fused_conv2d", dtype=tables.dtype,
                        backend=jax.default_backend(),
                        B=B, Ho=Ho, W=Wp, C=C, k=kh * kw, s=stride,
                        G=G, V=V, O=O, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    seg2 = _seg_2d(seg_offset)
    kw_args = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
                   kh=kh, kw=kw, stride=stride,
                   n_total=int(n_total) if n_total else G * group,
                   interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, seg2, tables):
            cfg = atn.tune(
                key,
                atn.conv2d_candidates(Ho, G, V, O, tables.dtype.itemsize,
                                      Wo=Wo),
                lambda c: _fused_conv2d_bench(x, s2, seg2, tables, c,
                                              kw_args, Ho),
            )
        if cfg is None:
            cfg = atn.conv2d_candidates(Ho, G, V, O, tables.dtype.itemsize,
                                        Wo=Wo)[0]
        tiles = (cfg.row_tile, cfg.Gb, cfg.Ob)
    Hb, Gb, Ob = _fit_conv_tiles(tiles, Ho, G, O)
    tp, _ = _pad_axis(tables, 2, Ob if O >= 128 else 1)
    out = pcilt_fused_conv2d_pallas(x, s2, seg2, tp, tiles=(Hb, Gb, Ob),
                                    **kw_args)
    return out[..., :O]


def _fused_conv2d_bench(x, s2, seg2, tables, cfg, kw_args, Ho):
    G, O = tables.shape[0], tables.shape[-1]
    Hb, Gb, Ob = _fit_conv_tiles((cfg.row_tile, cfg.Gb, cfg.Ob), Ho, G, O)
    tp, _ = _pad_axis(tables, 2, Ob if O >= 128 else 1)
    return lambda: pcilt_fused_conv2d_pallas(
        x, s2, seg2, tp, tiles=(Hb, Gb, Ob), **kw_args
    ).block_until_ready()


# ----------------------------------------------------------------------------
# Shared-pool fused pipeline (extension 3): pool + pointers in, indirection
# resolved in VMEM — the dense [G, V, O] tables never exist in HBM.
# ----------------------------------------------------------------------------


def pcilt_shared_gemv(
    x: jax.Array,
    pool: jax.Array,
    seg_idx: jax.Array,
    spec,
    scale,
    group: int,
    tiles=None,
    autotune: Optional[bool] = None,
) -> jax.Array:
    """x [B, n] float, pool [X, V, O], seg_idx [G] int32 (``n == G * group``)
    -> [B, O].

    The fused quantize→pack→fetch pipeline over the extension-3 shared pool;
    the per-shape tiling is dispatched through the autotune lookup table
    under a ``shared_gemv`` key that includes the pool cardinality ``X``.
    """
    B, n = x.shape
    X, V, O = pool.shape
    G = int(seg_idx.shape[-1])
    if n != G * group:
        raise ValueError(
            f"x trailing dim {n} != G*group = {G}*{group} (the shared-pool "
            f"kernel packs contiguous segments; generalized SegmentPlans are "
            f"rejected upstream at the core.lut_layers dispatch boundary)")
    key = atn.shape_key("shared_gemv", dtype=pool.dtype,
                        backend=jax.default_backend(),
                        B=B, G=G, V=V, O=O, X=X, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    idx2 = seg_idx.astype(jnp.int32).reshape(1, G)
    kw = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
              interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, idx2, pool):
            cfg = atn.tune(
                key,
                atn.shared_gemv_candidates(B, G, V, O, X,
                                           pool.dtype.itemsize),
                lambda c: _shared_gemv_bench(x, s2, idx2, pool, c, kw),
            )
        if cfg is None:
            # The staged pool is Gb-independent, but the in-kernel one-hot
            # scratch still scales with Gb — the untuned fallback must use
            # the VMEM-bounded heuristic (candidate 0), like every other
            # pipeline; "stage everything" is only reached via tuning, where
            # a compile rejection is skipped rather than fatal.
            cfg = atn.shared_gemv_candidates(B, G, V, O, X,
                                             pool.dtype.itemsize)[0]
        tiles = (cfg.Bb, cfg.Gb, cfg.Ob)
    tiles = _fit_tiles(tiles, B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])  # zero rows quantize harmlessly
    pp, _ = _pad_axis(pool, 2, tiles[2] if O >= 128 else 1)
    out = pcilt_shared_gemv_pallas(xp, s2, idx2, pp, tiles=tiles, **kw)
    return out[:B, :O]


def _shared_gemv_bench(x, s2, idx2, pool, cfg, kw):
    B, O = x.shape[0], pool.shape[-1]
    G = idx2.shape[-1]
    tiles = _fit_tiles((cfg.Bb, cfg.Gb, cfg.Ob), B, G, O)
    xp, _ = _pad_axis(x, 0, tiles[0])
    pp, _ = _pad_axis(pool, 2, tiles[2] if O >= 128 else 1)
    return lambda: pcilt_shared_gemv_pallas(
        xp, s2, idx2, pp, tiles=tiles, **kw
    ).block_until_ready()


def pcilt_shared_conv2d(
    x: jax.Array,
    pool: jax.Array,
    seg_idx: jax.Array,
    spec,
    scale,
    group: int,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    tiles=None,
    autotune: Optional[bool] = None,
    seg_offset=None,
    n_total: Optional[int] = None,
) -> jax.Array:
    """x [B, H, W, C] float NHWC, pool [X, V, O], seg_idx [G] int32
    -> [B, Ho, Wo, O].

    The shared-pool sibling of :func:`pcilt_fused_conv2d`: same host-side
    spatial pad and in-VMEM im2col, with the dense table operand replaced by
    (pointers, pool).  ``n_total = G * group >= kh*kw*C`` (alignment slots
    must have been built from zero weights).  ``seg_offset`` / ``n_total``
    carry the shard's first global segment and the global padded reduction
    length under ``shard_map`` — the pool and pointers stay local.
    """
    if padding == "SAME":
        x = jnp.pad(x, _conv_same_pads(x.shape[1], x.shape[2], kh, kw, stride))
    B, Hp, Wp, C = x.shape
    X, V, O = pool.shape
    G = int(seg_idx.shape[-1])
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    key = atn.shape_key("shared_conv2d", dtype=pool.dtype,
                        backend=jax.default_backend(),
                        B=B, Ho=Ho, W=Wp, C=C, k=kh * kw, s=stride,
                        G=G, V=V, O=O, X=X, g=group, bits=spec.bits)
    s2 = _scale_2d(scale, x.dtype)
    seg2 = _seg_2d(seg_offset)
    idx2 = seg_idx.astype(jnp.int32).reshape(1, G)
    kw_args = dict(bits=spec.bits, zero_point=spec.zero_point, group=group,
                   kh=kh, kw=kw, stride=stride,
                   n_total=int(n_total) if n_total else G * group,
                   interpret=not on_tpu())
    if tiles is None:
        cfg = atn.lookup(key)
        if cfg is None and atn.autotune_enabled(autotune) and _is_concrete(
                x, s2, seg2, idx2, pool):
            cfg = atn.tune(
                key,
                atn.shared_conv2d_candidates(Ho, G, V, O, X,
                                             pool.dtype.itemsize, Wo=Wo),
                lambda c: _shared_conv2d_bench(x, s2, seg2, idx2, pool, c,
                                               kw_args, Ho),
            )
        if cfg is None:
            cfg = atn.shared_conv2d_candidates(Ho, G, V, O, X,
                                               pool.dtype.itemsize, Wo=Wo)[0]
        tiles = (cfg.row_tile, cfg.Gb, cfg.Ob)
    Hb, Gb, Ob = _fit_conv_tiles(tiles, Ho, G, O)
    pp, _ = _pad_axis(pool, 2, Ob if O >= 128 else 1)
    out = pcilt_shared_conv2d_pallas(x, s2, seg2, idx2, pp,
                                     tiles=(Hb, Gb, Ob), **kw_args)
    return out[..., :O]


def _shared_conv2d_bench(x, s2, seg2, idx2, pool, cfg, kw_args, Ho):
    G, O = idx2.shape[-1], pool.shape[-1]
    Hb, Gb, Ob = _fit_conv_tiles((cfg.row_tile, cfg.Gb, cfg.Ob), Ho, G, O)
    pp, _ = _pad_axis(pool, 2, Ob if O >= 128 else 1)
    return lambda: pcilt_shared_conv2d_pallas(
        x, s2, seg2, idx2, pp, tiles=(Hb, Gb, Ob), **kw_args
    ).block_until_ready()
