"""Jit'd dispatch wrappers for the PCILT Pallas kernels.

Handles platform selection (compiled Pallas on TPU, ``interpret=True``
elsewhere so the exact kernel body is validated on CPU), padding to tile
multiples, and unpadding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .pcilt_gemv import pcilt_gemv_pallas
from .pcilt_conv2d import pcilt_conv2d_pallas
from .pcilt_dwconv1d import pcilt_dwconv1d_pallas

__all__ = ["pcilt_gemv", "pcilt_conv2d", "pcilt_dwconv1d", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x: jax.Array, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def pcilt_gemv(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, G] int32, tables [G, V, O] -> [B, O]."""
    B, O = offsets.shape[0], tables.shape[-1]
    offsets, _ = _pad_axis(offsets, 0, 8)
    tables, _ = _pad_axis(tables, 2, 128 if tables.shape[-1] >= 128 else 1)
    out = pcilt_gemv_pallas(offsets, tables, interpret=not on_tpu())
    return out[:B, :O]


def pcilt_conv2d(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, Ho, Wo, G] int32, tables [G, V, O] -> [B, Ho, Wo, O]."""
    return pcilt_conv2d_pallas(offsets, tables, interpret=not on_tpu())


def pcilt_dwconv1d(offsets: jax.Array, tables: jax.Array) -> jax.Array:
    """offsets [B, T, C] int32, tables [C, V] -> [B, T, C]."""
    C = offsets.shape[-1]
    offsets, padc = _pad_axis(offsets, 2, 128 if C >= 128 else 1)
    tables, _ = _pad_axis(tables, 0, 128 if C >= 128 else 1)
    out = pcilt_dwconv1d_pallas(offsets, tables, interpret=not on_tpu())
    return out[..., :C]
