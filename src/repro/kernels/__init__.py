"""repro.kernels — Pallas TPU kernels for the PCILT hot path.

Two pipelines implement the paper's fetch-and-add inner loop:

* **host-packed** (``pcilt_gemv.py``, ``pcilt_conv2d.py``,
  ``pcilt_dwconv1d.py``): quantization, im2col, and offset bit-packing run on
  the host and the kernel consumes a pre-built int32 offset tensor.  Kept for
  callers that hold offsets already (generalized ``SegmentPlan`` packings)
  and as the measured baseline.
* **fused** (``pcilt_fused.py``, ``pcilt_dwconv1d.py``): raw float
  activations in; quantize → offset-pack → table-fetch → adder-tree run
  entirely in VMEM, with the fetch expressed as a single flattened
  ``[Bb, Gb*V] x [Gb*V, Ob]`` one-hot MXU contraction per staged table tile
  (the depthwise conv1d uses a factored two-level one-hot — ``Vl + Vh``
  indicator lanes instead of ``V``).  The int32 offset tensor — for convs
  often larger than the activations — never touches HBM.  Tables may be
  stored bf16 to double the groups staged per ~8 MB VMEM budget.  The conv
  kernels take a ``seg_offset``/``n_total`` pair so tensor-parallel shards
  im2col the replicated image in VMEM and slice their own patch columns
  (``core.lut_layers`` ``mesh=``).  The **layer-stacked** GEMV variant
  (``pcilt_fused_gemv_stacked_pallas``) serves scanned LM decode: the
  ``[L, G, V, O]`` tables of a whole network stay resident and a
  scalar-prefetched layer index selects the staged per-layer tiles, so the
  decode ``lax.scan`` never copies a layer's tables through HBM.
* **shared-pool fused** (``pcilt_shared.py``): the fused pipeline over the
  extension-3 segment-deduped representation — a ``[X, V, O]`` pool of
  unique segment tables plus a ``[G]`` int32 pointer vector
  (``core.pcilt.SharedGroupedTables``).  The pointer indirection is resolved
  in-kernel by a one-hot pointer-select matmul on the staged pool, so
  weight-deduped layers fetch at fused speed and the dense ``[G, V, O]``
  tables never exist in HBM; staged bytes scale with the actual segment
  cardinality ``X``, not ``G``.

Dispatch (``ops.py``) routes both pipelines through a **persistent tile
autotuner** (``autotune.py``): per-shape winning tilings live in a JSON
lookup table (``$REPRO_PCILT_TUNE_CACHE``), so a cache hit dispatches at
zero cost and a miss can tune-once-and-record — the Inductor template
lookup-table design applied to PCILT.

``ref.py`` holds the pure-jnp oracles every kernel is tested against.
"""

from . import ops, ref, autotune  # noqa: F401

__all__ = ["ops", "ref", "autotune"]
