"""Distributed runtime: fault tolerance, straggler mitigation, pipeline parallelism."""
from .supervisor import StepWatchdog, detect_stragglers, Supervisor, FaultInjector
from .pipeline import pipeline_apply
