"""Distributed runtime: fault tolerance, straggler mitigation, pipeline parallelism."""
from .supervisor import StepWatchdog, detect_stragglers, Supervisor
from .faults import FaultInjector
from .pipeline import pipeline_apply
