"""Distributed runtime: fault tolerance, straggler mitigation, pipeline parallelism."""
from .supervisor import StepWatchdog, detect_stragglers, Supervisor
from .faults import FaultInjector
from .pipeline import pipeline_apply
from .traffic import (WallClock, VirtualClock, poisson_arrivals,
                      burst_arrivals, ramp_arrivals, make_arrivals)
