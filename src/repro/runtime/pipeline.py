"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Optional at the graded mesh sizes (2-D FSDP×TP wins on a v5e pod — DESIGN.md
§4); this is the cross-pod scaling building block for 1000+-chip
deployments, where a third mesh axis keeps TP domains inside a pod and
pipelines across pods.

Mechanics: stage ``s`` holds its slice of the stacked per-stage parameters;
microbatches enter at stage 0 and flow through a ``collective_permute``
ring.  The schedule runs ``M + S - 1`` ticks (fill + drain); each stage
computes only when its slot holds a live microbatch.  Activations are
fixed-shape, so the whole schedule is one ``lax.scan`` inside one
``shard_map`` — no host round-trips.  Differentiable end-to-end
(``ppermute`` transposes to the reverse ring), so the same primitive serves
training; 1F1B interleaving is a schedule refinement on top.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map



__all__ = ["pipeline_apply"]


def pipeline_apply(
    fn: Callable,
    stage_params,
    x: jax.Array,
    mesh: Mesh,
    axis: str = "stage",
):
    """Run ``x`` microbatches through ``S`` pipeline stages.

    fn: (params_slice, act [B, ...]) -> act [B, ...]  (one stage's compute)
    stage_params: pytree with a leading stage dim (sharded over ``axis``)
    x: [M, B, ...] microbatches (replicated in; M >= 1)
    Returns [M, B, ...]: the last stage's outputs, replicated.
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = x.shape[0]
    perm = [(i, (i + 1) % S) for i in range(S)]

    def body(params_local, xs):
        params_one = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        out0 = jnp.zeros_like(xs)
        buf0 = jnp.zeros_like(xs[0])

        def tick(carry, t):
            out_acc, inbuf = carry
            mb = t - sid  # microbatch index at this stage this tick
            feed = xs[jnp.clip(t, 0, M - 1)]
            a_in = jnp.where(sid == 0, feed, inbuf)
            active = (mb >= 0) & (mb < M)
            y = fn(params_one, a_in)
            y = jnp.where(active, y, a_in)
            # emit: last stage banks its finished microbatch
            write = active & (sid == S - 1)
            idx = jnp.clip(mb, 0, M - 1)
            out_acc = jax.lax.dynamic_update_index_in_dim(
                out_acc,
                jnp.where(write, y, out_acc[idx]),
                idx, axis=0)
            nxt = jax.lax.ppermute(y, axis, perm)
            return (out_acc, nxt), None

        (out, _), _ = jax.lax.scan(
            tick, (out0, buf0), jnp.arange(M + S - 1, dtype=jnp.int32))
        # replicate the last stage's bank to every stage
        return jax.lax.psum(jnp.where(sid == S - 1, out, 0.0), axis)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x)
