"""Open-loop traffic generation for the serving engine.

Closed-loop benchmarks (feed the next request when a slot frees) hide
overload: the harness self-throttles to the engine's capacity and latency
looks flat no matter how slow the engine is.  An **open-loop** arrival
process fixes the *offered* load independently of the engine's progress —
the only honest way to measure shed rate and tail latency under 2x
capacity.  This module provides:

* seeded arrival processes (:func:`poisson_arrivals`,
  :func:`burst_arrivals`, :func:`ramp_arrivals`, dispatched through
  :func:`make_arrivals`) — absolute arrival timestamps, deterministic for a
  seed, so a CI run and a local repro see the identical request stream;
* clocks the engine injects (``Engine(clock=...)``): :class:`WallClock`
  (production default) and :class:`VirtualClock` (tests/benchmarks —
  ``sleep`` *advances* virtual time instead of blocking, so deadline and
  backoff paths run deterministically at full speed instead of flaking on a
  loaded CI runner).

The virtual clock pairs with ``Engine(step_cost_s=...)``: each engine step
advances the clock by a fixed simulated service time, which makes capacity
analytic (``slots / (steps_per_request * step_cost_s)`` requests/s) and the
0.5x/1x/2x load points of ``benchmarks/traffic_bench.py`` exact.
"""

from __future__ import annotations

import time as _time
from typing import List, Optional, Sequence

import numpy as np

__all__ = [
    "WallClock",
    "VirtualClock",
    "poisson_arrivals",
    "burst_arrivals",
    "ramp_arrivals",
    "make_arrivals",
    "PROFILES",
]


class WallClock:
    """The production clock: real time, real sleeps."""

    def time(self) -> float:
        return _time.time()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            _time.sleep(seconds)


class VirtualClock:
    """A deterministic clock: ``sleep`` advances virtual time, never blocks.

    The engine's deadline, backoff, and arrival logic all read
    ``clock.time()`` and wait via ``clock.sleep()``, so swapping this in
    makes every time-dependent serving path a pure function of the seed —
    the CI traffic smoke runs thousands of virtual seconds in milliseconds.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def time(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            self._t += float(seconds)

    advance = sleep


def poisson_arrivals(n: int, rate: float, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    """``n`` absolute arrival times of a homogeneous Poisson process at
    ``rate`` requests/s starting at ``t0`` (exponential inter-arrivals)."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return t0 + np.cumsum(gaps)


def burst_arrivals(n: int, rate: float, burst: int = 4, seed: int = 0,
                   t0: float = 0.0) -> np.ndarray:
    """Bursty arrivals at the same *average* ``rate``: requests land in
    groups of ``burst`` simultaneous arrivals, with exponential gaps between
    groups stretched by ``burst`` so the long-run offered load matches the
    Poisson profile — the worst case for a bounded admission queue."""
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    rng = np.random.default_rng(seed)
    n_groups = -(-n // burst)
    gaps = rng.exponential(burst / rate, size=n_groups)
    group_t = t0 + np.cumsum(gaps)
    return np.repeat(group_t, burst)[:n]


def ramp_arrivals(n: int, rate: float, rate_end: Optional[float] = None,
                  seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """Arrivals whose instantaneous rate ramps linearly from ``rate`` to
    ``rate_end`` (default ``2 * rate``) across the stream — the overload
    onset profile: the engine starts under capacity and ends past it, so
    admission control has to *transition* into shedding rather than start
    there."""
    if rate_end is None:
        rate_end = 2.0 * rate
    if rate <= 0 or rate_end <= 0:
        raise ValueError(f"rates must be positive, got {rate}, {rate_end}")
    rng = np.random.default_rng(seed)
    rates = np.linspace(rate, rate_end, n)
    gaps = rng.exponential(1.0, size=n) / rates
    return t0 + np.cumsum(gaps)


PROFILES = ("poisson", "burst", "ramp")


def make_arrivals(profile: str, n: int, rate: float, seed: int = 0,
                  t0: float = 0.0, **kw) -> np.ndarray:
    """Dispatch by profile name (the ``--traffic`` CLI surface)."""
    if profile == "poisson":
        return poisson_arrivals(n, rate, seed=seed, t0=t0, **kw)
    if profile == "burst":
        return burst_arrivals(n, rate, seed=seed, t0=t0, **kw)
    if profile == "ramp":
        return ramp_arrivals(n, rate, seed=seed, t0=t0, **kw)
    raise ValueError(f"unknown traffic profile {profile!r} "
                     f"(known: {PROFILES})")
