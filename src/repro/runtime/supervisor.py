"""Fault tolerance: step watchdog, straggler detection, supervised restart.

Single-host building blocks with the same interfaces a multi-host deployment
wires to real heartbeats:

* ``StepWatchdog`` — EMA of step wall time; flags steps exceeding
  ``deadline_factor ×`` the EMA (the "re-dispatch or preempt" signal for
  straggler mitigation at the pod level).
* ``detect_stragglers`` — given per-host step times (an all-gathered vector
  on real hardware), returns outlier host ids (median × threshold rule).
* ``Supervisor`` — wraps the train loop: on any step failure it restores the
  latest good checkpoint and replays from there, up to ``max_restarts``.
  Elastic: the restore callback receives the (possibly re-built) mesh so a
  shrunken device set resumes seamlessly (tests simulate exactly this).

``FaultInjector`` grew into the full chaos harness and lives in
``runtime.faults`` now; it is re-exported here for compatibility.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from .faults import FaultInjector

log = logging.getLogger("repro.supervisor")

__all__ = ["StepWatchdog", "detect_stragglers", "Supervisor", "FaultInjector"]


class StepWatchdog:
    def __init__(self, deadline_factor: float = 3.0, ema: float = 0.9,
                 min_samples: int = 5):
        self.deadline_factor = deadline_factor
        self.ema_coef = ema
        self.min_samples = min_samples
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when the step breached its deadline."""
        slow = False
        if self.ema is not None and self.n >= self.min_samples:
            slow = dt > self.deadline_factor * self.ema
        self.ema = dt if self.ema is None else (
            self.ema_coef * self.ema + (1 - self.ema_coef) * dt)
        self.n += 1
        if slow:
            self.flagged.append(step)
            log.warning("step %d took %.3fs (deadline %.3fs) — straggler?",
                        step, dt, self.deadline_factor * (self.ema or dt))
        return slow


def detect_stragglers(host_step_times: Sequence[float],
                      threshold: float = 2.0) -> List[int]:
    """Host ids whose step time exceeds ``threshold × median``."""
    t = np.asarray(host_step_times, np.float64)
    med = np.median(t)
    return [int(i) for i in np.nonzero(t > threshold * med)[0]]


@dataclasses.dataclass
class Supervisor:
    """Run ``n_steps`` of ``step_fn`` with checkpoint/restart semantics.

    step_fn(state, step) -> state        (may raise)
    save_fn(state, step) -> None         (called every ``ckpt_every``)
    restore_fn() -> (step, state) | None (latest good checkpoint)
    """

    step_fn: Callable
    save_fn: Callable
    restore_fn: Callable
    ckpt_every: int = 10
    max_restarts: int = 3

    def run(self, state, n_steps: int, start_step: int = 0):
        step = start_step
        restarts = 0
        watchdog = StepWatchdog()
        while step < n_steps:
            try:
                t0 = time.time()
                state = self.step_fn(state, step)
                watchdog.observe(step, time.time() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(state, step)
            except Exception as e:  # noqa: BLE001 — any step fault
                restarts += 1
                log.error("step %d failed (%s); restart %d/%d",
                          step, e, restarts, self.max_restarts)
                if restarts > self.max_restarts:
                    raise
                restored = self.restore_fn()
                if restored is None:
                    raise RuntimeError("no checkpoint to restore from") from e
                step, state = restored
        return step, state, {"restarts": restarts,
                             "straggler_steps": watchdog.flagged}
