"""Composable fault injection — the chaos harness behind the resilience layer.

Generalizes the scheduled-raise ``FaultInjector`` that ``Supervisor`` tests
always used into one seeded harness covering every fault class the serving
engine must survive (``docs/resilience.md`` maps each to its detection and
response):

* **scheduled step faults** — :meth:`FaultInjector.maybe_fail` raises at
  given steps, once each (the original ``Supervisor`` contract, unchanged);
* **table corruption** — :meth:`corrupt_table` flips entries of any dense
  PCILT table array (conv ``[L, C, V]``, stacked proj ``[L, G, V, O]``,
  shared pools ``[X, V, O]``), simulating an HBM / host-memory bit-flip;
* **pointer corruption** — :meth:`flip_seg_idx` re-aims extension-3
  ``seg_idx`` pointers at wrong (possibly out-of-range) pool rows;
* **activation poisoning** — :meth:`poison` plants NaN/Inf in decode
  activations or recurrent cache state;
* **calibration drift** — :meth:`drift_scale` multiplies rows of a
  parameter (e.g. one layer's norm gain) so the live activation
  distribution walks away from the range the PCILTs were calibrated on —
  the only fault class that corrupts *no* bytes, only the statistics;
* **file garbling** — :meth:`garble_file` truncates or overwrites the
  persistent autotune JSON (or any on-disk artifact) in place.

Every injection is recorded in :attr:`FaultInjector.events` (a structured
list the chaos suite asserts against) and logged.  Corruption methods are
*functional*: they return a fresh corrupted array — JAX arrays are immutable
and jitted executors close over table values, so the caller swaps the new
array into its bundle and re-hoists the executor (the serving analogue of
"the bytes under the kernel changed").
"""

from __future__ import annotations

import logging
import os
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

log = logging.getLogger("repro.faults")

__all__ = ["FaultInjector"]


class FaultInjector:
    """Deterministic (seeded) fault schedule + corruption primitives."""

    def __init__(self, fail_at: Sequence[int] = (), seed: int = 0):
        self.fail_at = set(fail_at)
        self.rng = np.random.default_rng(seed)
        #: structured record of every injected fault, in injection order
        self.events: List[Dict[str, Any]] = []

    def _record(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, **info})
        log.warning("injected %s: %s", kind, info)

    # -- scheduled step faults (the original Supervisor contract) -----------

    def maybe_fail(self, step: int) -> None:
        """Raise at the scheduled steps, once each — replays are clean."""
        if step in self.fail_at:
            self.fail_at.discard(step)
            self._record("step_fault", step=int(step))
            raise RuntimeError(f"injected fault at step {step}")

    # -- table / pointer corruption ------------------------------------------

    def corrupt_table(self, tables, n_flips: int = 1):
        """Flip ``n_flips`` random entries of a table array; returns the
        corrupted copy (same shape/dtype) — swap it into the bundle and
        re-hoist.  Each flipped value is guaranteed to differ from the
        original (``x -> x + (1 + |x|)`` survives any float rounding)."""
        import jax.numpy as jnp

        a = np.asarray(tables).copy()
        flat = a.reshape(-1)
        n = min(max(n_flips, 1), flat.size)
        idx = self.rng.choice(flat.size, size=n, replace=False)
        for i in idx:
            old = float(np.float32(flat[i]))
            flat[i] = flat.dtype.type(old + (1.0 + abs(old)))
        sites = [tuple(int(c) for c in np.unravel_index(int(i), a.shape))
                 for i in idx]
        self._record("table_corruption", shape=tuple(a.shape), sites=sites)
        return jnp.asarray(a)

    def flip_seg_idx(self, seg_idx, n_pool: Optional[int] = None,
                     n_flips: int = 1):
        """Re-aim ``n_flips`` extension-3 segment pointers; returns the
        corrupted copy.  Pointers move to a different row of the ``n_pool``
        -row pool (``X == 1`` pools get an out-of-range pointer — the only
        way a single-row pool's pointers can be wrong)."""
        import jax.numpy as jnp

        a = np.asarray(seg_idx).copy()
        X = int(n_pool) if n_pool is not None else int(a.max()) + 1
        n = min(max(n_flips, 1), a.size)
        idx = self.rng.choice(a.size, size=n, replace=False)
        for i in idx:
            old = int(a.reshape(-1)[i])
            if X > 1:
                new = (old + 1 + int(self.rng.integers(0, X - 1))) % X
            else:
                new = old + 1  # out of range: still a detectable wrong pointer
            a.reshape(-1)[i] = new
        self._record("seg_idx_flip", sites=[int(i) for i in idx], n_pool=X)
        return jnp.asarray(a)

    # -- activation / state poisoning ----------------------------------------

    def poison(self, x, kind: str = "nan", n: int = 1):
        """Plant ``n`` NaN (or Inf) values at random positions of a float
        array (activations, logits, recurrent cache state); returns the
        poisoned copy."""
        import jax.numpy as jnp

        a = np.asarray(x).copy()
        val = np.nan if kind == "nan" else np.inf
        flat = a.reshape(-1)
        n = min(max(n, 1), flat.size)
        idx = self.rng.choice(flat.size, size=n, replace=False)
        flat[idx] = flat.dtype.type(val)
        self._record("activation_poison", poison=kind,
                     sites=[int(i) for i in idx], shape=tuple(a.shape))
        return jnp.asarray(a)

    # -- calibration drift ----------------------------------------------------

    def drift_scale(self, x, gamma: float, rows: Optional[Sequence[int]] = None):
        """Scale ``x`` (or just ``rows`` of its leading axis) by ``gamma``;
        returns the drifted copy.  Unlike every other injection this leaves
        all table bytes intact — checksums still pass, the dense oracle still
        agrees — so only the saturation sentinel can catch it."""
        import jax.numpy as jnp

        a = np.asarray(x).copy()
        if rows is None:
            a *= a.dtype.type(gamma)
            sites = "all"
        else:
            sites = [int(r) for r in rows]
            a[sites] *= a.dtype.type(gamma)
        self._record("calibration_drift", gamma=float(gamma), rows=sites,
                     shape=tuple(a.shape))
        return jnp.asarray(a)

    # -- on-disk artifact garbling -------------------------------------------

    def garble_file(self, path: str, mode: str = "truncate") -> None:
        """Corrupt a file in place: ``"truncate"`` keeps the first half of
        the bytes, ``"garbage"`` overwrites with non-JSON bytes, ``"empty"``
        leaves zero bytes.  A missing file is recorded, not an error."""
        if not os.path.exists(path):
            self._record("file_garble", path=path, mode=mode, absent=True)
            return
        with open(path, "rb") as f:
            data = f.read()
        if mode == "truncate":
            data = data[: max(len(data) // 2, 1)]
        elif mode == "garbage":
            data = b'{"tiles": tru\x00\xff not json'
        elif mode == "empty":
            data = b""
        else:
            raise ValueError(f"unknown garble mode {mode!r}")
        with open(path, "wb") as f:
            f.write(data)
        self._record("file_garble", path=path, mode=mode, absent=False)
