"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d4096 32H (GQA
kv=8) d_ff 14336 vocab 32000, sliding window 4096.  Vision frontend STUBBED
per assignment: input_specs supplies projected patch embeddings (anyres
tiling resolved host-side); 576 image tokens prepended (early fusion).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The 4096-token sliding window makes decode a rolling KV buffer ->
``long_500k`` runs with constant memory (DESIGN.md §7).
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        window=4096, n_img_tokens=576,
        rope_theta=1000000.0,
        remat_policy="full", loss_chunk=2048,
    )


def smoke_config():
    return ModelConfig(
        name="llava-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        window=32, n_img_tokens=8,
        remat_policy="none", loss_chunk=0,
    )
