"""qwen3-0.6b [dense] — 28L d1024 16H (GQA kv=8) d_ff 3072, vocab 151936,
qk-norm, head_dim 128 (decoupled from d_model/H), tied embeddings.
[hf:Qwen/Qwen3-8B; hf]
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, head_dim=128,
        qk_norm=True, tie_embeddings=True,
        rope_theta=1000000.0,
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=32, qk_norm=True, tie_embeddings=True,
        remat_policy="none", loss_chunk=0,
    )
