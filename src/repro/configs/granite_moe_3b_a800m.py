"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) d_ff 512/expert,
vocab 49155, MoE 40 experts top-8, MoE every layer.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Heads pad 24->32 and experts 40->48 for 16-way TP/EP (dead experts are
router-masked); vocab pads 49155->49168 for the model-axis logits shard.
"""

from .base import ModelConfig, MoEConfig


def config():
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, head_dim=64,
        pad_heads_to=32,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                      interleave=1, pad_experts_to=48),
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="granite-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256, head_dim=16,
        moe=MoEConfig(n_experts=10, top_k=4, d_ff_expert=32, interleave=1,
                      pad_experts_to=12),
        remat_policy="none", loss_chunk=0,
    )
