"""whisper-medium [audio] — enc-dec, 24+24L d1024 16H (MHA) d_ff 4096,
vocab 51865, LayerNorm+GELU, sinusoidal positions, conv frontend STUBBED:
input_specs supplies precomputed 1500-frame embeddings (30 s of audio).
[arXiv:2212.04356; unverified]
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        encoder_layers=24, encoder_len=1500,
        pos_embed="sinusoidal",
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        encoder_layers=2, encoder_len=16,
        pos_embed="sinusoidal",
        remat_policy="none", loss_chunk=0,
    )
