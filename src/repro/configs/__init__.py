"""Architecture registry: ``--arch <id>`` resolution."""

import importlib

from .base import ModelConfig, MoEConfig, SSMConfig, PCILTConfig, ShapeConfig, SHAPES

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen2.5-3b": "qwen25_3b",
    "qwen3-0.6b": "qwen3_06b",
    "whisper-medium": "whisper_medium",
    "mamba2-130m": "mamba2_130m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-7b": "zamba2_7b",
}

ARCHS = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _mod(name).config()


def get_smoke_config(name: str):
    return _mod(name).smoke_config()
