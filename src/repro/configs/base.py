"""Config dataclasses for the model zoo.

Every assigned architecture is a :class:`ModelConfig`; ``configs/<id>.py``
exports ``config()`` (the exact published shape) and ``smoke_config()`` (a
reduced same-family variant for CPU tests).

Sharding-driven padding: ``pad_heads_to`` / ``pad_experts_to`` round head and
expert counts up so they divide the production 16-way model axis (Megatron's
divisible-size trick).  Padding is part of the *config* (mesh-independent) so
checkpoints stay elastic across meshes; smoke configs use no padding and the
dry-run report carries both nominal and padded parameter counts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "PCILTConfig", "ShapeConfig",
           "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    interleave: int = 1          # MoE every `interleave` layers (2 = alternate)
    shared_expert: bool = False  # always-on shared expert (llama4)
    capacity_factor: float = 1.25
    pad_experts_to: int = 0      # 0 = no padding

    @property
    def padded_experts(self) -> int:
        return max(self.n_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    chunk: int = 256
    dt_rank: int = 0  # unused in SSD; kept for provenance


@dataclasses.dataclass(frozen=True)
class PCILTConfig:
    """Paper-technique integration for quantized serving (DESIGN.md §6)."""

    act_bits: int = 4
    group: int = 2
    weight_bits: int = 4
    apply_to_conv: bool = True   # frontends (mamba/whisper/llava)
    apply_to_gemv: bool = True   # decode projections


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 0              # sliding-window size (0 = full attention)
    rope_theta: float = 10000.0
    pos_embed: str = "rope"      # rope | sinusoidal | none
    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_period: int = 0  # zamba2: shared attn every N blocks
    n_shared_attn_blocks: int = 2
    encoder_layers: int = 0      # whisper enc-dec
    encoder_len: int = 1500
    n_img_tokens: int = 0        # llava stub frontend
    # sharding-driven padding (see module docstring)
    pad_heads_to: int = 0
    pad_kv_heads_to: int = 0
    # numerics / structure
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat_policy: str = "dots"   # nothing | dots | full
    scan_layers: bool = True
    loss_chunk: int = 2048       # vocab-loss token chunking (0 = unchunked)
    grad_accum: int = 1          # microbatches per step (memory / collective
                                 # trade: activations ÷ n, weight gathers × n)
    pcilt: Optional[PCILTConfig] = None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 16 so the logits/embedding shard
        over the 16-way model axis (Megatron's divisible-vocab trick; padded
        ids are never produced by data or sampling)."""
        return self.vocab + (-self.vocab) % 16

    @property
    def padded_heads(self) -> int:
        return max(self.n_heads, self.pad_heads_to)

    @property
    def padded_kv_heads(self) -> int:
        return max(self.n_kv_heads, self.pad_kv_heads_to)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §7)."""
        return self.family in ("ssm", "hybrid") or self.window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
