"""mamba2-130m [ssm] — 24L d768 attention-free, vocab 50280, SSD with
d_state 128, head_dim 64 (24 heads), expand 2, conv kernel 4, tied embeds.
[arXiv:2405.21060; unverified]

24 SSD heads do not divide the 16-way model axis -> the SSD interior runs
head-replicated (projections still TP-shard); noted in the roofline table.
"""

from .base import ModelConfig, SSMConfig


def config():
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, head_dim=1,
        ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, conv_kernel=4,
                      expand=2, chunk=256),
        tie_embeddings=True,
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256, head_dim=1,
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, conv_kernel=4,
                      expand=2, chunk=16),
        tie_embeddings=True,
        remat_policy="none", loss_chunk=0,
    )
