"""The paper's own 5-layer CNN example (50-80-120-200-350 channels, 5x5
filters, INT8/INT4 activations) — the faithful reproduction target.  Not an
assigned LM cell; exercised by examples/quickstart.py and benchmarks.
"""

from repro.models.cnn import PaperCNN
from repro.core import QuantSpec


def config():
    return PaperCNN(in_channels=1, n_classes=10,
                    act_spec=QuantSpec(bits=8), group=1)


def smoke_config():
    return PaperCNN(in_channels=1, n_classes=10, channels=(8, 12),
                    act_spec=QuantSpec(bits=2), group=1)
