"""zamba2-7b [hybrid] — 81 Mamba2 blocks d3584 (d_state 64) + shared
attention blocks (32H MHA on concat(hidden, embed) = 7168 wide, d_ff 14336
MLP) applied every 6 blocks, 2 alternating shared param sets, vocab 32000.
[arXiv:2411.15242; unverified]

112 SSD heads divide the 16-way model axis -> fully sharded SSD.
"""

from .base import ModelConfig, SSMConfig


def config():
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000, head_dim=112,
        ssm=SSMConfig(d_state=64, head_dim=64, n_groups=1, conv_kernel=4,
                      expand=2, chunk=256),
        shared_attn_period=6, n_shared_attn_blocks=2,
        remat_policy="full", loss_chunk=2048,
    )


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=32,
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, conv_kernel=4,
                      expand=2, chunk=16),
        shared_attn_period=3, n_shared_attn_blocks=2,
        remat_policy="none", loss_chunk=0,
    )
