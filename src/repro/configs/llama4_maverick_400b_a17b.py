"""llama4-maverick-400b-a17b [moe] — 48L d5120 40H (GQA kv=8) d_ff 8192,
vocab 202048, MoE 128 experts top-1 + always-on shared expert, MoE every
other layer (interleave 2: the public Maverick alternates dense/MoE).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Heads pad 40->48 for 16-way TP (DESIGN.md); experts 128 divide evenly.
"""

from .base import ModelConfig, MoEConfig


def config():
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, head_dim=128,
        pad_heads_to=48,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                      interleave=2, shared_expert=True),
        rope_theta=500000.0,
        remat_policy="full", loss_chunk=512, grad_accum=4,
    )


def smoke_config():
    return ModelConfig(
        name="llama4-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128,
                      interleave=2, shared_expert=True),
        remat_policy="none", loss_chunk=0,
    )
