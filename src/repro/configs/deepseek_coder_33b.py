"""deepseek-coder-33b [dense] — 62L d7168 56H (GQA kv=8) d_ff 19200,
vocab 32256, llama architecture.  [arXiv:2401.14196; hf]

Heads pad 56->64 for 16-way TP.
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=19200, vocab=32256, head_dim=128,
        pad_heads_to=64,
        rope_theta=100000.0,
        remat_policy="full", loss_chunk=2048,
    )


def smoke_config():
    return ModelConfig(
        name="deepseek-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab=256, head_dim=8,
        remat_policy="none", loss_chunk=0,
    )
