"""qwen1.5-4b [dense] — 40L d2560 20H (MHA kv=20) d_ff 6912, vocab 151936,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]

Heads (q and kv) pad 20->32 for 16-way TP; with MHA both pad together so the
KV heads shard too.
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab=151936, head_dim=128,
        qkv_bias=True, pad_heads_to=32, pad_kv_heads_to=32,
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="qwen15-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16, qkv_bias=True,
        remat_policy="none", loss_chunk=0,
    )
